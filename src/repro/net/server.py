"""Shard host: one ``DurableStore`` + its applied state behind the wire
protocol (DESIGN.md §8).

``ShardHost`` is the transport-free request handler — every protocol
message maps onto the durable-store primitive the coordinator would have
called locally (append_many / checkpoint / restore_at / recover /
rollback_to / retain / read_range), plus the replication verbs (TAIL,
REPLICA_ACK, STATE_HASH) and the planned read path (QUERY executes the
coordinator's ``QueryPlan`` route on the applied state). ``ShardServer``
wraps a host in a TCP accept loop, one frame per request; the CLI
(``python -m repro.net.server``) runs one shard per process and prints
``LISTENING <port>`` so a launcher or test can find the bound port.

Two invariants make the host correct under an at-least-once transport:

  * APPEND carries the client's expected base cursor; the host applies
    only at that cursor, and recognizes a byte-identical redelivery of the
    last committed group (same base, same digest, cursor already advanced)
    as a duplicate to re-ack — exactly-once commit over retries;
  * every hash the host advertises (HELLO, TAIL, STATE_HASH, REPLICA_ACK
    verification) is ``hashing.hash_pytree`` of a state the determinism
    contract makes bit-reproducible, so the remote end can *check* it
    rather than trust it.

A third invariant fences failover (DESIGN.md §12): the host keeps a
**durable fencing epoch** (an ``epoch`` file beside the store) that only
ever increases — adopted from HELLO, HEARTBEAT or APPEND frames carrying
a greater one, persisted *before* it takes effect. An APPEND whose epoch
is below the host's durable epoch is refused with ``StaleEpochError``:
once the failure detector stamps a revived old primary with the fleet
epoch, that host's pre-failover writers can never commit again.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import socket
import struct
import threading
from typing import Dict, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import hashing, machine, query as query_lib, snapshot
from repro.core.commands import log_from_bytes, log_to_bytes
from repro.core.contracts import DEFAULT_CONTRACT, get_contract
from repro.core.durability import DurableStore, SideTable
from repro.core.shard_wal import live_count
from repro.core.state import MemoryState, init_state
from repro.net import protocol as p

_VDT = {1: "<i1", 2: "<i2", 4: "<i4", 8: "<i8"}

EPOCH_FILE = "epoch"


def load_epoch(directory) -> int:
    """The shard's durable fencing epoch (0 when never stamped)."""
    path = pathlib.Path(directory) / EPOCH_FILE
    try:
        return int(path.read_text().strip())
    except (FileNotFoundError, ValueError):
        return 0


def persist_epoch(directory, epoch: int) -> None:
    """Durably record the fencing epoch (write-then-rename + fsync, the
    WAL discipline: the fence must survive exactly the crashes it exists
    to fence)."""
    path = pathlib.Path(directory) / EPOCH_FILE
    tmp = path.with_suffix(".tmp")
    with open(tmp, "w") as f:
        f.write(f"{int(epoch)}\n")
        f.flush()
        os.fsync(f.fileno())
    tmp.replace(path)


class ShardHost:
    """The request handler: one durable shard, its applied state, and the
    replication bookkeeping — no sockets. ``handle(msg)`` is the entire
    server semantics; ``ShardServer`` and the in-process ``LocalTransport``
    drive the same code path, so fault-injection tests exercise exactly
    the bytes and branches production traffic does."""

    # compressed-tier cache, keyed by applied cursor: the code table is a
    # pure function of the state (DESIGN.md §10), so any holder of the same
    # durable prefix derives the same table — caching is a cost choice,
    # never a semantic one. Class-level default so adopt() inherits it.
    _code_cache: Optional[Tuple[int, object]] = None

    def __init__(self, directory, genesis: Optional[MemoryState] = None, *,
                 segment_records: int = 1024,
                 ef_construction: int = 32):
        self.store = DurableStore(directory, genesis,
                                  segment_records=segment_records)
        self.ef_construction = ef_construction
        self._lock = threading.RLock()
        # (base_t, group digest, resulting t) of the last committed group —
        # the duplicate-APPEND detector (at-least-once transport)
        self._last_group: Optional[Tuple[int, int, int]] = None
        self.replica_cursors: Dict[int, int] = {}  # replica_id -> acked t
        self.state, self._hash, t = self.store.recover(
            ef_construction=ef_construction)
        assert t == self.store.t
        # serving-layer cache shipped to replicas via SIDE_TAIL (§9): doc
        # token prefixes and friends, torn-tail-truncated on open like the
        # engine's own table
        self.side_table = SideTable(self.store.dir / "docs.sdt")
        # fencing epoch (§12): survives restarts — a revived host stays
        # fenced at whatever epoch it was last stamped with
        self.epoch = load_epoch(self.store.dir)
        self._closed = False

    @classmethod
    def adopt(cls, store: DurableStore, state: MemoryState, state_hash: int,
              *, ef_construction: int = 32,
              epoch: Optional[int] = None) -> "ShardHost":
        """Wrap an already-open store + verified applied state as a host
        WITHOUT the recovery replay — the promotion path (DESIGN.md §9):
        a replica's state is proven bit-identical at its cursor, so the
        new primary adopts it after one lockstep check instead of
        rebuilding it from the WAL. ``epoch``, when given, stamps the
        promoted host with the new fleet epoch durably (§12) — promotion
        IS an epoch change, so the old regime's writers are fenced from
        the first request the new primary serves."""
        if int(state.version) != store.t:
            raise ValueError(
                f"adopt: applied cursor {int(state.version)} != durable "
                f"cursor {store.t} — recover() first")
        host = cls.__new__(cls)
        host.store = store
        host.ef_construction = ef_construction
        host._lock = threading.RLock()
        host._last_group = None
        host.replica_cursors = {}
        host.state = state
        host._hash = state_hash
        host.side_table = SideTable(store.dir / "docs.sdt")
        host.epoch = load_epoch(store.dir)
        host._closed = False
        if epoch is not None:
            host._adopt_epoch(epoch)
        return host

    def _adopt_epoch(self, epoch: int) -> None:
        """Monotone epoch adoption: persist BEFORE honoring, so a crash
        can only lose an *advance* (re-stamped by the next beat), never
        resurrect a fenced regime."""
        if epoch > self.epoch:
            persist_epoch(self.store.dir, epoch)
            self.epoch = epoch

    def close(self) -> None:
        """Idempotent teardown (the side table holds the only file handle
        that outlives a request)."""
        with self._lock:
            if self._closed:
                return
            self.side_table.close()
            self._closed = True

    # ------------------------------------------------------------------ #

    @property
    def contract(self):
        return self.store.wal.contract

    def state_hash(self) -> int:
        return self._hash

    def _hash_at(self, t: int) -> int:
        """The shard's state hash as of cursor ``t`` — live when ``t`` is
        the applied cursor, otherwise a time-travel restore (total over the
        retained window: the genesis snapshot exists from birth)."""
        if t == int(self.state.version):
            return self._hash
        return self.store.restore_at(
            t, ef_construction=self.ef_construction)[1]

    def handle(self, msg: p.Message) -> p.Message:
        """One request to one response. Every refusal becomes an ERROR
        frame carrying the exception class name, so the client can rebuild
        the same exception family (``RemoteError`` is a ``ValueError``) and
        the coordinator's local error handling stays transport-agnostic."""
        with self._lock:
            try:
                return self._dispatch(msg)
            except Exception as e:  # noqa: BLE001 — becomes a typed frame
                return p.ErrorMsg(kind=type(e).__name__, message=str(e))

    # ------------------------------------------------------------------ #

    def _dispatch(self, msg: p.Message) -> p.Message:
        if isinstance(msg, p.Hello):
            self._adopt_epoch(msg.epoch)
            isz = np.dtype(jnp.dtype(self.contract.storage_dtype).name
                           ).itemsize
            return p.HelloAck(dim=self.store.wal.dim, itemsize=isz,
                              contract=self.contract.name, t=self.store.t,
                              state_hash=self._hash, epoch=self.epoch)
        if isinstance(msg, p.Heartbeat):
            self._adopt_epoch(msg.epoch)
            return p.HeartbeatAck(t=self.store.t, epoch=self.epoch,
                                  state_hash=self._hash)
        if isinstance(msg, p.Cursor):
            return p.CursorAck(t=self.store.t)
        if isinstance(msg, p.Append):
            return self._do_append(msg)
        if isinstance(msg, p.Query):
            return self._do_query(msg)
        if isinstance(msg, p.Checkpoint):
            return self._do_checkpoint(msg)
        if isinstance(msg, p.RestoreAt):
            state, h = self.store.restore_at(
                msg.t, ef_construction=self.ef_construction)
            return p.StateAck(t=msg.t, state_hash=h,
                              blob=snapshot.snapshot_bytes(state))
        if isinstance(msg, p.Recover):
            self.state, self._hash, t = self.store.recover(
                ef_construction=self.ef_construction)
            self._last_group = None
            return p.StateAck(t=t, state_hash=self._hash,
                              blob=snapshot.snapshot_bytes(self.state))
        if isinstance(msg, p.Rollback):
            self.store.rollback_to(msg.t)
            self.state, self._hash = self.store.restore_at(
                msg.t, ef_construction=self.ef_construction)
            self._last_group = None
            return p.RollbackAck(t=msg.t)
        if isinstance(msg, p.Tail):
            return self._do_tail(msg)
        if isinstance(msg, p.ReplicaCursorAck):
            return self._do_replica_ack(msg)
        if isinstance(msg, p.StateHashReq):
            return p.StateHashAck(t=int(self.state.version),
                                  state_hash=self._hash)
        if isinstance(msg, p.ReadRange):
            log = self.store.wal.read_range(msg.t0, msg.t1)
            return p.LogAck(log=log_to_bytes(log))
        if isinstance(msg, p.SideTail):
            count = self.side_table.record_count
            if msg.from_index > count:
                raise ValueError(
                    f"side tail from index {msg.from_index} is ahead of the "
                    f"table's {count} records")
            return p.SideTailAck(
                from_index=msg.from_index, count=count,
                table_digest=self.side_table.digest_at(count),
                records=tuple(self.side_table.records_from(msg.from_index)))
        if isinstance(msg, p.Retain):
            stats = self.store.retain(msg.keep)
            return p.RetainAck(
                snapshots_dropped=stats["snapshots_dropped"],
                wal_segments_dropped=stats["wal_segments_dropped"],
                chunks_dropped=stats["chunks_dropped"],
                oldest_snapshot=stats["oldest_snapshot"])
        raise ValueError(f"request type {type(msg).__name__} not servable")

    # ------------------------------------------------------------------ #

    def _do_append(self, msg: p.Append) -> p.AppendAck:
        if msg.epoch < self.epoch:
            # the fence (§12): this writer belongs to a pre-failover
            # regime — refuse BEFORE any cursor/duplicate logic, so a
            # fenced client cannot even re-ack old work
            raise p.StaleEpochError(
                f"append carries epoch {msg.epoch}, host is fenced at "
                f"epoch {self.epoch}: this writer was superseded by a "
                "promotion and must not commit")
        self._adopt_epoch(msg.epoch)
        if not msg.logs:
            return p.AppendAck(t=self.store.t)
        digest = hashing.digest_bytes(b"".join(msg.logs))
        if msg.base_t != self.store.t:
            last = self._last_group
            if (last is not None and msg.base_t == last[0]
                    and digest == last[1] and self.store.t == last[2]):
                # byte-identical redelivery of the committed group (the
                # ack was lost in transit): re-ack, never re-apply
                return p.AppendAck(t=self.store.t)
            raise ValueError(
                f"append base_t={msg.base_t} != durable cursor "
                f"{self.store.t}; recover() the coordinator first")
        logs = [log_from_bytes(b, self.contract) for b in msg.logs]
        # WAL first, then the applied state — a crash between the two is
        # exactly the recover() case (state rebuilt from the durable log)
        t = self.store.append_many(logs)
        state = self.state
        for log in logs:
            state = machine.bulk_apply(state, log,
                                       ef_construction=self.ef_construction)
        assert int(state.version) == t, "applied state fell out of lockstep"
        self.state = state
        self._hash = hashing.hash_pytree(state)
        self._last_group = (msg.base_t, digest, t)
        return p.AppendAck(t=t)

    def _coarse_table(self):
        """The shard's int8 code table at the current applied cursor,
        derived from the state on first use and kept until the cursor
        moves (every applied command advances ``state.version``, and a
        rollback to cursor t restores the deterministic state at t, so
        the cursor fully keys the table)."""
        from repro.core import codes as codes_lib
        v = int(self.state.version)
        if self._code_cache is None or self._code_cache[0] != v:
            self._code_cache = (v, codes_lib.build(self.state))
        return self._code_cache[1]

    def _do_query(self, msg: p.Query) -> p.QueryAck:
        vdt = _VDT.get(msg.itemsize)
        if vdt is None:
            raise ValueError(f"unsupported query itemsize {msg.itemsize}")
        want = msg.nq * msg.dim * msg.itemsize
        if len(msg.data) != want:
            raise ValueError(
                f"query payload is {len(msg.data)} bytes, "
                f"[{msg.nq}, {msg.dim}] x {msg.itemsize} needs {want}")
        queries = jnp.asarray(
            np.frombuffer(msg.data, dtype=vdt).reshape(msg.nq, msg.dim),
            self.contract.storage_dtype)
        # the wire Query reuses the ef field for the coarse candidate-set
        # size (the route string disambiguates), so the frozen frame
        # format carries the compressed tier without a fields change
        coarse = msg.route == query_lib.ROUTE_COARSE
        plan = query_lib.QueryPlan(
            route=msg.route, k=msg.k, ef=msg.ef, use_kernel=msg.use_kernel,
            live_count=live_count(self.state), reason="remote",
            ef_coarse=msg.ef if coarse else 0, dim=msg.dim)
        table = self._coarse_table() if coarse else None
        ids, scores = query_lib.execute_plan(self.state, queries, msg.k, plan,
                                             codes=table)
        ids_h = np.asarray(ids).astype("<i8")
        scores_h = np.asarray(scores).astype("<i8")
        return p.QueryAck(nq=msg.nq, k=msg.k, ids=ids_h.tobytes(),
                          scores=scores_h.tobytes())

    def _do_checkpoint(self, msg: p.Checkpoint) -> p.CheckpointAck:
        if msg.t != int(self.state.version):
            raise ValueError(
                f"checkpoint at t={msg.t} but applied cursor is "
                f"{int(self.state.version)}")
        if msg.expect_hash != self._hash:
            raise ValueError(
                f"checkpoint hash mismatch at t={msg.t}: coordinator slice "
                f"{msg.expect_hash:#x}, applied shard {self._hash:#x} — "
                "the shard diverged from the coordinator's audit twin")
        stats = self.store.checkpoint(self.state)
        return p.CheckpointAck(t=msg.t,
                               bytes_written=stats.get("bytes_written", 0))

    def _do_tail(self, msg: p.Tail) -> p.TailAck:
        if msg.from_t > self.store.t:
            raise ValueError(
                f"tail from t={msg.from_t} is ahead of durable cursor "
                f"{self.store.t}")
        log, t_end = self.store.wal.tail(msg.from_t,
                                         max_commands=msg.max_commands)
        return p.TailAck(from_t=msg.from_t, t_end=t_end,
                         state_hash=self._hash_at(t_end),
                         log=log_to_bytes(log))

    def _do_replica_ack(self, msg: p.ReplicaCursorAck) -> p.Message:
        if msg.t > self.store.t:
            raise ValueError(
                f"replica acked t={msg.t} ahead of the primary's durable "
                f"cursor {self.store.t}")
        expect = self._hash_at(msg.t)
        if msg.state_hash != expect:
            raise ValueError(
                f"replica {msg.replica_id} diverged at t={msg.t}: replica "
                f"{msg.state_hash:#x}, primary {expect:#x}")
        prev = self.replica_cursors.get(msg.replica_id, 0)
        self.replica_cursors[msg.replica_id] = max(prev, msg.t)
        return p.ReplicaCursorAckAck(t=self.replica_cursors[msg.replica_id])


# --------------------------------------------------------------------------- #
# TCP server
# --------------------------------------------------------------------------- #


def read_frame(sock: socket.socket) -> Optional[bytes]:
    """Read exactly one frame off a stream socket (None on clean EOF at a
    frame boundary). A connection that dies mid-frame raises
    TransportError — the frame was torn, not delivered."""
    header = _read_exact(sock, p.HEADER_BYTES, eof_ok=True)
    if header is None:
        return None
    total = p.frame_length(header)  # validates magic/format
    rest = _read_exact(sock, total - p.HEADER_BYTES, eof_ok=False)
    return header + rest


def _read_exact(sock: socket.socket, n: int, *, eof_ok: bool
                ) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError as e:
            raise p.TransportError(f"connection lost mid-frame: {e}") from e
        if not chunk:
            if eof_ok and not buf:
                return None
            raise p.TransportError(
                f"connection closed after {len(buf)}/{n} bytes of a frame")
        buf += chunk
    return buf


class ShardServer:
    """A ``ShardHost`` behind a TCP accept loop: one frame in, one frame
    out, connections served on daemon threads (the host serializes on its
    own lock, so concurrency never reorders a connection's commits)."""

    def __init__(self, host: ShardHost, *, address: str = "127.0.0.1",
                 port: int = 0):
        self.host = host
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((address, port))
        self._sock.listen(16)
        self.address, self.port = self._sock.getsockname()[:2]
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ShardServer":
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # close() shut the listener down
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            while True:
                try:
                    frame = read_frame(conn)
                except (p.TransportError, p.ProtocolError):
                    return  # torn/garbage stream: drop the connection
                if frame is None:
                    return
                try:
                    msg, rid, _ = p.decode_frame(frame)
                    resp = self.host.handle(msg)
                except p.ProtocolError as e:
                    resp, rid = p.ErrorMsg(kind="ProtocolError",
                                           message=str(e)), 0
                try:
                    conn.sendall(p.encode_frame(resp, rid))
                except OSError:
                    return

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve one durable shard over the wire protocol")
    ap.add_argument("--dir", required=True, help="shard store directory")
    ap.add_argument("--capacity", type=int, default=0,
                    help="genesis capacity (required when --dir is fresh)")
    ap.add_argument("--dim", type=int, default=0,
                    help="genesis vector dim (required when --dir is fresh)")
    ap.add_argument("--contract", default=DEFAULT_CONTRACT.name)
    ap.add_argument("--segment-records", type=int, default=1024)
    ap.add_argument("--ef-construction", type=int, default=32)
    ap.add_argument("--address", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on stdout)")
    args = ap.parse_args(argv)

    directory = pathlib.Path(args.dir)
    genesis = None
    if not (directory / "store.json").exists():
        if not (args.capacity and args.dim):
            ap.error("--capacity and --dim are required for a fresh --dir")
        genesis = init_state(args.capacity, args.dim,
                             contract=get_contract(args.contract))
    host = ShardHost(directory, genesis,
                     segment_records=args.segment_records,
                     ef_construction=args.ef_construction)
    server = ShardServer(host, address=args.address, port=args.port)
    print(f"LISTENING {server.port}", flush=True)
    print(f"CURSOR {host.store.t}", flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
