"""Remote shard client: the ``DurableStore`` interface over the wire.

``RemoteShardClient`` speaks the protocol to one ``ShardHost`` and exposes
exactly the surface ``shard_wal.ShardedDurableStore`` drives on a local
shard — ``append_many`` / ``checkpoint`` / ``restore_at`` / ``recover`` /
``rollback_to`` / ``retain`` / ``t`` / ``wal.read_range`` — so the
coordinator cannot tell (and must not care) whether a shard is a directory
or a process. Error mapping preserves that symmetry: a server-side refusal
arrives as ``RemoteError`` (a ``ValueError``) and a lost message as
``TransportError`` (an ``OSError``), both inside the coordinator's
existing ``_RESTORE_ERRORS`` recovery envelope.

Transports are one method, ``request(bytes) -> bytes``:

  * ``SocketTransport`` — TCP, one in-flight request per client, one
    reconnect attempt on a dead connection (the request may have executed;
    the protocol's idempotent APPEND makes the retry safe);
  * ``LocalTransport`` — an in-process ``ShardHost`` behind the *full*
    codec round trip, so tests exercise every encode/decode branch without
    sockets (and fault-injection proxies can wrap it).

Request ids are a per-client monotone counter; the client refuses a
response whose id differs from its request's (a reordered or foreign
frame is a ``ProtocolError``, not an answer).
"""
from __future__ import annotations

import socket
from typing import Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

import jax.numpy as jnp

from repro.core import search, snapshot
from repro.core.commands import CommandLog, log_from_bytes, log_to_bytes
from repro.core.contracts import get_contract
from repro.net import protocol as p
from repro.net.server import ShardHost, read_frame


class SocketTransport:
    """One TCP connection to a ``ShardServer``; lazily connected, one
    reconnect attempt when the connection died between requests.

    ``timeout`` bounds EVERY socket operation — connect, send and each
    recv — and a deadline miss surfaces as ``TransportError``: a wedged
    (accepting but not answering) host looks exactly like a dead one to
    callers, instead of hanging the follower thread or ``sync_replicas``
    forever. The failure detector's lease math relies on this bound."""

    def __init__(self, address: str, port: int, *, timeout: float = 30.0):
        self.address = address
        self.port = port
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None

    def _connect(self) -> socket.socket:
        if self._sock is None:
            try:
                self._sock = socket.create_connection(
                    (self.address, self.port), timeout=self.timeout)
                # persistent per-operation deadline (explicit, even though
                # create_connection leaves its timeout on the socket): every
                # send/recv after this point is bounded by ``timeout``
                self._sock.settimeout(self.timeout)
            except OSError as e:
                raise p.TransportError(
                    f"cannot reach shard host {self.address}:{self.port}: "
                    f"{e}") from e
        return self._sock

    def request(self, data: bytes) -> bytes:
        fresh = self._sock is None
        sock = self._connect()
        try:
            sock.sendall(data)
            resp = read_frame(sock)
        except p.TransportError:
            self.close()
            if fresh:  # the reconnect already happened; give up
                raise
            # stale connection (server restarted): retry once on a fresh
            # one — idempotent requests make the possible re-execution safe
            return self.request(data)
        except OSError as e:
            # sendall deadline miss / reset: same lost-message semantics as
            # a torn read — map it into the retriable TransportError family
            self.close()
            if fresh:
                raise p.TransportError(
                    f"send to shard host {self.address}:{self.port} "
                    f"failed: {e}") from e
            return self.request(data)
        if resp is None:
            self.close()
            raise p.TransportError(
                f"shard host {self.address}:{self.port} closed the "
                "connection without a response")
        return resp

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None


class LocalTransport:
    """An in-process ``ShardHost`` reached through the full wire codec:
    requests are encoded, decoded, handled, and the response re-encoded —
    byte-for-byte what a socket would carry, minus the socket."""

    def __init__(self, host: ShardHost):
        self.host = host

    def request(self, data: bytes) -> bytes:
        msg, rid, end = p.decode_frame(data)
        if end != len(data):
            raise p.ProtocolError(
                f"trailing bytes after request frame ({len(data) - end})")
        return p.encode_frame(self.host.handle(msg), rid)

    def close(self) -> None:
        pass


class _RemoteWal:
    """The slice of ``WriteAheadLog`` the coordinator reads through a
    shard handle (audit log export, tail shipping) — served remotely."""

    def __init__(self, client: "RemoteShardClient"):
        self._client = client

    def read_range(self, t0: int, t1: int) -> CommandLog:
        ack = self._client._request(p.ReadRange(t0=t0, t1=t1), p.LogAck)
        return log_from_bytes(ack.log, self._client.contract)

    def tail(self, t0: int, max_commands: int = 0
             ) -> Tuple[CommandLog, int]:
        log, t_end, _ = self._client.tail(t0, max_commands=max_commands)
        return log, t_end

    @property
    def t(self) -> int:
        return self._client.refresh_t()


class RemoteShardClient:
    """One remote shard, drop-in for a local ``DurableStore`` in
    ``ShardedDurableStore(backends=[...])``. The cached cursor mirrors the
    server's durable cursor and is the APPEND precondition (``base_t``);
    a response lost in transit leaves it stale-low, which the server's
    duplicate detection turns into a safe re-ack on retry."""

    def __init__(self, transport, *, contract=None, epoch: int = 0):
        self.transport = transport
        self._rid = 0
        ack = self._request(p.Hello(epoch=epoch), p.HelloAck)
        self.dim = ack.dim
        self.itemsize = ack.itemsize
        self.contract = get_contract(ack.contract)
        if contract is not None and contract.name != self.contract.name:
            raise ValueError(
                f"shard host speaks contract {self.contract.name!r}, "
                f"coordinator expects {contract.name!r}")
        self._t = ack.t
        # fencing epoch (DESIGN.md §12): carried on every APPEND; the
        # handshake leaves both ends at the max epoch either had seen
        self.epoch = max(epoch, ack.epoch)
        self.wal = _RemoteWal(self)

    # ------------------------------------------------------------------ #

    def _request(self, msg: p.Message, expect_cls: Type[p.Message]
                 ) -> p.Message:
        self._rid += 1
        rid = self._rid
        data = self.transport.request(p.encode_frame(msg, rid))
        resp, resp_rid, end = p.decode_frame(data)
        if end != len(data):
            raise p.ProtocolError(
                f"trailing bytes after response frame ({len(data) - end})")
        if resp_rid != rid and not isinstance(resp, p.ErrorMsg):
            raise p.ProtocolError(
                f"response for request {resp_rid}, expected {rid} "
                "(reordered or foreign frame)")
        return p.expect(resp, expect_cls)

    # ------------------------------------------------------------------ #
    # the DurableStore surface
    # ------------------------------------------------------------------ #

    @property
    def t(self) -> int:
        """The shard's durable cursor as last confirmed over the wire."""
        return self._t

    def refresh_t(self) -> int:
        ack = self._request(p.Cursor(), p.CursorAck)
        self._t = ack.t
        return self._t

    def append(self, log: CommandLog) -> int:
        return self.append_many([log])

    def append_many(self, logs: Sequence[CommandLog]) -> int:
        logs = [log for log in logs if len(log)]
        if not logs:
            return self._t
        ack = self._request(
            p.Append(base_t=self._t, epoch=self.epoch,
                     logs=tuple(log_to_bytes(log) for log in logs)),
            p.AppendAck)
        self._t = ack.t
        return ack.t

    def bump_epoch(self, epoch: int) -> int:
        """Raise this writer's fencing epoch (monotone; a lower value is a
        no-op). The failover coordinator calls this after a promotion so
        the surviving write path speaks the new regime's epoch."""
        self.epoch = max(self.epoch, int(epoch))
        return self.epoch

    def heartbeat(self, *, node_id: int = 0) -> Tuple[int, int, int]:
        """One lease beat (DESIGN.md §12): proves the host alive within the
        transport timeout and stamps it with ``self.epoch`` (the host
        adopts a greater epoch durably). Returns the host's
        (durable cursor, durable epoch, applied state hash)."""
        ack = self._request(
            p.Heartbeat(node_id=node_id, epoch=self.epoch), p.HeartbeatAck)
        self.epoch = max(self.epoch, ack.epoch)
        return ack.t, ack.epoch, ack.state_hash

    def checkpoint(self, state) -> Dict[str, int]:
        """Checkpoint by hash, not by shipping state: the server snapshots
        its *own* applied state after proving it bit-matches the
        coordinator's slice — determinism makes the 64-bit check
        sufficient, and the state never crosses the wire."""
        from repro.core import hashing
        t = int(np.asarray(state.version).reshape(-1)[0])
        ack = self._request(
            p.Checkpoint(t=t, expect_hash=hashing.hash_pytree(state)),
            p.CheckpointAck)
        return {"t": ack.t, "bytes_written": ack.bytes_written}

    def restore_at(self, t: int, *, ef_construction: int = 32):
        ack = self._request(p.RestoreAt(t=t), p.StateAck)
        state, h = snapshot.restore_bytes(ack.blob)
        if h != ack.state_hash:
            raise p.ProtocolError(
                f"restored state hash {h:#x} != advertised "
                f"{ack.state_hash:#x} at t={t}")
        return state, h

    def recover(self, *, ef_construction: int = 32):
        ack = self._request(p.Recover(), p.StateAck)
        state, h = snapshot.restore_bytes(ack.blob)
        if h != ack.state_hash:
            raise p.ProtocolError(
                f"recovered state hash {h:#x} != advertised "
                f"{ack.state_hash:#x}")
        self._t = ack.t
        return state, h, ack.t

    def rollback_to(self, t: int) -> None:
        ack = self._request(p.Rollback(t=t), p.RollbackAck)
        self._t = ack.t

    def retain(self, keep: int) -> Dict[str, int]:
        ack = self._request(p.Retain(keep=keep), p.RetainAck)
        return {"snapshots_dropped": ack.snapshots_dropped,
                "wal_segments_dropped": ack.wal_segments_dropped,
                "chunks_dropped": ack.chunks_dropped,
                "oldest_snapshot": ack.oldest_snapshot}

    # ------------------------------------------------------------------ #
    # reads + replication
    # ------------------------------------------------------------------ #

    def query(self, queries_raw, k: int, plan) -> Tuple[np.ndarray,
                                                        np.ndarray]:
        """Run the coordinator's planned route on the shard's applied
        state; returns host (ids [nq, k], scores [nq, k]) int64 arrays."""
        q = np.asarray(queries_raw)
        nq, dim = q.shape
        data = q.astype(q.dtype.newbyteorder("<")).tobytes()
        # the coarse route rides the ef field (the route string
        # disambiguates), keeping the frozen Query frame format intact
        ef = plan.ef_coarse if plan.route == "coarse" else plan.ef
        ack = self._request(
            p.Query(k=k, ef=ef, route=plan.route,
                    use_kernel=plan.use_kernel, nq=nq, dim=dim,
                    itemsize=q.dtype.itemsize, data=data),
            p.QueryAck)
        ids = np.frombuffer(ack.ids, dtype="<i8").reshape(ack.nq, ack.k)
        scores = np.frombuffer(ack.scores, dtype="<i8").reshape(ack.nq,
                                                                ack.k)
        return ids, scores

    def state_hash(self) -> Tuple[int, int]:
        """(applied cursor, hash) of the shard's live state."""
        ack = self._request(p.StateHashReq(), p.StateHashAck)
        return ack.t, ack.state_hash

    def tail(self, from_t: int, *, max_commands: int = 0
             ) -> Tuple[CommandLog, int, int]:
        """Ship the durable commands [from_t, t_end) plus the primary's
        state hash AT t_end — the number a replica must reproduce before
        it may ack. Returns (log, t_end, state_hash)."""
        ack = self._request(
            p.Tail(from_t=from_t, max_commands=max_commands), p.TailAck)
        return log_from_bytes(ack.log, self.contract), ack.t_end, \
            ack.state_hash

    def side_tail(self, from_index: int) -> Tuple[List[bytes], int, int]:
        """Ship the primary's side-table records [from_index, count) plus
        the chained digest over the whole prefix — the verify target a
        mirroring replica must reproduce (DESIGN.md §9). Returns
        (records, count, table_digest)."""
        ack = self._request(p.SideTail(from_index=from_index), p.SideTailAck)
        return list(ack.records), ack.count, ack.table_digest

    def replica_ack(self, replica_id: int, t: int, state_hash: int) -> int:
        ack = self._request(
            p.ReplicaCursorAck(replica_id=replica_id, t=t,
                               state_hash=state_hash),
            p.ReplicaCursorAckAck)
        return ack.t

    def close(self) -> None:
        self.transport.close()


def remote_sharded_query(clients: Sequence[RemoteShardClient], queries_raw,
                         k: int, plan) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The planned route fanned out over remote shard hosts — the wire
    twin of ``query.sharded_host_query``: every shard executes the same
    plan on its applied state, candidates combine with the one
    order-invariant (score, id) merge, so the answer is bit-identical to
    the in-process sharded read on the same content. Returns
    (ids [nq, k], scores [nq, k])."""
    ids_parts, score_parts = [], []
    for c in clients:
        ids, scores = c.query(queries_raw, k, plan)
        ids_parts.append(jnp.asarray(ids, jnp.int64))
        score_parts.append(jnp.asarray(scores, jnp.int64))
    flat_ids = jnp.concatenate(ids_parts, axis=-1)
    flat_scores = jnp.concatenate(score_parts, axis=-1)
    s_out, i_out = search.merge_candidates(flat_scores, flat_ids, k)
    return i_out, s_out
