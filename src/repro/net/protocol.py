"""Deterministic wire protocol for shard serving + replication (DESIGN.md §8).

One frame layout for every message, little-endian throughout — the WAL
record discipline (docs/wal-format.md) applied to the network:

  offset  size  field
  0       4     magic  b"VWIR"
  4       4     u32 format = 2
  8       4     u32 msg_type
  12      8     u64 request_id   (echoed by the response; reordered or
                                  foreign responses are detected, not
                                  silently consumed)
  20      4     u32 payload length N
  24      N     payload          (canonical per-type encoding below)
  24+N    8     u64 digest = hashing.digest_bytes(frame[0:24+N])

The digest makes a torn, truncated or bit-flipped frame a *decode error*
(``ProtocolError``), never a silently different message — the property
tests/test_protocol.py pins byte-by-byte. Payload encodings are canonical
(field order fixed, strings as u32-len + utf8, arrays as raw little-endian
bytes), so encoding is deterministic: the same message always produces the
same bytes, and every message type is byte-frozen by a golden fixture
(scripts/gen_golden_wire.py).

Command logs travel as ``commands.log_to_bytes`` blobs; states travel as
v1 snapshot blobs (``snapshot.snapshot_bytes``), whose embedded state hash
is re-verified on restore — integrity is checked at the frame layer AND at
the content layer.

Transports are a one-method seam (``request(bytes) -> bytes``) so the
fault-injection suite can drop, duplicate, delay, reorder and corrupt
messages without sockets; ``TransportError`` is the "message lost" signal
retriable callers (the replica's catch-up loop, the group-commit writer's
pending buffer) recover from.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Tuple, Type

from repro.core import hashing

MAGIC = b"VWIR"
# format 2: HEARTBEAT/HEARTBEAT_ACK lease frames + the fencing epoch
# carried by HELLO / HELLO_ACK / APPEND (DESIGN.md §12). Any payload
# change is a format bump + a deliberate golden-fixture regeneration
# (scripts/gen_golden_wire.py) — never a silent reinterpretation.
WIRE_FORMAT = 2
HEADER_BYTES = 24
DIGEST_BYTES = 8

# message type ids (u32). Requests are odd-ish historical accidents are
# avoided: every type is explicit and golden-fixture-frozen.
HELLO = 1
HELLO_ACK = 2
CURSOR = 3
CURSOR_ACK = 4
APPEND = 5
APPEND_ACK = 6
QUERY = 7
QUERY_ACK = 8
CHECKPOINT = 9
CHECKPOINT_ACK = 10
RESTORE_AT = 11
STATE_ACK = 12
RECOVER = 13
ROLLBACK = 14
ROLLBACK_ACK = 15
TAIL = 16
TAIL_ACK = 17
REPLICA_ACK = 18
REPLICA_ACK_ACK = 19
STATE_HASH = 20
STATE_HASH_ACK = 21
READ_RANGE = 22
LOG_ACK = 23
RETAIN = 24
RETAIN_ACK = 25
SIDE_TAIL = 26
SIDE_TAIL_ACK = 27
HEARTBEAT = 28
HEARTBEAT_ACK = 29
ERROR = 255


class ProtocolError(ValueError):
    """A frame or payload failed to decode: torn, truncated, bit-flipped,
    wrong magic/format, trailing garbage, or a response whose request id
    does not match the request (reordered/foreign delivery)."""


class TransportError(OSError):
    """A message was lost in transit (connection refused/reset, timeout,
    injected drop). The request may or may not have reached the server —
    callers must treat delivery as at-least-once and rely on the
    protocol's idempotence (e.g. APPEND's base-cursor precondition)."""


class RemoteError(ValueError):
    """The server executed the request and refused it. ``kind`` carries the
    server-side exception class name; subclassing ValueError keeps the
    coordinator's transport-agnostic error handling (restore fallbacks,
    rollback refusals) working identically for local and remote shards."""

    def __init__(self, kind: str, message: str):
        super().__init__(f"{kind}: {message}")
        self.kind = kind
        self.remote_message = message


class StaleEpochError(ValueError):
    """A write carried an epoch below the host's durable epoch — the
    writer belongs to a fenced (pre-failover) regime. A revived old
    primary that was stamped with the fleet epoch refuses its old
    clients' APPENDs with this, so a split brain can never commit; the
    refusal crosses the wire as ``RemoteError(kind="StaleEpochError")``."""


# --------------------------------------------------------------------------- #
# strict little-endian payload reader/writer
# --------------------------------------------------------------------------- #


class _Writer:
    def __init__(self):
        self._parts = []

    def u8(self, v: int):
        self._parts.append(struct.pack("<B", v))

    def u32(self, v: int):
        self._parts.append(struct.pack("<I", v))

    def u64(self, v: int):
        self._parts.append(struct.pack("<Q", v & ((1 << 64) - 1)))

    def i64(self, v: int):
        self._parts.append(struct.pack("<q", v))

    def str_(self, s: str):
        b = s.encode()
        self.u32(len(b))
        self._parts.append(b)

    def bytes_(self, b: bytes):
        self.u32(len(b))
        self._parts.append(bytes(b))

    def bytes_list(self, bs):
        self.u32(len(bs))
        for b in bs:
            self.bytes_(b)

    def value(self) -> bytes:
        return b"".join(self._parts)


class _Reader:
    def __init__(self, data: bytes):
        self._d = data
        self._off = 0

    def _take(self, n: int) -> bytes:
        if self._off + n > len(self._d):
            raise ProtocolError(
                f"payload truncated: wanted {n} bytes at offset {self._off}, "
                f"payload is {len(self._d)} bytes")
        out = self._d[self._off:self._off + n]
        self._off += n
        return out

    def u8(self) -> int:
        return struct.unpack("<B", self._take(1))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self._take(4))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self._take(8))[0]

    def i64(self) -> int:
        return struct.unpack("<q", self._take(8))[0]

    def str_(self) -> str:
        n = self.u32()
        try:
            return self._take(n).decode()
        except UnicodeDecodeError as e:
            raise ProtocolError(f"invalid utf8 string: {e}") from e

    def bytes_(self) -> bytes:
        return self._take(self.u32())

    def bytes_list(self) -> Tuple[bytes, ...]:
        return tuple(self.bytes_() for _ in range(self.u32()))

    def done(self) -> None:
        if self._off != len(self._d):
            raise ProtocolError(
                f"trailing garbage: {len(self._d) - self._off} bytes past "
                "the end of the payload")


# --------------------------------------------------------------------------- #
# message dataclasses — canonical field order IS the wire order
# --------------------------------------------------------------------------- #
#
# FIELDS maps each dataclass field to its wire kind; encode/decode walk the
# spec in order, so adding a field is a format change (bump WIRE_FORMAT and
# regenerate the golden fixtures deliberately).

_FIELD_KINDS = ("u8", "u32", "u64", "i64", "str", "bytes", "bytes_list",
                "bool")


@dataclasses.dataclass(frozen=True)
class Message:
    # deliberately un-annotated: class metadata, not dataclass fields
    TYPE = -1
    FIELDS = ()

    def encode_payload(self) -> bytes:
        w = _Writer()
        for name, kind in self.FIELDS:
            v = getattr(self, name)
            if kind == "u8":
                w.u8(v)
            elif kind == "bool":
                w.u8(1 if v else 0)
            elif kind == "u32":
                w.u32(v)
            elif kind == "u64":
                w.u64(v)
            elif kind == "i64":
                w.i64(v)
            elif kind == "str":
                w.str_(v)
            elif kind == "bytes":
                w.bytes_(v)
            elif kind == "bytes_list":
                w.bytes_list(v)
            else:  # pragma: no cover — spec typo guard
                raise AssertionError(f"unknown field kind {kind}")
        return w.value()

    @classmethod
    def decode_payload(cls, payload: bytes) -> "Message":
        r = _Reader(payload)
        kwargs = {}
        for name, kind in cls.FIELDS:
            if kind == "u8":
                kwargs[name] = r.u8()
            elif kind == "bool":
                kwargs[name] = bool(r.u8())
            elif kind == "u32":
                kwargs[name] = r.u32()
            elif kind == "u64":
                kwargs[name] = r.u64()
            elif kind == "i64":
                kwargs[name] = r.i64()
            elif kind == "str":
                kwargs[name] = r.str_()
            elif kind == "bytes":
                kwargs[name] = r.bytes_()
            elif kind == "bytes_list":
                kwargs[name] = r.bytes_list()
            else:  # pragma: no cover
                raise AssertionError(f"unknown field kind {kind}")
        r.done()
        return cls(**kwargs)


@dataclasses.dataclass(frozen=True)
class Hello(Message):
    """Open a session: learn the shard's shape before trusting it.
    ``epoch`` is the client's fencing epoch (DESIGN.md §12) — the host
    adopts a greater one and advertises its own in the ack, so both ends
    leave the handshake agreeing on the newest regime either has seen."""
    TYPE = HELLO
    FIELDS = (("epoch", "u64"),)
    epoch: int = 0


@dataclasses.dataclass(frozen=True)
class HelloAck(Message):
    TYPE = HELLO_ACK
    FIELDS = (("dim", "u32"), ("itemsize", "u32"), ("contract", "str"),
              ("t", "u64"), ("state_hash", "u64"), ("epoch", "u64"))
    dim: int = 0
    itemsize: int = 0
    contract: str = ""
    t: int = 0
    state_hash: int = 0
    epoch: int = 0


@dataclasses.dataclass(frozen=True)
class Cursor(Message):
    """The shard's durable cursor (the fleet-lockstep probe)."""
    TYPE = CURSOR
    FIELDS = ()


@dataclasses.dataclass(frozen=True)
class CursorAck(Message):
    TYPE = CURSOR_ACK
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class Append(Message):
    """Group-commit this shard's share of one or more batches.

    ``base_t`` is the precondition cursor: the server applies only when its
    durable cursor equals it, and recognizes an exact re-delivery (same
    base, same bytes, cursor already advanced) as a duplicate to re-ack —
    exactly-once commit semantics over an at-least-once transport.
    ``epoch`` is the writer's fencing epoch: a host whose durable epoch is
    greater refuses the append with ``StaleEpochError`` — the fence that
    keeps a revived pre-failover primary's clients from committing."""
    TYPE = APPEND
    FIELDS = (("base_t", "u64"), ("epoch", "u64"), ("logs", "bytes_list"))
    base_t: int = 0
    epoch: int = 0
    logs: Tuple[bytes, ...] = ()


@dataclasses.dataclass(frozen=True)
class AppendAck(Message):
    TYPE = APPEND_ACK
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class Query(Message):
    """Run the planned route on the shard's applied state; the coordinator
    merges per-shard candidates with the order-invariant combine."""
    TYPE = QUERY
    FIELDS = (("k", "u32"), ("ef", "u32"), ("route", "str"),
              ("use_kernel", "bool"), ("nq", "u32"), ("dim", "u32"),
              ("itemsize", "u32"), ("data", "bytes"))
    k: int = 0
    ef: int = 0
    route: str = "exact"
    use_kernel: bool = False
    nq: int = 0
    dim: int = 0
    itemsize: int = 4
    data: bytes = b""


@dataclasses.dataclass(frozen=True)
class QueryAck(Message):
    TYPE = QUERY_ACK
    FIELDS = (("nq", "u32"), ("k", "u32"), ("ids", "bytes"),
              ("scores", "bytes"))
    nq: int = 0
    k: int = 0
    ids: bytes = b""     # [nq, k] int64 LE
    scores: bytes = b""  # [nq, k] int64 LE


@dataclasses.dataclass(frozen=True)
class Checkpoint(Message):
    """Snapshot the shard's applied state at cursor ``t`` — but only if its
    ``hash_pytree`` equals ``expect_hash``: the coordinator's slice and the
    server's applied state are bit-identical by the determinism contract,
    so a mismatch is divergence and must refuse, not snapshot."""
    TYPE = CHECKPOINT
    FIELDS = (("t", "u64"), ("expect_hash", "u64"))
    t: int = 0
    expect_hash: int = 0


@dataclasses.dataclass(frozen=True)
class CheckpointAck(Message):
    TYPE = CHECKPOINT_ACK
    FIELDS = (("t", "u64"), ("bytes_written", "u64"))
    t: int = 0
    bytes_written: int = 0


@dataclasses.dataclass(frozen=True)
class RestoreAt(Message):
    TYPE = RESTORE_AT
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class StateAck(Message):
    """A full shard state in flight: v1 snapshot blob (self-verifying — the
    embedded hash is re-checked on restore) + the cursor and hash."""
    TYPE = STATE_ACK
    FIELDS = (("t", "u64"), ("state_hash", "u64"), ("blob", "bytes"))
    t: int = 0
    state_hash: int = 0
    blob: bytes = b""


@dataclasses.dataclass(frozen=True)
class Recover(Message):
    TYPE = RECOVER
    FIELDS = ()


@dataclasses.dataclass(frozen=True)
class Rollback(Message):
    TYPE = ROLLBACK
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class RollbackAck(Message):
    TYPE = ROLLBACK_ACK
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class Tail(Message):
    """Log shipping: the commands [from_t, min(cursor, from_t + max)) plus
    the primary's state hash AT the returned end cursor — the hash the
    replica must reproduce before acking. ``max_commands=0`` = no bound."""
    TYPE = TAIL
    FIELDS = (("from_t", "u64"), ("max_commands", "u32"))
    from_t: int = 0
    max_commands: int = 0


@dataclasses.dataclass(frozen=True)
class TailAck(Message):
    TYPE = TAIL_ACK
    FIELDS = (("from_t", "u64"), ("t_end", "u64"), ("state_hash", "u64"),
              ("log", "bytes"))
    from_t: int = 0
    t_end: int = 0
    state_hash: int = 0
    log: bytes = b""  # commands.log_to_bytes of [from_t, t_end)


@dataclasses.dataclass(frozen=True)
class ReplicaCursorAck(Message):
    """A replica's verified-cursor ack. The primary refuses an ack whose
    hash contradicts its own state at that cursor — a divergent replica is
    an error at BOTH ends, never a bookkeeping entry."""
    TYPE = REPLICA_ACK
    FIELDS = (("replica_id", "u64"), ("t", "u64"), ("state_hash", "u64"))
    replica_id: int = 0
    t: int = 0
    state_hash: int = 0


@dataclasses.dataclass(frozen=True)
class ReplicaCursorAckAck(Message):
    TYPE = REPLICA_ACK_ACK
    FIELDS = (("t", "u64"),)
    t: int = 0


@dataclasses.dataclass(frozen=True)
class StateHashReq(Message):
    TYPE = STATE_HASH
    FIELDS = ()


@dataclasses.dataclass(frozen=True)
class StateHashAck(Message):
    TYPE = STATE_HASH_ACK
    FIELDS = (("t", "u64"), ("state_hash", "u64"))
    t: int = 0
    state_hash: int = 0


@dataclasses.dataclass(frozen=True)
class ReadRange(Message):
    TYPE = READ_RANGE
    FIELDS = (("t0", "u64"), ("t1", "u64"))
    t0: int = 0
    t1: int = 0


@dataclasses.dataclass(frozen=True)
class LogAck(Message):
    TYPE = LOG_ACK
    FIELDS = (("log", "bytes"),)
    log: bytes = b""


@dataclasses.dataclass(frozen=True)
class Retain(Message):
    TYPE = RETAIN
    FIELDS = (("keep", "u32"),)
    keep: int = 1


@dataclasses.dataclass(frozen=True)
class RetainAck(Message):
    TYPE = RETAIN_ACK
    FIELDS = (("snapshots_dropped", "u64"), ("wal_segments_dropped", "u64"),
              ("chunks_dropped", "u64"), ("oldest_snapshot", "u64"))
    snapshots_dropped: int = 0
    wal_segments_dropped: int = 0
    chunks_dropped: int = 0
    oldest_snapshot: int = 0


@dataclasses.dataclass(frozen=True)
class SideTail(Message):
    """Side-table shipping: pull the primary's ``SideTable`` records from
    record index ``from_index`` onward, so a replica mirrors doc token
    prefixes alongside the WAL slices it tails — a promoted replica then
    serves prefixes without refilling."""
    TYPE = SIDE_TAIL
    FIELDS = (("from_index", "u64"),)
    from_index: int = 0


@dataclasses.dataclass(frozen=True)
class SideTailAck(Message):
    """Raw self-validating side-table records [from_index, count) plus the
    primary's running digest over ALL record bytes up to ``count`` — the
    content-layer verify target, exactly like TAIL_ACK's ``state_hash``."""
    TYPE = SIDE_TAIL_ACK
    FIELDS = (("from_index", "u64"), ("count", "u64"),
              ("table_digest", "u64"), ("records", "bytes_list"))
    from_index: int = 0
    count: int = 0
    table_digest: int = 0
    records: Tuple[bytes, ...] = ()


@dataclasses.dataclass(frozen=True)
class Heartbeat(Message):
    """One lease beat from the failure detector (DESIGN.md §12): proves
    the host is alive AND stamps it with the detector's fleet epoch —
    the host adopts a greater epoch durably, which is what fences a
    revived old primary's writers. ``node_id`` identifies the detector
    (diagnostics only; liveness is per-connection)."""
    TYPE = HEARTBEAT
    FIELDS = (("node_id", "u64"), ("epoch", "u64"))
    node_id: int = 0
    epoch: int = 0


@dataclasses.dataclass(frozen=True)
class HeartbeatAck(Message):
    """The host's durable cursor, durable epoch and applied state hash —
    one beat doubles as a liveness proof and a divergence tripwire."""
    TYPE = HEARTBEAT_ACK
    FIELDS = (("t", "u64"), ("epoch", "u64"), ("state_hash", "u64"))
    t: int = 0
    epoch: int = 0
    state_hash: int = 0


@dataclasses.dataclass(frozen=True)
class ErrorMsg(Message):
    TYPE = ERROR
    FIELDS = (("kind", "str"), ("message", "str"))
    kind: str = "ValueError"
    message: str = ""


MESSAGE_TYPES: Dict[int, Type[Message]] = {
    cls.TYPE: cls for cls in (
        Hello, HelloAck, Cursor, CursorAck, Append, AppendAck, Query,
        QueryAck, Checkpoint, CheckpointAck, RestoreAt, StateAck, Recover,
        Rollback, RollbackAck, Tail, TailAck, ReplicaCursorAck,
        ReplicaCursorAckAck, StateHashReq, StateHashAck, ReadRange, LogAck,
        Retain, RetainAck, SideTail, SideTailAck, Heartbeat, HeartbeatAck,
        ErrorMsg)
}
assert len(MESSAGE_TYPES) == 30, "duplicate message type id"


# --------------------------------------------------------------------------- #
# frame encode / decode
# --------------------------------------------------------------------------- #


def encode_frame(msg: Message, request_id: int) -> bytes:
    payload = msg.encode_payload()
    head = (MAGIC + struct.pack("<II", WIRE_FORMAT, msg.TYPE)
            + struct.pack("<QI", request_id & ((1 << 64) - 1), len(payload)))
    body = head + payload
    return body + struct.pack("<Q", hashing.digest_bytes(body))


def frame_length(header: bytes) -> int:
    """Total frame size from the fixed 24-byte header (for stream reads).
    Validates magic and format up front so a desynced stream fails fast."""
    if len(header) < HEADER_BYTES:
        raise ProtocolError(
            f"short frame header: {len(header)} < {HEADER_BYTES} bytes")
    if header[:4] != MAGIC:
        raise ProtocolError("bad frame magic")
    (fmt,) = struct.unpack_from("<I", header, 4)
    if fmt != WIRE_FORMAT:
        raise ProtocolError(f"unsupported wire format {fmt}")
    (n,) = struct.unpack_from("<I", header, 20)
    return HEADER_BYTES + n + DIGEST_BYTES


def decode_frame(data: bytes, offset: int = 0) -> Tuple[Message, int, int]:
    """Decode one frame at ``offset``; returns (message, request_id,
    next_offset). Raises ProtocolError on anything short of a bit-perfect
    frame: truncation, digest mismatch, unknown type, payload garbage."""
    view = data[offset:offset + HEADER_BYTES]
    total = frame_length(view)  # validates magic/format, may raise
    if offset + total > len(data):
        raise ProtocolError(
            f"truncated frame: need {total} bytes, have {len(data) - offset}")
    body = data[offset:offset + total - DIGEST_BYTES]
    (stored,) = struct.unpack_from("<Q", data, offset + total - DIGEST_BYTES)
    if stored != hashing.digest_bytes(body):
        raise ProtocolError("frame digest mismatch (corrupt or torn frame)")
    (msg_type,) = struct.unpack_from("<I", data, offset + 8)
    (request_id, n) = struct.unpack_from("<QI", data, offset + 12)
    cls = MESSAGE_TYPES.get(msg_type)
    if cls is None:
        raise ProtocolError(f"unknown message type {msg_type}")
    payload = data[offset + HEADER_BYTES:offset + HEADER_BYTES + n]
    return cls.decode_payload(payload), request_id, offset + total


def raise_if_error(msg: Message) -> Message:
    """Turn a server ERROR frame into the client-side exception hierarchy."""
    if isinstance(msg, ErrorMsg):
        raise RemoteError(msg.kind, msg.message)
    return msg


def expect(msg: Message, cls: Type[Message]) -> Message:
    raise_if_error(msg)
    if not isinstance(msg, cls):
        raise ProtocolError(
            f"expected {cls.__name__}, got {type(msg).__name__}")
    return msg
