"""Log-shipping read replica with verify-then-ack (DESIGN.md §8).

A ``ReplicaStore`` follows one primary shard host by tailing its durable
command log through the wire protocol and replaying it locally — the
paper's core move (the log IS the memory) applied to read scaling. The
safety discipline is *verify, commit, ack*, in that order:

  1. TAIL ships the commands [cursor, t_end) together with the primary's
     ``hash_pytree`` at ``t_end``;
  2. the replica applies them to a **candidate** state and compares its
     own hash — a mismatch raises ``ReplicaDivergence`` and commits
     nothing (the replica's served state never silently diverges);
  3. only a verified candidate is committed (and, for a durable replica,
     appended to the replica's own WAL first), and only a committed
     cursor is acked back — so the primary's view of a replica's cursor
     is always a *proven* bit-identical state, and the primary re-checks
     the hash on ack anyway (both ends verify; neither trusts).

Deliveries may be dropped, duplicated, delayed or reordered by the
transport: TAIL is a pure read (re-asking is harmless), the local append
happens once per verified advance, and the ack is idempotent — so the
replica converges to the primary's exact state under any at-least-once
schedule, which is precisely what tests/test_replication.py's
fault-injection suite drives.

Two additions make replicas a first-class availability layer (§9):

  * **SideTable shipping** — a durable replica mirrors the primary's
    side table (doc token prefixes) record-by-record via SIDE_TAIL,
    verified against one chained prefix digest, so a *promoted* replica
    serves prefixes without refilling;
  * **promotion** — ``promote()`` turns a durable replica into a
    ``ShardHost`` without replaying its WAL: every record in that WAL was
    hash-verified against the old primary before it touched disk, so the
    takeover needs one lockstep + hash check, not a replay.

``LocalPrimary`` exposes the same replication surface over a
``DurableStore`` the caller already owns — how the serve engine attaches
in-process read replicas to its own durable stores without a server.

Replicas can also be **live followers** (DESIGN.md §12): under a
``FollowerPolicy``, ``start_following()`` runs ``catch_up`` on a daemon
thread — waking at least every ``max_delay_s`` and immediately when the
primary nudges it past ``max_lag_commands`` — so the read pool advances
between explicit barriers. The safety discipline is UNCHANGED: the
follower thread runs the same verify-then-ack path, rides transport
faults, and **stops** on ``ReplicaDivergence`` (recorded on
``follow_error``), never relaxing the hash check to go faster."""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import hashing, machine, query as query_lib
from repro.core.durability import DurableStore, SideTable
from repro.core.shard_wal import live_count
from repro.core.state import MemoryState
from repro.net import protocol as p


class ReplicaDivergence(ValueError):
    """The replica replayed the primary's own log and got a different
    state hash — replication is wrong (or the shipped log / advertised
    hash was tampered with), and serving must not continue from here."""


@dataclasses.dataclass(frozen=True)
class FollowerPolicy:
    """Bounded-staleness policy for a background follower (§12).

    ``max_lag_commands`` — the lag (in commands past the replica's proven
    cursor) the primary tolerates before nudging the follower awake
    immediately; 0 nudges on every flush. It also bounds each shipped
    TAIL slice, so one wake replays bounded work per round.
    ``max_delay_s`` — the follower wakes at least this often regardless
    of nudges, so staleness is bounded by wall clock even when nobody
    writes (the lease heartbeat of the read path)."""
    max_lag_commands: int = 0
    max_delay_s: float = 0.05


class LocalPrimary:
    """The replica-facing surface of a ``DurableStore`` the caller already
    owns: ``tail`` / ``replica_ack`` / ``side_tail`` with the exact
    semantics of a ``ShardHost`` behind a client, minus the codec. The
    serve engine uses this to attach in-process read replicas to its own
    store(s); ``state_fn`` (when given) returns the owner's live applied
    state so the common tail-to-the-live-cursor case hashes without a
    time-travel restore."""

    def __init__(self, store, *, state_fn=None,
                 side_table: Optional[SideTable] = None,
                 ef_construction: int = 32):
        self.store = store
        self._state_fn = state_fn
        self.side_table = side_table
        self.ef_construction = ef_construction
        self.replica_cursors: Dict[int, int] = {}
        # serialize tails/acks against the owner's concurrent appends: a
        # live follower thread reads the WAL while the engine extends it,
        # and the store's own mutation lock is the correct fence (falls
        # back to a private lock for store-likes without one)
        self._lock = getattr(store, "_lock", None) or threading.RLock()

    def _hash_at(self, t: int) -> int:
        if self._state_fn is not None:
            state = self._state_fn()
            if int(np.asarray(state.version).reshape(-1)[0]) == t:
                return hashing.hash_pytree(state)
        return self.store.restore_at(
            t, ef_construction=self.ef_construction)[1]

    def tail(self, from_t: int, *, max_commands: int = 0):
        with self._lock:
            if from_t > self.store.t:
                raise ValueError(
                    f"tail from t={from_t} is ahead of durable cursor "
                    f"{self.store.t}")
            log, t_end = self.store.wal.tail(from_t,
                                             max_commands=max_commands)
            return log, t_end, self._hash_at(t_end)

    def replica_ack(self, replica_id: int, t: int, state_hash: int) -> int:
        with self._lock:
            return self._replica_ack_locked(replica_id, t, state_hash)

    def _replica_ack_locked(self, replica_id: int, t: int,
                            state_hash: int) -> int:
        if t > self.store.t:
            raise ValueError(
                f"replica acked t={t} ahead of the primary's durable "
                f"cursor {self.store.t}")
        expect = self._hash_at(t)
        if state_hash != expect:
            raise ReplicaDivergence(
                f"replica {replica_id} diverged at t={t}: replica "
                f"{state_hash:#x}, primary {expect:#x}")
        prev = self.replica_cursors.get(replica_id, 0)
        self.replica_cursors[replica_id] = max(prev, t)
        return self.replica_cursors[replica_id]

    def side_tail(self, from_index: int):
        if self.side_table is None:
            return [], 0, 0
        count = self.side_table.record_count
        return (self.side_table.records_from(from_index), count,
                self.side_table.digest_at(count))

    def close(self) -> None:
        pass  # the store and side table belong to the caller


class ReplicaStore:
    """A read replica of one primary shard host.

    ``primary`` is anything with the client replication surface —
    ``tail(from_t, max_commands=...) -> (log, t_end, hash)`` and
    ``replica_ack(replica_id, t, hash) -> t`` (a ``RemoteShardClient``
    over any transport). With a ``directory`` the replica keeps its own
    ``DurableStore`` (genesis required on first boot) and survives a kill:
    restart recovery rebuilds the state from the local WAL and catch-up
    resumes from the durable cursor. Without one, it is a pure in-memory
    follower.

    ``prefetch``, when given, is a *second* independent client to the same
    primary; ``catch_up(pipeline=True)`` uses it to request slice t+1
    while slice t is still being applied — the catch-up latency lever
    (``bench_replication.py`` prices it)."""

    def __init__(self, primary, genesis: Optional[MemoryState] = None, *,
                 directory: Optional[str | os.PathLike] = None,
                 replica_id: int = 0, ef_construction: int = 32,
                 prefetch=None):
        self.primary = primary
        self.prefetch = prefetch
        self.replica_id = replica_id
        self.ef_construction = ef_construction
        self.store: Optional[DurableStore] = None
        self.side_table: Optional[SideTable] = None
        self._closed = False
        self._prefetch_thread: Optional[threading.Thread] = None
        # live-follower machinery (§12): one catch-up at a time, whether
        # driven by the background thread or an explicit sync_replicas();
        # the commit lock publishes (state, hash, t) atomically so a
        # concurrent reader never pairs a new state with an old cursor
        self._sync_lock = threading.Lock()
        self._commit_lock = threading.Lock()
        self.follow_policy: Optional[FollowerPolicy] = None
        self.follow_error: Optional[Exception] = None
        self._follow_thread: Optional[threading.Thread] = None
        self._follow_stop = threading.Event()
        self._follow_wake = threading.Event()
        if directory is not None:
            self.store = DurableStore(directory, genesis)
            self.state, self._hash, self.t = self.store.recover(
                ef_construction=ef_construction)
            # the mirror of the primary's side table (SIDE_TAIL target):
            # same filename the promoted host will serve it from
            self.side_table = SideTable(self.store.dir / "docs.sdt")
        else:
            if genesis is None:
                raise ValueError("an in-memory replica needs a genesis "
                                 "state (or give it a directory)")
            if int(genesis.version) != 0:
                raise ValueError("replica genesis must be at t=0")
            self.state = genesis
            self._hash = hashing.hash_pytree(genesis)
            self.t = 0

    # ------------------------------------------------------------------ #
    # following the primary
    # ------------------------------------------------------------------ #

    def sync(self, *, max_commands: int = 0) -> int:
        """One catch-up step: tail from the replica's cursor, verify, then
        commit + ack. Returns the new cursor (unchanged when the primary
        has nothing new). Raises ``ReplicaDivergence`` on a hash mismatch
        — nothing is committed in that case — and lets transport faults
        (``TransportError`` / ``ProtocolError``) propagate: the step is
        idempotent, so the caller just runs it again."""
        with self._sync_lock:
            log, t_end, advertised = self.primary.tail(
                self.t, max_commands=max_commands)
            return self._commit_slice(log, t_end, advertised)

    def _commit_slice(self, log, t_end: int, advertised: int) -> int:
        """Verify-commit-ack one shipped slice (the body of ``sync``,
        shared with the pipelined catch-up path)."""
        if t_end == self.t:
            # nothing new; still re-verify our own position against the
            # primary (a free divergence tripwire on idle syncs)
            if advertised != self._hash:
                raise ReplicaDivergence(
                    f"replica at t={self.t} has hash {self._hash:#x}, "
                    f"primary advertises {advertised:#x}")
            self._ack()
            self._sync_side()
            return self.t
        if len(log) != t_end - self.t:
            raise p.ProtocolError(
                f"tail shipped {len(log)} commands for "
                f"[{self.t}, {t_end})")
        candidate = machine.bulk_apply(
            self.state, log, ef_construction=self.ef_construction)
        h = hashing.hash_pytree(candidate)
        if h != advertised:
            raise ReplicaDivergence(
                f"replaying [{self.t}, {t_end}) produced {h:#x}, primary "
                f"advertises {advertised:#x}; refusing the cursor")
        # verified: make it durable first (a crash between append and the
        # state commit is repaired by recover() — the WAL is authoritative)
        if self.store is not None:
            self.store.append(log)
        with self._commit_lock:
            self.state = candidate
            self._hash = h
            self.t = t_end
        self._ack()
        self._sync_side()
        return self.t

    def _ack(self) -> None:
        self.primary.replica_ack(self.replica_id, self.t, self._hash)

    def _sync_side(self) -> None:
        """Mirror side-table records shipped alongside the WAL slice —
        only when both ends have a table (idempotent, so a transport
        fault here just defers the mirror to the next sync)."""
        if self.side_table is not None and hasattr(self.primary,
                                                   "side_tail"):
            self.sync_side_table()

    def sync_side_table(self) -> int:
        """Pull the primary's side-table records past our mirror's count
        and verify the *whole prefix* against the primary's one chained
        digest before committing a byte — the TAIL_ACK discipline applied
        to the serving cache. Returns the mirrored record count."""
        if self.side_table is None:
            raise ValueError("an in-memory replica has no side table "
                             "(give the replica a directory)")
        start = self.side_table.record_count
        records, count, advertised = self.primary.side_tail(start)
        if count == 0 and start == 0:
            return 0  # primary ships no side table
        if count < start:
            raise ReplicaDivergence(
                f"primary's side table has {count} records, mirror already "
                f"holds {start} — the mirror is not a prefix of the source")
        if len(records) != count - start:
            raise p.ProtocolError(
                f"side tail shipped {len(records)} records for "
                f"[{start}, {count})")
        # dry-run the chained digest from our prefix before any append:
        # a mismatch must commit nothing
        digest = self.side_table.digest_at(start)
        for raw in records:
            digest = hashing.digest_bytes(struct.pack("<Q", digest) + raw)
        if digest != advertised:
            raise ReplicaDivergence(
                f"side-table prefix digest {digest:#x} != primary's "
                f"{advertised:#x}; refusing the mirrored records")
        for raw in records:
            self.side_table.append_record(raw)
        self.side_table.sync()
        return count

    def catch_up(self, *, max_commands: int = 0, max_rounds: int = 64,
                 pipeline: bool = False) -> int:
        """Run ``sync`` until the replica reaches the primary's cursor,
        riding through transport faults (lost/reordered messages) but
        never through divergence. Returns the **residual lag**: 0 means
        the replica *proved* it reached the primary's cursor (a
        fault-free round shipped nothing new); a positive value is the
        best-known number of commands still ahead of us when the round
        budget ran out — a hot primary outran this catch-up, and the
        caller can tell "caught up" from "gave up".

        With ``pipeline=True`` (requires the ``prefetch`` client), the
        next TAIL is requested on the second connection *while the current
        slice is applying* — the network/codec latency of slice t+1 hides
        behind the bulk_apply of slice t. Verification is unchanged: every
        slice is still hash-checked before commit, whichever connection
        shipped it."""
        if pipeline and self.prefetch is None:
            raise ValueError("pipelined catch-up needs a prefetch client "
                             "(a second connection to the same primary)")
        with self._sync_lock:
            return self._catch_up_locked(max_commands, max_rounds, pipeline)

    def _catch_up_locked(self, max_commands: int, max_rounds: int,
                         pipeline: bool) -> int:
        pending: Optional[Tuple[threading.Thread, dict, int]] = None
        last_t_end = self.t
        for _ in range(max_rounds):
            t_before = self.t
            try:
                if pending is not None:
                    thread, box, from_t = pending
                    thread.join()
                    pending = None
                    if "result" in box and from_t == self.t:
                        log, t_end, advertised = box["result"]
                    else:
                        # prefetch faulted or raced a cursor change:
                        # fall back to a direct (idempotent) tail
                        log, t_end, advertised = self.primary.tail(
                            self.t, max_commands=max_commands)
                else:
                    log, t_end, advertised = self.primary.tail(
                        self.t, max_commands=max_commands)
            except (p.TransportError, p.ProtocolError):
                continue  # the step is idempotent: just ask again
            last_t_end = max(last_t_end, t_end)
            if pipeline and t_end > self.t:
                pending = self._start_prefetch(t_end, max_commands)
            try:
                self._commit_slice(log, t_end, advertised)
            except (p.TransportError, p.ProtocolError):
                continue
            if self.t == t_before:
                # a fault-free round shipped nothing past our cursor:
                # t_end == t proves the primary's cursor == ours
                return 0
        return self._residual_lag(last_t_end)

    def _residual_lag(self, last_t_end: int) -> int:
        """Best-known commands still ahead of the replica when catch-up
        gives up: the primary's cursor when it is probeable, else the
        newest shipped ``t_end`` (a lower bound — a bounded TAIL never
        advertises the full cursor). Never 0: reaching the cursor exits
        through the proven fault-free path above, so a give-up is always
        reported as real lag."""
        try:
            refresh = getattr(self.primary, "refresh_t", None)
            if refresh is not None:
                return max(1, refresh() - self.t)
            store = getattr(self.primary, "store", None)
            if store is not None:
                return max(1, store.t - self.t)
        except (p.TransportError, p.ProtocolError):
            pass
        return max(1, last_t_end - self.t)

    def _start_prefetch(self, from_t: int, max_commands: int
                        ) -> Tuple[threading.Thread, dict, int]:
        box: dict = {}

        def run():
            try:
                box["result"] = self.prefetch.tail(
                    from_t, max_commands=max_commands)
            except Exception as e:  # noqa: BLE001 — surfaced via the box
                box["error"] = e

        thread = threading.Thread(target=run, daemon=True)
        thread.start()
        self._prefetch_thread = thread
        return thread, box, from_t

    # ------------------------------------------------------------------ #
    # live following: the background tailer (DESIGN.md §12)
    # ------------------------------------------------------------------ #

    @property
    def following(self) -> bool:
        """True while the background follower thread is alive."""
        thread = self._follow_thread
        return thread is not None and thread.is_alive()

    def start_following(self, policy: Optional[FollowerPolicy] = None
                        ) -> None:
        """Start the background tailer: a daemon thread loops ``catch_up``
        under ``policy``, waking at least every ``max_delay_s`` and
        immediately on ``notify_writes()``. Same verify-then-ack path as
        an explicit sync — every cursor the follower commits is proven —
        and the thread rides transport faults but STOPS on divergence
        (``follow_error`` records why; a diverged follower must not keep
        serving reads as if it were healthy). Idempotent while a follower
        is already running."""
        if self._closed:
            raise ValueError("cannot follow on a closed replica")
        if self.following:
            return
        self.follow_policy = policy or FollowerPolicy()
        self.follow_error = None
        self._follow_stop.clear()
        self._follow_wake.set()  # first round runs immediately
        self._follow_thread = threading.Thread(
            target=self._follow_loop, daemon=True,
            name=f"replica-{self.replica_id}-follower")
        self._follow_thread.start()

    def notify_writes(self) -> None:
        """Nudge the follower awake (the primary's flush hook): the next
        catch-up round starts now instead of at the ``max_delay_s`` tick.
        Safe to call from any thread; a no-op without a follower."""
        self._follow_wake.set()

    def stop_following(self, *, timeout: float = 10.0) -> None:
        """Stop the background tailer and join it (idempotent). The
        replica stays valid — explicit ``catch_up`` still works, and
        ``start_following`` may be called again."""
        self._follow_stop.set()
        self._follow_wake.set()
        thread = self._follow_thread
        if thread is not None and thread.is_alive():
            thread.join(timeout=timeout)
        self._follow_thread = None

    def _follow_loop(self) -> None:
        policy = self.follow_policy
        while not self._follow_stop.is_set():
            self._follow_wake.wait(timeout=policy.max_delay_s)
            self._follow_wake.clear()
            if self._follow_stop.is_set():
                return
            try:
                self.catch_up(max_commands=policy.max_lag_commands)
            except (p.TransportError, p.ProtocolError):
                continue  # transient: the next tick retries idempotently
            except Exception as e:  # noqa: BLE001 — recorded, never silent
                if self._follow_stop.is_set():
                    return  # teardown race: the primary is going away
                # divergence (or any non-transient refusal): stop serving
                # the illusion of a healthy follower — record and halt;
                # the hash check is never relaxed and never retried past
                # a proven mismatch
                self.follow_error = e
                return

    def checkpoint(self) -> None:
        """Snapshot the replica's own verified state (durable replicas
        only) — bounds restart catch-up to the WAL tail past the newest
        snapshot."""
        if self.store is None:
            raise ValueError("in-memory replica has nothing to checkpoint")
        self.store.checkpoint(self.state)

    # ------------------------------------------------------------------ #
    # failover: promotion
    # ------------------------------------------------------------------ #

    def promote(self, *, epoch: Optional[int] = None):
        """Turn this durable replica into the new primary (DESIGN.md §9).

        The replica's WAL is already a *verified prefix*: every slice in
        it was applied to a candidate, hash-compared against the old
        primary, and only then appended — so promotion needs one lockstep
        + hash check, not a replay. Returns a ``ShardHost`` that adopts
        the replica's store, applied state and side-table mirror; the
        replica hands its handles over and must not be synced afterwards.

        Refuses with ``ReplicaDivergence`` when the in-memory state no
        longer matches the proven hash (bit rot / tampering); a WAL/state
        cursor skew (the crash window between append and commit) is first
        reconciled through ``recover()`` — the durable log stays
        authoritative."""
        if self.store is None:
            raise ValueError("only a durable replica can be promoted "
                             "(an in-memory follower has no WAL to adopt)")
        self.stop_following()  # the old primary is gone; stop tailing it
        if self.store.t != self.t:
            # crash window: the WAL holds a verified slice the in-memory
            # state never committed — recover() lands on the durable prefix
            self.state, self._hash, self.t = self.store.recover(
                ef_construction=self.ef_construction)
        if hashing.hash_pytree(self.state) != self._hash:
            raise ReplicaDivergence(
                f"replica {self.replica_id} state no longer matches its "
                f"proven hash at t={self.t}; refusing promotion")
        from repro.net.server import ShardHost  # local import: no cycle
        side = self.side_table
        if side is not None:
            side.close()  # the promoted host reopens the mirror file
            self.side_table = None
        return ShardHost.adopt(self.store, self.state, self._hash,
                               ef_construction=self.ef_construction,
                               epoch=epoch)

    # ------------------------------------------------------------------ #
    # serving reads
    # ------------------------------------------------------------------ #

    def state_hash(self) -> int:
        """Hash of the replica's verified applied state — equal to the
        primary's at the same cursor, by construction (that equality is
        the ack precondition)."""
        return self._hash

    def snapshot(self) -> Tuple[MemoryState, int, int]:
        """A consistent (state, state_hash, t) triple under the commit
        lock — what a reader racing a live follower must use: commits
        publish the triple atomically, so the pair a read serves from is
        always a *proven* (state, cursor), never a torn mix of two."""
        with self._commit_lock:
            return self.state, self._hash, self.t

    def retrieve(self, queries_raw, k: int, *, ef: int = 64,
                 use_kernel: bool = False, route: str = "auto"
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Planned read on the replica's state: same planner, same routes,
        same bits as the primary at the same cursor — the read-scaling
        path. Returns host (ids [nq, k], scores [nq, k])."""
        plan = query_lib.plan_query(live_count(self.state), k, ef,
                                    use_kernel=use_kernel, route=route)
        ids, scores = query_lib.execute_plan(self.state, queries_raw, k,
                                             plan)
        return np.asarray(ids), np.asarray(scores)

    def retrieval_hash(self, queries_raw, k: int, **kw) -> int:
        ids, scores = self.retrieve(queries_raw, k, **kw)
        return query_lib.retrieval_hash(ids, scores)

    def close(self) -> None:
        """Idempotent teardown: join any in-flight prefetch, close both
        transports and the side-table mirror. Benches and kill tests close
        replicas repeatedly — a double close is a no-op."""
        if self._closed:
            return
        self._closed = True
        self.stop_following()
        thread = self._prefetch_thread
        if thread is not None and thread.is_alive():
            thread.join(timeout=5.0)
        self._prefetch_thread = None
        for handle in (self.primary, self.prefetch):
            close = getattr(handle, "close", None)
            if close is not None:
                close()
        if self.side_table is not None:
            self.side_table.close()
