"""Log-shipping read replica with verify-then-ack (DESIGN.md §8).

A ``ReplicaStore`` follows one primary shard host by tailing its durable
command log through the wire protocol and replaying it locally — the
paper's core move (the log IS the memory) applied to read scaling. The
safety discipline is *verify, commit, ack*, in that order:

  1. TAIL ships the commands [cursor, t_end) together with the primary's
     ``hash_pytree`` at ``t_end``;
  2. the replica applies them to a **candidate** state and compares its
     own hash — a mismatch raises ``ReplicaDivergence`` and commits
     nothing (the replica's served state never silently diverges);
  3. only a verified candidate is committed (and, for a durable replica,
     appended to the replica's own WAL first), and only a committed
     cursor is acked back — so the primary's view of a replica's cursor
     is always a *proven* bit-identical state, and the primary re-checks
     the hash on ack anyway (both ends verify; neither trusts).

Deliveries may be dropped, duplicated, delayed or reordered by the
transport: TAIL is a pure read (re-asking is harmless), the local append
happens once per verified advance, and the ack is idempotent — so the
replica converges to the primary's exact state under any at-least-once
schedule, which is precisely what tests/test_replication.py's
fault-injection suite drives."""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np

from repro.core import hashing, machine, query as query_lib
from repro.core.durability import DurableStore
from repro.core.shard_wal import live_count
from repro.core.state import MemoryState
from repro.net import protocol as p


class ReplicaDivergence(ValueError):
    """The replica replayed the primary's own log and got a different
    state hash — replication is wrong (or the shipped log / advertised
    hash was tampered with), and serving must not continue from here."""


class ReplicaStore:
    """A read replica of one primary shard host.

    ``primary`` is anything with the client replication surface —
    ``tail(from_t, max_commands=...) -> (log, t_end, hash)`` and
    ``replica_ack(replica_id, t, hash) -> t`` (a ``RemoteShardClient``
    over any transport). With a ``directory`` the replica keeps its own
    ``DurableStore`` (genesis required on first boot) and survives a kill:
    restart recovery rebuilds the state from the local WAL and catch-up
    resumes from the durable cursor. Without one, it is a pure in-memory
    follower."""

    def __init__(self, primary, genesis: Optional[MemoryState] = None, *,
                 directory: Optional[str | os.PathLike] = None,
                 replica_id: int = 0, ef_construction: int = 32):
        self.primary = primary
        self.replica_id = replica_id
        self.ef_construction = ef_construction
        self.store: Optional[DurableStore] = None
        if directory is not None:
            self.store = DurableStore(directory, genesis)
            self.state, self._hash, self.t = self.store.recover(
                ef_construction=ef_construction)
        else:
            if genesis is None:
                raise ValueError("an in-memory replica needs a genesis "
                                 "state (or give it a directory)")
            if int(genesis.version) != 0:
                raise ValueError("replica genesis must be at t=0")
            self.state = genesis
            self._hash = hashing.hash_pytree(genesis)
            self.t = 0

    # ------------------------------------------------------------------ #
    # following the primary
    # ------------------------------------------------------------------ #

    def sync(self, *, max_commands: int = 0) -> int:
        """One catch-up step: tail from the replica's cursor, verify, then
        commit + ack. Returns the new cursor (unchanged when the primary
        has nothing new). Raises ``ReplicaDivergence`` on a hash mismatch
        — nothing is committed in that case — and lets transport faults
        (``TransportError`` / ``ProtocolError``) propagate: the step is
        idempotent, so the caller just runs it again."""
        log, t_end, advertised = self.primary.tail(
            self.t, max_commands=max_commands)
        if t_end == self.t:
            # nothing new; still re-verify our own position against the
            # primary (a free divergence tripwire on idle syncs)
            if advertised != self._hash:
                raise ReplicaDivergence(
                    f"replica at t={self.t} has hash {self._hash:#x}, "
                    f"primary advertises {advertised:#x}")
            self._ack()
            return self.t
        if len(log) != t_end - self.t:
            raise p.ProtocolError(
                f"tail shipped {len(log)} commands for "
                f"[{self.t}, {t_end})")
        candidate = machine.bulk_apply(
            self.state, log, ef_construction=self.ef_construction)
        h = hashing.hash_pytree(candidate)
        if h != advertised:
            raise ReplicaDivergence(
                f"replaying [{self.t}, {t_end}) produced {h:#x}, primary "
                f"advertises {advertised:#x}; refusing the cursor")
        # verified: make it durable first (a crash between append and the
        # state commit is repaired by recover() — the WAL is authoritative)
        if self.store is not None:
            self.store.append(log)
        self.state = candidate
        self._hash = h
        self.t = t_end
        self._ack()
        return self.t

    def _ack(self) -> None:
        self.primary.replica_ack(self.replica_id, self.t, self._hash)

    def catch_up(self, *, max_commands: int = 0, max_rounds: int = 64
                 ) -> int:
        """Run ``sync`` until the replica reaches the primary's cursor,
        riding through transport faults (lost/reordered messages) but
        never through divergence. Returns the final cursor."""
        for _ in range(max_rounds):
            t_before = self.t
            try:
                self.sync(max_commands=max_commands)
            except (p.TransportError, p.ProtocolError):
                continue  # the step is idempotent: just ask again
            if self.t == t_before:
                return self.t  # a fault-free round with no progress: caught up
        return self.t

    def checkpoint(self) -> None:
        """Snapshot the replica's own verified state (durable replicas
        only) — bounds restart catch-up to the WAL tail past the newest
        snapshot."""
        if self.store is None:
            raise ValueError("in-memory replica has nothing to checkpoint")
        self.store.checkpoint(self.state)

    # ------------------------------------------------------------------ #
    # serving reads
    # ------------------------------------------------------------------ #

    def state_hash(self) -> int:
        """Hash of the replica's verified applied state — equal to the
        primary's at the same cursor, by construction (that equality is
        the ack precondition)."""
        return self._hash

    def retrieve(self, queries_raw, k: int, *, ef: int = 64,
                 use_kernel: bool = False, route: str = "auto"
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Planned read on the replica's state: same planner, same routes,
        same bits as the primary at the same cursor — the read-scaling
        path. Returns host (ids [nq, k], scores [nq, k])."""
        plan = query_lib.plan_query(live_count(self.state), k, ef,
                                    use_kernel=use_kernel, route=route)
        ids, scores = query_lib.execute_plan(self.state, queries_raw, k,
                                             plan)
        return np.asarray(ids), np.asarray(scores)

    def retrieval_hash(self, queries_raw, k: int, **kw) -> int:
        ids, scores = self.retrieve(queries_raw, k, **kw)
        return query_lib.retrieval_hash(ids, scores)

    def close(self) -> None:
        close = getattr(self.primary, "close", None)
        if close is not None:
            close()
