"""Networked shard serving + log-shipping replication (DESIGN.md §8).

The deterministic substrate's network story: a small length-prefixed wire
protocol whose every frame carries a digest (``protocol``), a per-process
shard host wrapping one ``DurableStore`` plus its applied state
(``server``), a client implementing the same interface
``ShardedDurableStore`` drives locally (``client``), and a WAL-tailing
read replica whose every acked cursor is a verified ``state_hash`` match
against the primary (``replica``). Determinism is what makes the network
boundary *checkable*: a remote shard or replica is correct iff one 64-bit
hash agrees — the same one-line contract the local conformance suite pins.

Exports resolve lazily so ``python -m repro.net.server`` (the shard-host
entry point) does not import the package's own submodule twice.
"""
_EXPORTS = {
    "ProtocolError": "repro.net.protocol",
    "RemoteError": "repro.net.protocol",
    "TransportError": "repro.net.protocol",
    "LocalTransport": "repro.net.client",
    "RemoteShardClient": "repro.net.client",
    "SocketTransport": "repro.net.client",
    "remote_sharded_query": "repro.net.client",
    "ReplicaDivergence": "repro.net.replica",
    "ReplicaStore": "repro.net.replica",
    "ShardHost": "repro.net.server",
    "ShardServer": "repro.net.server",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.net' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(module), name)
