"""repro: a deterministic-memory JAX framework reproducing the Valori paper.

x64 note: the Valori substrate is built on exact integer arithmetic with
64-bit accumulators (paper §5.1). JAX disables 64-bit types by default, which
would silently truncate our accumulators to int32 and break the overflow-
freedom argument — so we enable x64 here, before any array is created.
All model/training code keeps explicit dtypes (bf16/f32/int32) so the wider
defaults never leak into compute graphs.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
