"""Three-term roofline extraction from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = wire_bytes / (chips × link_bw)

HLO FLOPs/bytes come from compiled.cost_analysis(). Collective bytes are NOT
in cost_analysis — we parse the post-SPMD HLO (compiled.as_text()) and sum
per-device wire bytes for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, using ring-cost factors:

  all-reduce      2·B·(n-1)/n      (B = full result bytes)
  all-gather      B·(n-1)/n
  reduce-scatter  B·(n-1)/n        (B = full operand bytes = result·n)
  all-to-all      B·(n-1)/n
  collective-permute  B

Hardware constants (TPU v5e): 197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s ICI
per link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches `f32[128,1024]{1,0}` or `s32[64]` shape atoms
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_RG_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_RG_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _group_size(line: str) -> int:
    m = _RG_V2_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _RG_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    op: str
    count: int = 0
    result_bytes: int = 0
    wire_bytes: float = 0.0


def parse_collectives(hlo_text: str) -> Dict[str, CollectiveStats]:
    """Per-op totals of result bytes and estimated per-device wire bytes."""
    stats: Dict[str, CollectiveStats] = {
        op: CollectiveStats(op) for op in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1].strip()
        op = None
        for cand in _COLLECTIVES:
            # matches `all-reduce(` and async `all-reduce-start(`;
            # `-done(` carries no data and does not match
            if re.search(rf"\b{cand}(-start)?\(", rhs):
                op = cand
                break
        if op is None:
            continue
        # result shapes live between '=' and the op name
        head = rhs.split(op)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if nbytes == 0:
            continue
        n = _group_size(rhs)
        frac = (n - 1) / n if n > 1 else 0.0
        if op == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif op == "all-gather":
            wire = nbytes * frac
        elif op == "reduce-scatter":
            wire = nbytes * n * frac
        elif op == "all-to-all":
            wire = nbytes * frac
        else:  # collective-permute
            wire = float(nbytes)
        st = stats[op]
        st.count += 1
        st.result_bytes += nbytes
        st.wire_bytes += wire
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    chips: int
    collectives: Dict[str, dict]
    dot_flops: float = 0.0
    hbm_bytes_min: float = 0.0  # fused-boundary lower bound (TPU-realistic)

    @property
    def compute_s(self) -> float:
        # flops are already per-chip (SPMD-partitioned module)
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        """TPU-realistic memory term: the fused-boundary bound when present.

        The CPU backend barely fuses, so raw op-by-op bytes overestimate TPU
        HBM traffic severalfold; hbm_bytes keeps the upper bound for
        reference."""
        return (self.hbm_bytes_min or self.hbm_bytes) / HBM_BW

    @property
    def memory_upper_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        # wire bytes are already per-device estimates
        return self.wire_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_min": self.hbm_bytes_min,
            "memory_upper_s": self.memory_upper_s,
            "wire_bytes_per_device": self.wire_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "collectives": self.collectives,
        }


def analyze(compiled, chips: int) -> Roofline:
    """All terms are per-device: the compiled module is the SPMD-partitioned
    per-device program (verified empirically: an N-way sharded matmul's
    cost_analysis reports flops/N).

    FLOPs/bytes/collectives come from the while-aware HLO walker
    (hlo_walk.py) because XLA's own cost_analysis counts loop bodies once —
    fatally undercounting scan-over-layers models. The walker matches
    cost_analysis exactly on loop-free modules (tests/test_roofline.py).
    """
    from repro.roofline.hlo_walk import walk_hlo

    tally = walk_hlo(compiled.as_text())
    collectives = {
        op: {
            "count": tally.collective_counts.get(op, 0),
            "wire_bytes": tally.collective_wire.get(op, 0.0),
        }
        for op in set(tally.collective_counts) | set(tally.collective_wire)
    }
    return Roofline(
        flops=tally.flops,
        hbm_bytes=tally.bytes,
        hbm_bytes_min=tally.bytes_min,
        wire_bytes=tally.wire_bytes,
        chips=chips,
        collectives=collectives,
        dot_flops=tally.dot_flops,
    )
