"""While-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified empirically), which silently zeroes out nearly all FLOPs
in scan-over-layers models. This walker parses the post-optimization HLO text
and accumulates

  * dot/convolution FLOPs (2 × result elems × contraction size, operand
    shapes resolved through a module-wide symbol table),
  * elementwise-ish FLOPs (1 × result elems for a known op list),
  * memory traffic at fusion/op boundaries (operands + results, matching
    HloCostAnalysis semantics),
  * collective wire bytes (ring-cost model),

multiplying everything inside a while body by the loop's trip count. Trip
counts are recovered from the loop condition: lax.scan/fori lower to a
counted loop whose condition compares the induction variable against a
constant. All numbers are per-device (the SPMD-partitioned module).

Validated in tests/test_roofline.py against cost_analysis on loop-free
modules and against hand counts on scanned ones.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "compare", "select",
    "and", "or", "xor", "not", "clamp", "atan2", "remainder",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "cosine", "sine", "tan", "erf", "is-finite", "convert",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "custom-call",
         "copy-start", "copy-done", "send", "recv", "send-done", "recv-done",
         "domain", "opt-barrier"}

_SHAPE_ATOM = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*([a-z][\w\-]*)\((.*)$"
)
_NAME_REF = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_ATOM.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Tally:
    flops: float = 0.0
    bytes: float = 0.0        # unfused upper bound (CPU-backend HLO op-by-op)
    bytes_min: float = 0.0    # fused-boundary lower bound: only dot/conv/
                              # scatter-gather/collective/loop-state traffic —
                              # approximates what a TPU fusion pass leaves
    wire_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    # (opcode, result_type, wire_bytes_total, executions) — for perf triage
    instances: List[tuple] = dataclasses.field(default_factory=list)

    def add(self, other: "Tally", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        self.wire_bytes += other.wire_bytes * mult
        self.dot_flops += other.dot_flops * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult
        for k, v in other.collective_wire.items():
            self.collective_wire[k] = self.collective_wire.get(k, 0.0) + v * mult
        for (op, t, w, n) in other.instances:
            self.instances.append((op, t, w * mult, n * mult))

    def top_collectives(self, n: int = 12) -> List[tuple]:
        agg: Dict[tuple, List[float]] = {}
        for (op, t, w, cnt) in self.instances:
            key = (op, t)
            cur = agg.setdefault(key, [0.0, 0.0])
            cur[0] += w
            cur[1] += cnt
        rows = [(op, t, w, cnt) for (op, t), (w, cnt) in agg.items()]
        rows.sort(key=lambda r: -r[2])
        return rows[:n]

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes,
            "bytes_min": self.bytes_min,
            "wire_bytes": self.wire_bytes, "dot_flops": self.dot_flops,
            "collective_counts": self.collective_counts,
            "collective_wire": self.collective_wire,
        }


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.symbols: Dict[str, str] = {}  # op name -> result type string
        self._cache: Dict[Tuple[str, bool], Tally] = {}
        self._parse(text)

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            s = raw.strip()
            if not s or s.startswith("//") or s.startswith("HloModule"):
                continue
            if s.endswith("{") and "=" not in s.split("(")[0]:
                header = s[len("ENTRY"):].strip() if s.startswith("ENTRY") else s
                name = header.split("(")[0].strip().lstrip("%").rstrip()
                current = name
                self.computations[name] = []
                # parameters carry types in the header
                params = re.findall(r"([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])",
                                    header)
                for pname, ptype in params:
                    self.symbols[pname] = ptype
                continue
            if s.startswith("}"):
                current = None
                continue
            if current is None or "=" not in s:
                continue
            m = _OP_LINE.match(s)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            op = Op(name, rtype, opcode, rest)
            self.computations[current].append(op)
            self.symbols[name] = rtype
        self.entry = next(iter(self.computations)) if self.computations else ""
        for name in self.computations:
            if name.startswith("main"):
                self.entry = name

    # ------------------------------------------------------------------ #
    def _operands(self, op: Op) -> List[str]:
        """Operand names (within the first paren group)."""
        depth = 1
        out = []
        buf = []
        for ch in op.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        inner = "".join(buf)
        return _NAME_REF.findall(inner)

    def _operand_bytes(self, op: Op) -> int:
        total = 0
        for name in self._operands(op):
            t = self.symbols.get(name)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def trip_count(self, cond_name: str) -> int:
        best = 1
        for op in self.computations.get(cond_name, []):
            if op.opcode == "constant":
                m = re.search(r"^\s*\(?(\d+)\)?", op.rest)
                if m:
                    best = max(best, int(m.group(1)))
            m = re.search(r"constant\((\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # ------------------------------------------------------------------ #
    def _dot_flops(self, op: Op) -> float:
        result_elems, _ = _shape_elems_bytes(op.result_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        ops = self._operands(op)
        lhs_type = self.symbols.get(ops[0]) if ops else None
        if not m or not lhs_type:
            return 2.0 * result_elems
        atom = _SHAPE_ATOM.search(lhs_type)
        if not atom:
            return 2.0 * result_elems
        dims = atom.group(2)
        lhs_shape = [int(d) for d in dims.split(",")] if dims else []
        contract = 1
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_shape):
                contract *= lhs_shape[int(idx)]
        return 2.0 * result_elems * contract

    def _conv_flops(self, op: Op) -> float:
        result_elems, _ = _shape_elems_bytes(op.result_type)
        m = re.search(r"window=\{[^}]*size=([\dx]+)", op.rest)
        k = 1
        if m:
            for d in m.group(1).split("x"):
                k *= int(d)
        return 2.0 * result_elems * k

    def _collective(self, op: Op, tally: Tally) -> None:
        base = op.opcode
        if base.endswith("-start"):
            base = base[:-6]
        _, nbytes = _shape_elems_bytes(op.result_type)
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
        if m:
            n = max(int(m.group(2)), 1)
        else:
            m1 = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
            n = len(m1.group(1).split(",")) if m1 else 2
        frac = (n - 1) / n if n > 1 else 0.0
        if base == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif base == "reduce-scatter":
            wire = nbytes * n * frac
        elif base == "collective-permute":
            wire = float(nbytes)
        else:
            wire = nbytes * frac
        tally.collective_counts[base] = tally.collective_counts.get(base, 0) + 1
        tally.collective_wire[base] = tally.collective_wire.get(base, 0.0) + wire
        tally.wire_bytes += wire
        tally.bytes += nbytes
        tally.bytes_min += nbytes
        tally.instances.append((base, op.result_type.strip(), wire, 1.0))

    # ------------------------------------------------------------------ #
    def walk(self, comp_name: Optional[str] = None, flops_only: bool = False
             ) -> Tally:
        comp_name = comp_name or self.entry
        key = (comp_name, flops_only)
        if key in self._cache:
            return self._cache[key]
        tally = Tally()
        for op in self.computations.get(comp_name, []):
            oc = op.opcode
            if oc in _SKIP or oc.endswith("-done"):
                continue
            if oc == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = self.trip_count(cm.group(1)) if cm else 1
                if bm:
                    tally.add(self.walk(bm.group(1), flops_only), mult=trips)
                continue
            if oc in ("call", "async-start"):
                cm = re.search(r"to_apply=%?([\w.\-]+)", op.rest) or \
                    re.search(r"calls=%?([\w.\-]+)", op.rest)
                if cm:
                    tally.add(self.walk(cm.group(1), flops_only))
                continue
            if oc == "conditional":
                names = re.findall(r"branch_computations=\{([^}]*)\}", op.rest)
                branch_names = []
                if names:
                    branch_names = [b.strip().lstrip("%")
                                    for b in names[0].split(",")]
                else:
                    branch_names = re.findall(
                        r"(?:true|false)_computation=%?([\w.\-]+)", op.rest)
                if branch_names:
                    subs = [self.walk(n, flops_only) for n in branch_names]
                    tally.add(max(subs, key=lambda t: t.flops + t.bytes))
                continue
            if oc == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if cm:
                    sub = self.walk(cm.group(1), flops_only=True)
                    tally.flops += sub.flops
                    tally.dot_flops += sub.dot_flops
                if not flops_only:
                    _, rbytes = _shape_elems_bytes(op.result_type)
                    b = rbytes + self._operand_bytes(op)
                    tally.bytes += b
                    tally.bytes_min += b  # fusion boundaries are real traffic
                continue
            if any(oc == c or oc == c + "-start" for c in _COLLECTIVES):
                self._collective(op, tally)
                continue
            if oc == "dot":
                f = self._dot_flops(op)
                tally.flops += f
                tally.dot_flops += f
                if not flops_only:
                    _, rbytes = _shape_elems_bytes(op.result_type)
                    b = rbytes + self._operand_bytes(op)
                    tally.bytes += b
                    tally.bytes_min += b
                continue
            if oc == "convolution":
                tally.flops += self._conv_flops(op)
                if not flops_only:
                    _, rbytes = _shape_elems_bytes(op.result_type)
                    b = rbytes + self._operand_bytes(op)
                    tally.bytes += b
                    tally.bytes_min += b
                continue
            relems, rbytes = _shape_elems_bytes(op.result_type)
            if not flops_only:
                tally.bytes += rbytes + self._operand_bytes(op)
                if oc in ("scatter", "gather", "dynamic-slice",
                          "dynamic-update-slice", "sort", "reduce",
                          "transpose", "reshape", "concatenate", "pad",
                          "slice", "iota", "broadcast", "copy"):
                    # data-movement ops a fusion pass cannot elide entirely
                    if oc in ("scatter", "gather", "sort", "concatenate"):
                        tally.bytes_min += rbytes + self._operand_bytes(op)
            if oc in _ELEMENTWISE:
                tally.flops += relems
            elif oc in ("reduce", "reduce-window"):
                # ~1 flop per *input* element
                in_elems = 0
                for name in self._operands(op):
                    t = self.symbols.get(name)
                    if t:
                        in_elems += _shape_elems_bytes(t)[0]
                tally.flops += in_elems
            elif oc == "sort":
                n = max(relems, 2)
                tally.flops += n * math.log2(n)
        self._cache[key] = tally
        return tally


def walk_hlo(text: str) -> Tally:
    return HloModule(text).walk()
