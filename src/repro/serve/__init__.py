from repro.serve.engine import MemoryAugmentedEngine, ServeConfig  # noqa: F401
