"""Memory-augmented batched serving engine — the paper's deployment story.

The engine glues the LM stack to the Valori substrate exactly along the
paper's §5.3 boundary:

  embed (float, nondeterministic) ──boundary.normalize──▶ MemoryState
  query (float)                  ──boundary.admit_query──▶ deterministic k-NN

Request lifecycle:
  1. WRITE path: a document's pooled hidden state (mean of final-layer
     states) crosses the boundary and is INSERTed through the command log —
     the audit trail IS the memory (replayable, snapshot-able, hashable).
  2. READ path: a prompt is embedded the same way; deterministic k-NN
     returns neighbor ids; their stored token prefixes are prepended as
     retrieved context (classic RAG conditioning).
  3. GENERATE: batched prefill + greedy decode with the KV cache.

Everything after the boundary is bit-deterministic: the same request log
replayed on any host produces the same memory hash AND the same retrieval
sets, which is the property the paper's §8.1 snapshot-transfer test checks.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary, commands, machine, query, snapshot
from repro.core import wal as wal_lib
from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract
from repro.core.durability import DurableStore
from repro.core.state import MemoryState, init_state
from repro.models import transformer as tf
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    capacity: int = 4096
    retrieve_k: int = 4
    max_new_tokens: int = 32
    s_cache: int = 512
    contract: PrecisionContract = DEFAULT_CONTRACT
    context_tokens: int = 32     # tokens of each retrieved doc to prepend
    # read-path planning (DESIGN.md §4): the planner picks exact-scan vs
    # HNSW per request from static facts; "auto" applies the planner rules,
    # "exact"/"hnsw" force a route
    route: str = "auto"
    ef: int = 64                 # HNSW beam width when that route is taken
    exact_threshold: int = 1024  # live count at/below which exact scan wins
    use_kernel: bool = False     # Pallas qgemm/qtopk on the exact route
    # durability (DESIGN.md §5): with a durable_dir, every ingested command
    # is WAL-appended before it is visible, incremental v2 snapshots are cut
    # every checkpoint_every commands (0 = manual only), and recover()
    # rebuilds the last durable prefix after a crash
    durable_dir: Optional[str] = None
    checkpoint_every: int = 0    # commands between background checkpoints
    retain_snapshots: int = 0    # keep newest N (snapshot, WAL) pairs; 0=all
    # high-QPS ingest (DESIGN.md §6): with a group-commit policy, ingested
    # batches buffer in a GroupCommitWriter and fsync once per group instead
    # of once per append; the read path flushes pending commands first (the
    # sync-on-read barrier), so retrieval never observes un-durable state.
    # A compaction policy schedules dead-ratio-driven WAL compaction.
    group_commit: Optional[wal_lib.GroupCommitPolicy] = None
    compaction: Optional[wal_lib.CompactionPolicy] = None


class MemoryAugmentedEngine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.memory: MemoryState = init_state(
            serve_cfg.capacity, cfg.d_model, contract=serve_cfg.contract
        )
        self.log = commands.empty_log(cfg.d_model, serve_cfg.contract)
        self.docs: Dict[int, np.ndarray] = {}   # id -> token prefix
        self._next_id = 0
        self.last_plan: Optional[query.QueryPlan] = None

        self.durable: Optional[DurableStore] = None
        self._group: Optional[wal_lib.GroupCommitWriter] = None
        self._ckpt_thread: Optional[threading.Thread] = None
        self._ckpt_error: Optional[BaseException] = None
        self._last_ckpt_t = 0
        if serve_cfg.durable_dir is not None:
            self.durable = DurableStore(serve_cfg.durable_dir, self.memory,
                                        compaction=serve_cfg.compaction)
            if serve_cfg.group_commit is not None:
                self._group = wal_lib.GroupCommitWriter(
                    self.durable, serve_cfg.group_commit)
        elif (serve_cfg.group_commit is not None
              or serve_cfg.compaction is not None):
            # refuse the inconsistent config loudly: an operator who set a
            # durability policy believes ingest is durable — silently
            # running non-durable would be the worst possible reading
            raise ValueError(
                "group_commit/compaction policies need durable_dir set")

        self._embed_fn = jax.jit(self._embed_batch)
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(p, b, cfg, self.sc.s_cache))
        self._decode = jax.jit(
            lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg))

    # ------------------------------------------------------------------ #
    # embedding: pooled final hidden states (pre-head)
    # ------------------------------------------------------------------ #

    def _embed_batch(self, params, tokens: jax.Array) -> jax.Array:
        batch = {"tokens": tokens}
        h = tf._embed(params, batch, self.cfg)
        B, L = h.shape[:2]
        positions = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None], (B, L))
        angles = tf._angles_for(batch, positions, self.cfg)
        h, _, _ = tf._run_stack(params, h, positions, self.cfg, "train",
                                None, angles)
        return jnp.mean(h.astype(jnp.float32), axis=1)  # [B, D]

    # ------------------------------------------------------------------ #
    # WRITE path
    # ------------------------------------------------------------------ #

    def insert_documents(self, token_batches: np.ndarray) -> List[int]:
        """token_batches [N, L] int32 → ids. Batched through the boundary.

        The WRITE path goes through ``machine.bulk_apply`` — hash-identical
        to scanning the log one command at a time (the audit check in
        ``replay_log_fresh`` re-derives the same state via ``replay``), but
        ingesting the whole batch in vectorized form."""
        emb = self._embed_fn(self.params, jnp.asarray(token_batches))
        raw = boundary.normalize_embedding(emb, self.sc.contract)
        ids = np.arange(self._next_id, self._next_id + len(token_batches),
                        dtype=np.int64)
        self._next_id += len(token_batches)
        batch_log = commands.insert_batch(jnp.asarray(ids), raw,
                                          self.sc.contract)
        if self._group is not None:
            # group commit: the batch buffers toward one fsync per group —
            # it is NOT yet durable, so it also must not be readable; the
            # read path's flush() barrier restores WAL-first ordering at
            # the moment of first observation (DESIGN.md §6)
            self._group.submit(batch_log)
        elif self.durable is not None:
            # WAL-first: the commands are durable before their effects are
            # visible, so a crash can lose at most un-acked work
            self.durable.append(batch_log)
        self.log = self.log.concat(batch_log)
        self.memory = machine.bulk_apply(self.memory, batch_log)
        for i, tid in enumerate(ids):
            self.docs[int(tid)] = np.asarray(token_batches[i])
        self._maybe_checkpoint()
        return [int(i) for i in ids]

    # ------------------------------------------------------------------ #
    # READ path
    # ------------------------------------------------------------------ #

    def retrieve(self, prompt_tokens: np.ndarray, k: Optional[int] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """[B, L] prompts → (ids [B, k], scores [B, k]) — deterministic.

        The whole batch runs under one jit on the route the query planner
        picks from static facts (live count, k, ef) — bit-identical to the
        per-query reference loop either way (DESIGN.md §4). The decision is
        recorded on ``self.last_plan`` for audit."""
        k = k or self.sc.retrieve_k
        self.flush()  # sync-on-read: nothing un-durable is observable
        emb = self._embed_fn(self.params, jnp.asarray(prompt_tokens))
        q_raw = boundary.admit_query(emb, self.sc.contract)
        plan = query.plan_query(
            int(self.memory.count), k, self.sc.ef,
            use_kernel=self.sc.use_kernel,
            exact_threshold=self.sc.exact_threshold, route=self.sc.route)
        self.last_plan = plan
        ids, scores = query.execute_plan(self.memory, q_raw, k, plan)
        return np.asarray(ids), np.asarray(scores)

    def retrieval_hash(self, prompt_tokens: np.ndarray,
                       k: Optional[int] = None) -> int:
        """Platform-invariant hash of the retrieval set for these prompts —
        the read-path audit artifact (paper §8.1 applied to queries)."""
        ids, scores = self.retrieve(prompt_tokens, k)
        return query.retrieval_hash(ids, scores)

    # ------------------------------------------------------------------ #
    # GENERATE
    # ------------------------------------------------------------------ #

    def generate(self, prompt_tokens: np.ndarray, *, augment: bool = True
                 ) -> np.ndarray:
        """Greedy decode a batch of prompts, optionally memory-augmented.
        Returns [B, max_new_tokens] int32."""
        B, L = prompt_tokens.shape
        if augment and self.memory.count > 0:
            ids, _ = self.retrieve(prompt_tokens)
            ctx = np.zeros((B, self.sc.context_tokens), np.int32)
            for b in range(B):
                best = int(ids[b, 0])
                if best >= 0:
                    doc = self.docs.get(best)
                    if doc is not None:
                        n = min(len(doc), self.sc.context_tokens)
                        ctx[b, -n:] = doc[:n]
            prompt_tokens = np.concatenate([ctx, prompt_tokens], axis=1)
            L = prompt_tokens.shape[1]

        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompt_tokens)})
        out = np.zeros((B, self.sc.max_new_tokens), np.int32)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for t in range(self.sc.max_new_tokens):
            out[:, t] = np.asarray(tok)[:, 0]
            pos = jnp.full((B, 1), L + t, jnp.int32)
            logits, caches = self._decode(self.params, caches, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return out

    # ------------------------------------------------------------------ #
    # durability: background checkpoints + crash recovery (DESIGN.md §5)
    # ------------------------------------------------------------------ #

    def flush(self) -> int:
        """Force any pending group-commit batch durable; returns the
        durable WAL cursor (== memory cursor afterwards). The read path
        calls this before serving — the sync-on-read barrier that keeps
        retrieval from ever observing un-durable commands — and it is the
        ack point for upstream callers under group commit."""
        if self._group is not None:
            return self._group.flush()
        return self.durable.t if self.durable is not None else \
            int(self.memory.version)

    def wait_durable(self) -> None:
        """Join any in-flight background checkpoint; re-raise its error —
        same no-silent-loss contract as checkpoint.CheckpointManager."""
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None
        if self._ckpt_error is not None:
            err, self._ckpt_error = self._ckpt_error, None
            raise RuntimeError("background checkpoint failed") from err

    def checkpoint(self) -> Dict[str, int]:
        """Synchronously cut an incremental v2 snapshot at the current
        cursor; returns the snapshot stats (dirty chunks written, etc.)."""
        if self.durable is None:
            raise RuntimeError("no durable_dir configured")
        self.flush()  # a snapshot may only cover durable commands
        self.wait_durable()
        stats = self.durable.checkpoint(
            jax.tree.map(np.asarray, self.memory))
        self._last_ckpt_t = int(self.memory.version)
        if self.sc.retain_snapshots > 0:
            stats.update(self.durable.retain(self.sc.retain_snapshots))
        return stats

    def _maybe_checkpoint(self) -> None:
        if (self.durable is None or self.sc.checkpoint_every <= 0
                or int(self.memory.version) - self._last_ckpt_t
                < self.sc.checkpoint_every):
            return
        self.flush()  # a snapshot may only cover durable commands
        self.wait_durable()  # one in flight at a time; surfaces past errors
        host_state = jax.tree.map(np.asarray, self.memory)
        self._last_ckpt_t = int(host_state.version)

        def work():
            try:
                self.durable.checkpoint(host_state)
                if self.sc.retain_snapshots > 0:
                    self.durable.retain(self.sc.retain_snapshots)
            except BaseException as e:  # noqa: BLE001 — re-raised on wait
                self._ckpt_error = e

        self._ckpt_thread = threading.Thread(target=work, daemon=True)
        self._ckpt_thread.start()

    def recover(self) -> Tuple[int, int]:
        """Rebuild memory from the durable store after a crash: nearest
        snapshot + WAL tail, bit-identical to replaying the durable prefix.
        Returns (t, hash). Retrieval serves immediately; ``docs`` token
        prefixes are serving-cache only and refill as documents re-insert
        (the deterministic substrate never depended on them)."""
        if self.durable is None:
            raise RuntimeError("no durable_dir configured")
        self.flush()  # a live engine recovering: don't drop acked-to-us work
        self.wait_durable()
        state, h, t = self.durable.recover()
        self.memory = state
        self._last_ckpt_t = int(state.version)
        try:  # audit trail, if retention kept the full history
            self.log = self.durable.wal.read_range(0, t)
        except ValueError:
            self.log = commands.empty_log(self.cfg.d_model, self.sc.contract)
        ids = np.asarray(state.ids)
        live = ids[np.asarray(state.valid)]
        self._next_id = int(live.max()) + 1 if live.size else 0
        return t, h

    # ------------------------------------------------------------------ #
    # audit / replay (paper §8.1, §9)
    # ------------------------------------------------------------------ #

    def memory_hash(self) -> int:
        from repro.core import hashing
        return hashing.hash_pytree(self.memory)

    def snapshot_bytes(self) -> bytes:
        return snapshot.snapshot_bytes(self.memory)

    def replay_log_fresh(self) -> int:
        """Re-apply the full command log to S_0; returns the hash — must
        equal memory_hash() (the paper's replayability guarantee)."""
        from repro.core import hashing
        fresh = init_state(self.sc.capacity, self.cfg.d_model,
                           contract=self.sc.contract)
        fresh = machine.replay(fresh, self.log)
        return hashing.hash_pytree(fresh)
