"""Memory-augmented batched serving engine — the paper's deployment story.

The engine glues the LM stack to the Valori substrate exactly along the
paper's §5.3 boundary:

  embed (float, nondeterministic) ──boundary.normalize──▶ MemoryState
  query (float)                  ──boundary.admit_query──▶ deterministic k-NN

Request lifecycle:
  1. WRITE path: a document's pooled hidden state (mean of final-layer
     states) crosses the boundary and is INSERTed through the command log —
     the audit trail IS the memory (replayable, snapshot-able, hashable).
  2. READ path: a prompt is embedded the same way; deterministic k-NN
     returns neighbor ids; their stored token prefixes are prepended as
     retrieved context (classic RAG conditioning).
  3. GENERATE: batched prefill + greedy decode with the KV cache.

Everything after the boundary is bit-deterministic: the same request log
replayed on any host produces the same memory hash AND the same retrieval
sets, which is the property the paper's §8.1 snapshot-transfer test checks.

Two serving modes share this one class (DESIGN.md §7):

* ``ServeConfig(shards=1)`` — the single-host engine: flat MemoryState,
  ``DurableStore`` durability, planner-routed batched reads.
* ``ServeConfig(shards=N)`` — the sharded engine: shard-major sharded-layout
  MemoryState (mesh-free, ``distributed.init_sharded_host``), ingest routed
  and NOP-padded into lockstep per-shard application
  (``shard_wal.bulk_apply_sharded``), durability through a
  ``ShardedDurableStore`` (per-shard WALs + snapshots under one global
  cursor), reads fanned out per shard and merged with the one
  order-invariant (score, id) combine (``query.sharded_host_query``).
* ``ServeConfig(shards=N, hosts=[...])`` — the networked engine
  (DESIGN.md §8): the same sharded-layout machinery, but durability and
  retrieval fan out to per-process shard hosts over the deterministic wire
  protocol (``net/``); the engine's local sharded state stays as the audit
  twin, so every remote append, checkpoint and answer is checkable against
  it by hash.

The cross-mode conformance contract (tests/test_conformance.py): both modes
fed the same documents allocate the same ids, append the same command log,
and report one ``memory_hash()`` (the layout-invariant live-content hash)
and one ``retrieval_hash()`` — including after kill + ``recover()``.
``state_hash()`` stays the within-layout ``hash_pytree`` artifact that the
durable stores' snapshots and merged records verify.
"""
from __future__ import annotations

import dataclasses
import pathlib
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary, commands, distributed, hnsw, machine, \
    query, shard_wal, snapshot
from repro.core import wal as wal_lib
from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract
from repro.core.durability import DurableStore, SideTable
from repro.core.shard_wal import ShardedDurableStore
from repro.core.state import MemoryState, init_state
from repro.models import transformer as tf
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    capacity: int = 4096
    retrieve_k: int = 4
    max_new_tokens: int = 32
    s_cache: int = 512
    contract: PrecisionContract = DEFAULT_CONTRACT
    context_tokens: int = 32     # tokens of each retrieved doc to prepend
    # serving topology (DESIGN.md §7): shards=1 is the single-host engine;
    # shards=N runs the whole path — ingest, durability, retrieval — on a
    # shard-major sharded-layout state with per-shard WALs. ``capacity`` is
    # the TOTAL arena (split evenly across shards; a single shard filling up
    # rejects its inserts exactly like a full flat arena would).
    shards: int = 1
    # networked topology (DESIGN.md §8): "host:port" shard servers
    # (``python -m repro.net.server``), one per shard. Ingest routing,
    # grouped append, planned retrieval fan-in, checkpoint, recover and
    # rollback then run over the wire through ``net.RemoteShardClient``s,
    # while the engine keeps its local sharded-layout state as the audit
    # twin — every remote answer is checkable against it by hash. Requires
    # ``durable_dir`` (the coordinator's own metadata directory); when
    # ``shards`` is left at 1 it is inferred as ``len(hosts)``.
    hosts: Optional[List[str]] = None
    # read-path planning (DESIGN.md §4, §10): the planner picks exact-scan
    # vs HNSW vs the compressed coarse tier per request from static facts;
    # "auto" applies the planner rules, "exact"/"hnsw"/"coarse" force a
    # route
    route: str = "auto"
    ef: int = 64                 # HNSW beam width when that route is taken
    # compressed tier (DESIGN.md §10): candidate-set size for the coarse
    # route. 0 disables the tier; > 0 lets "auto" route through the int8
    # coarse scan + exact re-rank, and makes the engine maintain the code
    # tables incrementally on ingest (table == codes.build(state) always)
    ef_coarse: int = 0
    exact_threshold: int = 1024  # live count at/below which exact scan wins
    use_kernel: bool = False     # Pallas kernels on the exact/coarse routes
    # durability (DESIGN.md §5): with a durable_dir, every ingested command
    # is WAL-appended before it is visible, incremental v2 snapshots are cut
    # every checkpoint_every commands (0 = manual only), and recover()
    # rebuilds the last durable prefix after a crash
    durable_dir: Optional[str] = None
    checkpoint_every: int = 0    # commands between background checkpoints
    retain_snapshots: int = 0    # keep newest N (snapshot, WAL) pairs; 0=all
    # high-QPS ingest (DESIGN.md §6): with a group-commit policy, ingested
    # batches buffer in a GroupCommitWriter and fsync once per group instead
    # of once per append; the read path flushes pending commands first (the
    # sync-on-read barrier), so retrieval never observes un-durable state,
    # and policy.timer_flush additionally bounds un-durability by wall clock.
    # A compaction policy schedules dead-ratio-driven WAL compaction.
    group_commit: Optional[wal_lib.GroupCommitPolicy] = None
    compaction: Optional[wal_lib.CompactionPolicy] = None
    # graph maintenance under churn (DESIGN.md §11): a RelinkPolicy
    # schedules the deterministic HNSW re-link pass the way ``compaction``
    # schedules WAL compaction — dead-ratio-driven from layout-invariant
    # facts (global commands ingested, effective deletes, live count), so
    # flat and sharded engines fed the same batches re-link at the same
    # batch boundaries. None = manual only (``relink_now()``).
    relink: Optional[hnsw.RelinkPolicy] = None
    # read scaling (DESIGN.md §9): replicas=k attaches k verified
    # log-shipping read replicas per shard (net.ReplicaStore followers of
    # the engine's own durable store(s), or of the shard hosts in
    # networked mode). ``retrieve()`` routes each request to a replica
    # chosen deterministically from the query bytes — but only when that
    # replica's acked cursor has reached the engine's flush cursor
    # (read-your-writes through the same sync-on-read barrier); otherwise
    # it falls back to the primary. The route lands on ``last_plan`` as
    # ``served_by``. Replicas advance via ``sync_replicas()`` (an
    # operator/cron concern, like checkpoints). Requires durable_dir —
    # a replica follows a WAL, and without one there is nothing to tail.
    replicas: int = 0
    # live followers (DESIGN.md §12): with a FollowerPolicy
    # (net.replica.FollowerPolicy), every attached replica runs a
    # background tailer — catch-up loops on a daemon thread, nudged by
    # ``flush()`` whenever the pool lags past ``max_lag_commands`` and
    # ticking at least every ``max_delay_s`` — so the read pool absorbs
    # traffic between barriers with NO manual sync_replicas(). Admission
    # is unchanged: a replica serves only at/past the flush cursor, so
    # liveness changes and correctness doesn't. Requires replicas > 0.
    follow: Optional[Any] = None


class MemoryAugmentedEngine:
    def __init__(self, cfg: ModelConfig, params: Any, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        n = serve_cfg.shards
        if n < 1:
            raise ValueError(f"shards must be >= 1, got {n}")
        if serve_cfg.hosts is not None:
            if n == 1:
                n = len(serve_cfg.hosts)
            elif n != len(serve_cfg.hosts):
                raise ValueError(
                    f"shards={n} but {len(serve_cfg.hosts)} hosts given")
            if serve_cfg.durable_dir is None:
                raise ValueError(
                    "networked serving (hosts=[...]) needs durable_dir: the "
                    "coordinator keeps its merged-hash records there")
        if serve_cfg.capacity % n:
            raise ValueError(
                f"capacity {serve_cfg.capacity} must divide evenly across "
                f"{n} shards")
        self.n_shards = n
        # the layout switch: networked serving uses the sharded-layout
        # machinery even at one shard (its durable twin is a fleet of one)
        self._layout_sharded = (n > 1) or (serve_cfg.hosts is not None)
        if not self._layout_sharded:
            self.memory: MemoryState = init_state(
                serve_cfg.capacity, cfg.d_model, contract=serve_cfg.contract)
        else:
            self.memory = distributed.init_sharded_host(
                n, serve_cfg.capacity // n, cfg.d_model,
                contract=serve_cfg.contract)
        # the audit trail: the global command log, plus — in sharded mode —
        # its routed per-shard twin (what the per-shard WALs hold). After a
        # sharded recover() only the per-shard logs are reconstructible
        # (the global interleaving across shards is not durable by design).
        self.log = commands.empty_log(cfg.d_model, serve_cfg.contract)
        self._shard_logs: List[commands.CommandLog] = [
            commands.empty_log(cfg.d_model, serve_cfg.contract)
            for _ in range(n)]
        self.docs: Dict[int, np.ndarray] = {}   # id -> token prefix
        self._next_id = 0
        self.last_plan: Optional[query.QueryPlan] = None
        # churn audit (DESIGN.md §11): cursors at which the serving graph
        # was re-linked (``graph_gen == len(relink_ts)`` rides on every
        # plan), plus the layout-invariant scheduling counters — global
        # commands since the last schedule check and effective deletes
        # since the last re-link
        self.graph_gen = 0
        self.relink_ts: List[int] = []
        self._deletes_since_relink = 0
        self._cmds_since_relink_check = 0
        # compressed tier (DESIGN.md §10): one code table per shard slice
        # (one entry in flat mode), built on first coarse read and then
        # maintained incrementally on ingest; None until needed and after
        # recover/rollback (the table is a pure function of the state, so
        # a lazy rebuild is always bit-identical)
        self._code_tables: Optional[List[Any]] = None

        self.durable = None  # DurableStore | ShardedDurableStore | None
        self._group: Optional[wal_lib.GroupCommitWriter] = None
        self._doc_table: Optional[SideTable] = None
        self._clients = None  # net.RemoteShardClient fleet (hosts mode)
        self._ckpt_thread: Optional[threading.Thread] = None
        self._ckpt_error: Optional[BaseException] = None
        self._last_ckpt_t = 0
        if serve_cfg.durable_dir is not None:
            if serve_cfg.hosts is not None:
                # one RemoteShardClient per shard host; the sharded store
                # drives them through the exact surface local shards expose
                from repro.net.client import (RemoteShardClient,
                                              SocketTransport)
                self._clients = [
                    RemoteShardClient(
                        SocketTransport(h.rsplit(":", 1)[0],
                                        int(h.rsplit(":", 1)[1])),
                        contract=serve_cfg.contract)
                    for h in serve_cfg.hosts]
                self.durable = ShardedDurableStore(
                    serve_cfg.durable_dir, backends=self._clients)
            elif not self._layout_sharded:
                self.durable = DurableStore(
                    serve_cfg.durable_dir, self.memory,
                    compaction=serve_cfg.compaction)
            else:
                self.durable = ShardedDurableStore(
                    serve_cfg.durable_dir, self.memory, n_shards=n,
                    compaction=serve_cfg.compaction)
            # the doc cache's durable side table (DESIGN.md §7): token
            # prefixes ride beside the WAL so recover() starts warm; the
            # substrate never depends on it (it is a cache, not state).
            # Its records are written — and under group commit, synced via
            # the writer's pre_flush hook — BEFORE the commands they
            # describe become durable, so a live id can never outrun its
            # tokens (the rollback + id-reuse hazard)
            self._doc_table = SideTable(
                pathlib.Path(serve_cfg.durable_dir) / "docs.sdt")
            if serve_cfg.group_commit is not None:
                self._group = wal_lib.GroupCommitWriter(
                    self.durable, serve_cfg.group_commit,
                    pre_flush=self._doc_table.sync)
        elif (serve_cfg.group_commit is not None
              or serve_cfg.compaction is not None):
            # refuse the inconsistent config loudly: an operator who set a
            # durability policy believes ingest is durable — silently
            # running non-durable would be the worst possible reading
            raise ValueError(
                "group_commit/compaction policies need durable_dir set")

        # the read pool (DESIGN.md §9): read_replicas[s][i] is the i-th
        # verified follower of shard s (one list in flat mode)
        self.read_replicas: List[List[Any]] = []
        self._closed = False
        if serve_cfg.follow is not None and not serve_cfg.replicas:
            raise ValueError(
                "follow=FollowerPolicy(...) needs replicas > 0: a "
                "follower policy paces read replicas, and there are none")
        if serve_cfg.replicas:
            if self.durable is None:
                raise ValueError(
                    "replicas=k needs durable_dir: a read replica follows "
                    "a durable WAL, and without one there is nothing to "
                    "tail")
            self._spawn_replicas(serve_cfg.replicas)
            self._start_followers()

        self._embed_fn = jax.jit(self._embed_batch)
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(p, b, cfg, self.sc.s_cache))
        self._decode = jax.jit(
            lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg))

    # ------------------------------------------------------------------ #
    # embedding: pooled final hidden states (pre-head)
    # ------------------------------------------------------------------ #

    def _embed_batch(self, params, tokens: jax.Array) -> jax.Array:
        batch = {"tokens": tokens}
        h = tf._embed(params, batch, self.cfg)
        B, L = h.shape[:2]
        positions = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[None], (B, L))
        angles = tf._angles_for(batch, positions, self.cfg)
        h, _, _ = tf._run_stack(params, h, positions, self.cfg, "train",
                                None, angles)
        return jnp.mean(h.astype(jnp.float32), axis=1)  # [B, D]

    def _cursor(self) -> int:
        """The engine's applied-command cursor: flat ``version``, or the
        common per-shard padded cursor in sharded mode (always equal at
        the batch boundaries the engine operates at)."""
        v = np.asarray(self.memory.version).reshape(-1)
        return int(v[0])

    # ------------------------------------------------------------------ #
    # read pool: verified replicas behind the flush barrier (DESIGN.md §9)
    # ------------------------------------------------------------------ #

    def _spawn_replicas(self, k: int) -> None:
        """Attach ``k`` in-process verified followers per shard. Local
        modes follow the engine's own store(s) through ``LocalPrimary``
        (the replica-facing surface of a DurableStore); networked mode
        follows the shard hosts over their own wire connections. Genesis
        is the engine's t=0 state (its shard slice in sharded layouts) —
        replicas then catch up through the same verify-then-ack discipline
        any replica uses, so every cursor they report is proven."""
        from repro.net.replica import LocalPrimary, ReplicaStore
        if not self._layout_sharded:
            primaries = [lambda: LocalPrimary(
                self.durable, state_fn=lambda: self.memory,
                side_table=self._doc_table)]
            geneses = [self.memory]
        elif self._clients is not None:
            from repro.net.client import RemoteShardClient, SocketTransport
            def remote(h):
                addr, port = h.rsplit(":", 1)
                return lambda: RemoteShardClient(
                    SocketTransport(addr, int(port)),
                    contract=self.sc.contract)
            primaries = [remote(h) for h in self.sc.hosts]
            geneses = [distributed.shard_slice(self.memory, s, self.n_shards)
                       for s in range(self.n_shards)]
        else:
            def local(s):
                return lambda: LocalPrimary(
                    self.durable.shards[s],
                    state_fn=lambda: distributed.shard_slice(
                        self.memory, s, self.n_shards),
                    side_table=self._doc_table)
            primaries = [local(s) for s in range(self.n_shards)]
            geneses = [distributed.shard_slice(self.memory, s, self.n_shards)
                       for s in range(self.n_shards)]
        self.read_replicas = [
            [ReplicaStore(make_primary(), geneses[s],
                          replica_id=s * k + i)
             for i in range(k)]
            for s, make_primary in enumerate(primaries)]

    def _start_followers(self) -> None:
        """Start one background tailer per replica under the configured
        ``FollowerPolicy`` (DESIGN.md §12); a no-op without one — the
        pool then advances only on explicit ``sync_replicas()``."""
        if self.sc.follow is None:
            return
        for pool in self.read_replicas:
            for rep in pool:
                rep.start_following(self.sc.follow)

    def _reset_replicas(self) -> None:
        """Tear the read pool down and respawn it (recover/rollback):
        follower threads stop, transports close, and fresh replicas
        re-earn their cursors through the same verify-then-ack catch-up —
        a pool must never serve a state the *current* durable history
        cannot prove (rollback rewrites history; recovery may land on an
        older cursor)."""
        if not self.read_replicas:
            return
        for pool in self.read_replicas:
            for rep in pool:
                rep.close()  # close() stops the follower thread first
        self.read_replicas = []
        self._spawn_replicas(self.sc.replicas)
        self._start_followers()

    def _pick_replica(self, q_raw) -> Optional[int]:
        """Deterministic replica choice from the request bytes — the same
        query always lands on the same pool slot, so a served answer is
        replayable from (log cursor, query, plan). The slot must exist on
        EVERY shard's pool (the read fans out across shards at one slot),
        so the usable pool size is the min across shards: a ragged pool
        (a replica failed to spawn or was closed) shrinks the pool rather
        than routing to a missing slot, and an empty pool returns None —
        the primary serves."""
        from repro.core import hashing
        sizes = [len(pool) for pool in self.read_replicas]
        n = min(sizes) if sizes else 0
        if n == 0:
            return None
        return (hashing.digest_bytes(np.asarray(q_raw).tobytes()) % n)

    def sync_replicas(self, *, max_commands: int = 0) -> int:
        """Catch every attached replica up to the current flush cursor
        (each slice verified against the primary's hash before commit).
        Returns the **max residual lag** across the pool — 0 means every
        replica proved the flush cursor; a positive value means a hot
        primary outran at least one catch-up (the caller can tell "caught
        up" from "gave up"). Like checkpoints, replica advancement is an
        explicit serving-loop concern — ``retrieve()`` never blocks a
        read on it; a lagging replica just loses the route until it
        catches up."""
        self.flush()
        lag = 0
        for pool in self.read_replicas:
            for rep in pool:
                lag = max(lag, rep.catch_up(max_commands=max_commands))
        return lag

    # ------------------------------------------------------------------ #
    # compressed tier: per-slice code tables (DESIGN.md §10)
    # ------------------------------------------------------------------ #

    def _memory_slices(self) -> List[MemoryState]:
        if not self._layout_sharded:
            return [self.memory]
        return [distributed.shard_slice(self.memory, s, self.n_shards)
                for s in range(self.n_shards)]

    def _ensure_code_tables(self) -> None:
        """Build the per-slice code tables from the live state. Idempotent;
        the result is the same bits any other holder of this state would
        derive (``codes.build`` is pure in the live rows)."""
        if self._code_tables is not None:
            return
        from repro.core import codes as codes_lib
        self._code_tables = [codes_lib.build(sl)
                             for sl in self._memory_slices()]

    def _refresh_code_tables(self, inserted_ids: np.ndarray) -> None:
        """Incremental maintenance after an ingest batch: only the slots
        that received this batch's ids re-encode (engine writes are fresh
        INSERTs, so those are exactly the touched slots); a per-dim param
        drift falls back to a full rebuild inside ``codes.refresh`` —
        either way the invariant ``table == codes.build(slice)`` holds."""
        if self._code_tables is None:
            return
        from repro.core import codes as codes_lib
        tables = []
        for sl, tbl in zip(self._memory_slices(), self._code_tables):
            touched = np.nonzero(
                np.isin(np.asarray(sl.ids), inserted_ids)
                & np.asarray(sl.valid))[0].astype(np.int32)
            tables.append(codes_lib.refresh(tbl, sl, touched))
        self._code_tables = tables

    def _checkpoint_code_tables(self) -> None:
        """Cut the code tables' own content-addressed manifests beside the
        state snapshots (``<durable_dir>/codes/``): chunks dedup against
        the previous checkpoint, so a param-stable refresh costs only the
        touched rows' chunks. Recovery does NOT read these — the table is
        rebuilt from the recovered state (pure function, always correct);
        the manifests are the incremental audit/warm-start artifact, and
        tests verify a restored manifest equals the rebuild bit-for-bit."""
        if self.sc.durable_dir is None or not self._coarse_enabled():
            return
        from repro.core import codes as codes_lib
        self._ensure_code_tables()
        t = self._cursor()
        cdir = pathlib.Path(self.sc.durable_dir) / "codes"
        store = snapshot.ChunkStore(cdir / "chunks")
        keep_keys = set()
        for s, tbl in enumerate(self._code_tables):
            manifest, _ = codes_lib.snapshot_table_v2(tbl, t, store)
            path = cdir / f"codes_{s:04d}_t{t:020d}.mft"
            tmp = path.with_suffix(".tmp")
            tmp.write_bytes(manifest)
            tmp.replace(path)
            keep_keys.update(codes_lib.table_manifest_chunk_keys(manifest))
        # retain only the newest manifest set + the chunks it references
        for old in cdir.glob("codes_*.mft"):
            if not old.name.endswith(f"t{t:020d}.mft"):
                old.unlink()
        for key in store.keys():
            if key not in keep_keys:
                store.delete(key)

    def _coarse_enabled(self) -> bool:
        return self.sc.ef_coarse > 0 or self.sc.route == query.ROUTE_COARSE

    # ------------------------------------------------------------------ #
    # WRITE path
    # ------------------------------------------------------------------ #

    def insert_documents(self, token_batches: np.ndarray) -> List[int]:
        """token_batches [N, L] int32 → ids. Batched through the boundary.

        The WRITE path goes through ``machine.bulk_apply`` — hash-identical
        to scanning the log one command at a time — in flat mode, and
        through ``shard_wal.bulk_apply_sharded`` (route once, apply each
        shard's share to its slice) in sharded mode. Id allocation is
        sequential in BOTH modes: the same documents produce the same
        command log everywhere, which is what makes the two modes
        conformance-comparable (DESIGN.md §7)."""
        if len(token_batches) == 0:
            # routing pads an empty batch to one NOP per shard, which would
            # advance the sharded memory cursor while both durable paths
            # (correctly) skip empty logs — refuse the desync up front
            return []
        emb = self._embed_fn(self.params, jnp.asarray(token_batches))
        raw = boundary.normalize_embedding(emb, self.sc.contract)
        ids = np.arange(self._next_id, self._next_id + len(token_batches),
                        dtype=np.int64)
        self._next_id += len(token_batches)
        batch_log = commands.insert_batch(jnp.asarray(ids), raw,
                                          self.sc.contract)
        routed = None if not self._layout_sharded else \
            distributed.route_commands(batch_log, self.n_shards)

        # doc cache first: its side-table records must be durable no later
        # than the commands they describe, or a crash after a rollback-
        # then-reinsert could recover a live id with stale tokens. Under
        # group commit the writer's pre_flush hook syncs the table inside
        # every flush (foreground, policy or timer), before the sink commit
        for i, tid in enumerate(ids):
            doc = np.asarray(token_batches[i])
            self.docs[int(tid)] = doc
            if self._doc_table is not None:
                self._doc_table.put(
                    int(tid), doc.astype("<i4", copy=False).tobytes())

        if self._group is not None:
            # group commit: the batch buffers toward one fsync per group —
            # it is NOT yet durable, so it also must not be readable; the
            # read path's flush() barrier restores WAL-first ordering at
            # the moment of first observation (DESIGN.md §6)
            self._group.submit(batch_log, routed=routed)
        elif self.durable is not None:
            # WAL-first: the commands are durable before their effects are
            # visible, so a crash can lose at most un-acked work
            if self._doc_table is not None:
                self._doc_table.sync()
            if not self._layout_sharded:
                self.durable.append(batch_log)
            else:
                self.durable.append(batch_log, routed=routed)
        self.log = self.log.concat(batch_log)
        if not self._layout_sharded:
            self.memory = machine.bulk_apply(self.memory, batch_log)
        else:
            for s in range(self.n_shards):
                self._shard_logs[s] = self._shard_logs[s].concat(
                    jax.tree.map(lambda a, s=s: a[s], routed))
            self.memory = shard_wal.bulk_apply_sharded(
                self.memory, batch_log, self.n_shards, routed=routed)
        self._refresh_code_tables(ids)
        self._cmds_since_relink_check += len(batch_log)
        self._maybe_relink()
        self._maybe_checkpoint()
        return [int(i) for i in ids]

    def delete_documents(self, doc_ids) -> int:
        """Delete documents by id through the same durable path INSERTs
        take: one canonical DELETE batch is WAL-appended (or group-
        submitted) before its effects are visible, applied with the same
        bulk driver, and recorded on the same audit logs — a churny
        workload is just a log with more opcodes, not a different engine.
        Unknown ids are deterministic no-ops (they still advance logical
        time, like every rejected command). Returns the number of rows
        actually tombstoned.

        The HNSW graph survives: ``machine`` repairs a tombstoned entry
        point on the spot (DESIGN.md §11) and the scheduled re-link pass
        (``ServeConfig.relink``) sweeps dead waypoints, so the planner
        keeps the ANN route under churn."""
        if len(doc_ids) == 0:
            return 0
        ids = np.asarray(sorted(int(i) for i in doc_ids), dtype=np.int64)
        batch_log = commands.delete_batch(jnp.asarray(ids), self.cfg.d_model,
                                          self.sc.contract)
        routed = None if not self._layout_sharded else \
            distributed.route_commands(batch_log, self.n_shards)
        if self._group is not None:
            self._group.submit(batch_log, routed=routed)
        elif self.durable is not None:
            if self._doc_table is not None:
                self._doc_table.sync()
            if not self._layout_sharded:
                self.durable.append(batch_log)
            else:
                self.durable.append(batch_log, routed=routed)
        self.log = self.log.concat(batch_log)
        before = shard_wal.live_count(self.memory)
        if not self._layout_sharded:
            self.memory = machine.bulk_apply(self.memory, batch_log)
        else:
            for s in range(self.n_shards):
                self._shard_logs[s] = self._shard_logs[s].concat(
                    jax.tree.map(lambda a, s=s: a[s], routed))
            self.memory = shard_wal.bulk_apply_sharded(
                self.memory, batch_log, self.n_shards, routed=routed)
        removed = before - shard_wal.live_count(self.memory)
        for tid in ids:
            # the doc cache drops now; the side table's record stays — a
            # dead id is never retrieved, and the engine's sequential id
            # allocation never reuses it, so the stale bytes are inert
            self.docs.pop(int(tid), None)
        # deletes touch layout-dependent slots; the lazy rebuild is a pure
        # function of the live rows, so it is always bit-identical
        self._code_tables = None
        self._deletes_since_relink += removed
        self._cmds_since_relink_check += len(batch_log)
        self._maybe_relink()
        self._maybe_checkpoint()
        return removed

    # ------------------------------------------------------------------ #
    # graph maintenance: scheduled deterministic re-link (DESIGN.md §11)
    # ------------------------------------------------------------------ #

    def _maybe_relink(self) -> None:
        """The schedule of ``ServeConfig.relink``, checked at batch
        boundaries from layout-invariant facts only — flat and sharded
        engines fed the same batches fire at the same boundaries."""
        pol = self.sc.relink
        if pol is None or self._cmds_since_relink_check < pol.check_every:
            return
        self._cmds_since_relink_check = 0
        dead = self._deletes_since_relink
        live = shard_wal.live_count(self.memory)
        if dead < pol.min_deletes or dead < pol.dead_ratio * (dead + live):
            return
        self.relink_now()

    def relink_now(self) -> int:
        """Re-link the serving graph from its live rows right now (each
        shard's slice in sharded mode) and record the firing cursor on
        ``relink_ts`` — the pass mutates the graph without a logged
        command, so the audit trail must know where it fired for
        ``replay_log_fresh`` to reproduce the serving state. Returns the
        cursor. Arena, WAL and durable artifacts are untouched: a re-link
        changes how the graph routes, never what the memory contains."""
        t = self._cursor()
        if not self._layout_sharded:
            self.memory = hnsw.relink(self.memory)
        else:
            self.memory = shard_wal.relink_sharded(self.memory,
                                                   self.n_shards)
        self.relink_ts.append(t)
        self.graph_gen = len(self.relink_ts)
        self._deletes_since_relink = 0
        return t

    # ------------------------------------------------------------------ #
    # READ path
    # ------------------------------------------------------------------ #

    def retrieve(self, prompt_tokens: np.ndarray, k: Optional[int] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """[B, L] prompts → (ids [B, k], scores [B, k]) — deterministic.

        The whole batch runs on the route the query planner picks from
        static facts (live count, k, ef). Flat mode executes the plan under
        one jit; sharded mode fans it out per shard and merges with the
        order-invariant integer combine — bit-identical to the flat answer
        for the exact route, and for HNSW whenever the beam covers each
        shard (DESIGN.md §7). The decision is recorded on ``self.last_plan``
        for audit.

        With a read pool (``replicas=k``), the request picks a pool slot
        deterministically from its query bytes and is served from that
        replica's verified state — but only when every chosen replica's
        acked cursor has reached the flush cursor returned by the barrier
        above (read-your-writes: a replica may lag the log, never the
        reader). Otherwise the primary serves, and either way
        ``last_plan.served_by`` records who answered (DESIGN.md §9)."""
        k = k or self.sc.retrieve_k
        # sync-on-read barrier: nothing un-durable is observable, and the
        # cursor it returns is the read-your-writes floor for replica routes
        flush_t = self.flush()
        emb = self._embed_fn(self.params, jnp.asarray(prompt_tokens))
        q_raw = boundary.admit_query(emb, self.sc.contract)
        plan = query.plan_query(
            shard_wal.live_count(self.memory), k, self.sc.ef,
            use_kernel=self.sc.use_kernel,
            exact_threshold=self.sc.exact_threshold, route=self.sc.route,
            ef_coarse=self.sc.ef_coarse, dim=self.cfg.d_model,
            graph_gen=self.graph_gen)
        pool_states = None
        if self.read_replicas:
            slot = self._pick_replica(q_raw)
            if slot is not None:
                # consistent (state, hash, t) per replica: a live follower
                # may commit concurrently, and admission + serving must
                # read ONE proven pair, not a torn mix of two
                snaps = [shard_pool[slot].snapshot()
                         for shard_pool in self.read_replicas]
                if all(t >= flush_t for _, _, t in snaps):
                    pool_states = [state for state, _, _ in snaps]
                    plan = dataclasses.replace(plan,
                                               served_by=f"replica:{slot}")
        self.last_plan = plan
        if pool_states is not None:
            ids, scores = self._replica_query(pool_states, q_raw, k, plan)
        elif self._clients is not None:
            # the networked read: every shard host executes the same plan
            # on its applied state, candidates merge with the one
            # order-invariant combine — bit-identical to the local sharded
            # read on the same content (the conformance suite pins it)
            from repro.net.client import remote_sharded_query
            ids, scores = remote_sharded_query(self._clients, q_raw, k, plan)
        elif not self._layout_sharded:
            if plan.route == query.ROUTE_COARSE:
                self._ensure_code_tables()
                ids, scores = query.execute_plan(
                    self.memory, q_raw, k, plan, codes=self._code_tables[0])
            else:
                ids, scores = query.execute_plan(self.memory, q_raw, k, plan)
        else:
            tables = None
            if plan.route == query.ROUTE_COARSE:
                self._ensure_code_tables()
                tables = self._code_tables
            ids, scores = query.sharded_host_query(
                self.memory, self.n_shards, q_raw, k, plan, tables=tables)
        return np.asarray(ids), np.asarray(scores)

    def _replica_query(self, pool_states, q_raw, k: int,
                       plan: query.QueryPlan
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Execute the engine's plan on the chosen replicas' verified
        states: the flat state directly, per-shard states merged with the
        one order-invariant (score, id) combine — the same merge the
        networked read uses, so a replica-served answer is bit-identical
        to the primary's at the same cursor (the conformance suite pins
        it)."""
        from repro.core import search
        if not self._layout_sharded:
            return query.execute_plan(pool_states[0], q_raw, k, plan)
        ids_parts, score_parts = [], []
        for state in pool_states:
            ids_s, scores_s = query.execute_plan(state, q_raw, k, plan)
            ids_parts.append(jnp.asarray(ids_s, jnp.int64))
            score_parts.append(jnp.asarray(scores_s, jnp.int64))
        flat_ids = jnp.concatenate(ids_parts, axis=-1)
        flat_scores = jnp.concatenate(score_parts, axis=-1)
        s_out, i_out = search.merge_candidates(flat_scores, flat_ids, k)
        return i_out, s_out

    def retrieval_hash(self, prompt_tokens: np.ndarray,
                       k: Optional[int] = None) -> int:
        """Platform-invariant hash of the retrieval set for these prompts —
        the read-path audit artifact (paper §8.1 applied to queries)."""
        ids, scores = self.retrieve(prompt_tokens, k)
        return query.retrieval_hash(ids, scores)

    # ------------------------------------------------------------------ #
    # GENERATE
    # ------------------------------------------------------------------ #

    def generate(self, prompt_tokens: np.ndarray, *, augment: bool = True
                 ) -> np.ndarray:
        """Greedy decode a batch of prompts, optionally memory-augmented.
        Returns [B, max_new_tokens] int32."""
        B, L = prompt_tokens.shape
        if augment and shard_wal.live_count(self.memory) > 0:
            ids, _ = self.retrieve(prompt_tokens)
            ctx = np.zeros((B, self.sc.context_tokens), np.int32)
            for b in range(B):
                best = int(ids[b, 0])
                if best >= 0:
                    doc = self.docs.get(best)
                    if doc is not None:
                        n = min(len(doc), self.sc.context_tokens)
                        ctx[b, -n:] = doc[:n]
            prompt_tokens = np.concatenate([ctx, prompt_tokens], axis=1)
            L = prompt_tokens.shape[1]

        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompt_tokens)})
        out = np.zeros((B, self.sc.max_new_tokens), np.int32)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for t in range(self.sc.max_new_tokens):
            out[:, t] = np.asarray(tok)[:, 0]
            pos = jnp.full((B, 1), L + t, jnp.int32)
            logits, caches = self._decode(self.params, caches, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return out

    # ------------------------------------------------------------------ #
    # durability: background checkpoints + crash recovery (DESIGN.md §5, §7)
    # ------------------------------------------------------------------ #

    def flush(self) -> int:
        """Force any pending group-commit batch durable; returns the
        durable WAL cursor (== memory cursor afterwards). The read path
        calls this before serving — the sync-on-read barrier that keeps
        retrieval from ever observing un-durable commands — and it is the
        ack point for upstream callers under group commit. The doc side
        table syncs here too, so its durability never lags the barrier.
        With live followers, the barrier doubles as the staleness nudge:
        any follower lagging the new cursor past the policy's
        ``max_lag_commands`` is woken immediately (never waited on)."""
        if self._doc_table is not None:
            self._doc_table.sync()
        if self._group is not None:
            t = self._group.flush()
        else:
            t = self.durable.t if self.durable is not None \
                else self._cursor()
        if self.sc.follow is not None:
            lag_bound = self.sc.follow.max_lag_commands
            for pool in self.read_replicas:
                for rep in pool:
                    if t - rep.t > lag_bound:
                        rep.notify_writes()
        return t

    def close(self) -> None:
        """Flush pending ingest, join background work and release durable
        resources: the group-commit writer (and its timer thread, if
        ``timer_flush`` was set), the doc side table's file handle, every
        read replica (transports + any catch-up prefetch thread) and the
        shard-host connections. Long-lived processes that construct
        engines repeatedly must close them — daemon threads and fds do not
        collect themselves. Idempotent: benches and kill tests close
        engines repeatedly, and a double close is a no-op."""
        if self._closed:
            return
        self._closed = True
        self.flush()
        self.wait_durable()
        if self._group is not None:
            self._group.close()
        for pool in self.read_replicas:
            for rep in pool:
                rep.close()
        if self._doc_table is not None:
            self._doc_table.close()
        if self._clients is not None:
            for c in self._clients:
                c.close()

    def wait_durable(self) -> None:
        """Join any in-flight background checkpoint; re-raise its error —
        same no-silent-loss contract as checkpoint.CheckpointManager."""
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None
        if self._ckpt_error is not None:
            err, self._ckpt_error = self._ckpt_error, None
            raise RuntimeError("background checkpoint failed") from err

    def checkpoint(self) -> Dict[str, int]:
        """Synchronously cut an incremental snapshot at the current cursor
        (per-shard v2 snapshots + the merged whole-state-hash record in
        sharded mode); returns the snapshot stats."""
        if self.durable is None:
            raise RuntimeError("no durable_dir configured")
        self.flush()  # a snapshot may only cover durable commands
        self.wait_durable()
        stats = self.durable.checkpoint(
            jax.tree.map(np.asarray, self.memory))
        self._last_ckpt_t = self._cursor()
        if self.sc.retain_snapshots > 0:
            stats.update(self.durable.retain(self.sc.retain_snapshots))
        self._checkpoint_code_tables()
        return stats

    def _maybe_checkpoint(self) -> None:
        if (self.durable is None or self.sc.checkpoint_every <= 0
                or self._cursor() - self._last_ckpt_t
                < self.sc.checkpoint_every):
            return
        self.flush()  # a snapshot may only cover durable commands
        self.wait_durable()  # one in flight at a time; surfaces past errors
        host_state = jax.tree.map(np.asarray, self.memory)
        self._last_ckpt_t = self._cursor()
        if self._clients is not None:
            # synchronous over the wire: the shard host proves the cursor +
            # hash against its applied state at request time, so a
            # background thread would race the next append's cursor advance
            self.durable.checkpoint(host_state)
            if self.sc.retain_snapshots > 0:
                self.durable.retain(self.sc.retain_snapshots)
            return

        def work():
            try:
                self.durable.checkpoint(host_state)
                if self.sc.retain_snapshots > 0:
                    self.durable.retain(self.sc.retain_snapshots)
            except BaseException as e:  # noqa: BLE001 — re-raised on wait
                self._ckpt_error = e

        self._ckpt_thread = threading.Thread(target=work, daemon=True)
        self._ckpt_thread.start()

    def _reload_audit_logs(self, t: int) -> None:
        """Rebuild the in-memory audit trail from the durable WAL(s) after
        recover/rollback, if retention kept the full history."""
        empty = commands.empty_log(self.cfg.d_model, self.sc.contract)
        if not self._layout_sharded:
            try:
                self.log = self.durable.wal.read_range(0, t)
            except ValueError:
                self.log = empty
        else:
            # the global interleaving is not durable (per-shard WALs only);
            # the per-shard logs are the reconstructible audit trail
            self.log = empty
            try:
                self._shard_logs = self.durable.shard_logs(0, t)
            except ValueError:
                self._shard_logs = [empty for _ in range(self.n_shards)]

    def _reload_serving_caches(self) -> None:
        """Refresh next-id allocation and the doc cache from durable
        artifacts: ids from the live rows of the recovered state (works in
        both layouts), token prefixes from the side table — the recovered
        engine generates with warm retrieved context immediately instead
        of refilling lazily (DESIGN.md §7)."""
        ids = np.asarray(self.memory.ids)
        live = ids[np.asarray(self.memory.valid)]
        self._next_id = int(live.max()) + 1 if live.size else 0
        if self._doc_table is not None:
            self.docs = {
                int(key): np.frombuffer(payload, "<i4").astype(np.int32)
                for key, payload in self._doc_table.entries.items()}

    def recover(self) -> Tuple[int, int]:
        """Rebuild memory from the durable store after a crash: nearest
        snapshot(s) + WAL tail(s), bit-identical to replaying the durable
        prefix; in sharded mode the shards reconcile to one global cursor
        first (min over shards, ahead shards roll back — DESIGN.md §6).
        Returns (t, state hash). Retrieval serves immediately, and the doc
        cache reloads from its durable side table so generation is warm."""
        if self.durable is None:
            raise RuntimeError("no durable_dir configured")
        self.flush()  # a live engine recovering: don't drop acked-to-us work
        self.wait_durable()
        state, h, t = self.durable.recover()
        self.memory = state
        self._code_tables = None  # rebuilt from the recovered state on
        self._last_ckpt_t = t     # first coarse read (pure function of it)
        self._reload_audit_logs(t)
        self._reload_serving_caches()
        # recovery may land below the replicas' cursors (lost unflushed
        # suffix): respawn the pool so every served cursor re-earns its
        # proof against the recovered history (follower threads restart)
        self._reset_replicas()
        h = self._canonicalize_graph(t, h)
        return t, h

    def rollback_to(self, t: int) -> Tuple[int, int]:
        """Roll the durable history AND the serving state back to logical
        time ``t``: snapshots/WAL records above ``t`` are dropped (on every
        shard in sharded mode, with merged records pruned too) and memory
        is restored at ``t``. Returns (t, state hash)."""
        if self.durable is None:
            raise RuntimeError("no durable_dir configured")
        self.flush()
        self.wait_durable()
        self.durable.rollback_to(t)
        state, h = self.durable.restore_at(t)
        self.memory = state
        self._code_tables = None  # pure function of the restored state
        self._last_ckpt_t = t
        self._reload_audit_logs(t)
        self._reload_serving_caches()
        # rollback rewrites history: replicas ahead of ``t`` proved a
        # prefix that no longer exists — tear the pool down and re-earn
        self._reset_replicas()
        h = self._canonicalize_graph(t, h)
        return t, h

    def _canonicalize_graph(self, t: int, h: int) -> int:
        """Post-restore graph canonicalization (DESIGN.md §11). The durable
        WAL holds commands only — a restored graph is the pure-replay
        graph, not the re-linked one the engine was serving. With a re-link
        policy configured, one re-link of the restored state puts every
        recovered engine (and every layout) on the same canonical footing:
        ``relink_ts=[t]``, ``graph_gen=1``, counters reset — and the
        returned hash becomes the post-re-link ``state_hash()`` (the
        pre-re-link state was already verified against the durable records
        by the restore itself). Retrieval is unaffected either way in the
        beam-exhaustive regime; the canonical graph is simply the one whose
        provenance ``replay_log_fresh`` can restate. Without a policy the
        restore is returned untouched (graph audit state just resets)."""
        self._deletes_since_relink = 0
        self._cmds_since_relink_check = 0
        if self.sc.relink is None:
            self.relink_ts = []
            self.graph_gen = 0
            return h
        if not self._layout_sharded:
            self.memory = hnsw.relink(self.memory)
        else:
            self.memory = shard_wal.relink_sharded(self.memory,
                                                   self.n_shards)
        self.relink_ts = [t]
        self.graph_gen = 1
        return self.state_hash()

    # ------------------------------------------------------------------ #
    # audit / replay (paper §8.1, §9; DESIGN.md §7)
    # ------------------------------------------------------------------ #

    def memory_hash(self) -> int:
        """The layout-invariant live-content hash (DESIGN.md §7): flat and
        sharded engines fed the same command log report the same value —
        the cross-mode conformance artifact."""
        from repro.core import hashing
        return hashing.content_hash(self.memory)

    def state_hash(self) -> int:
        """``hash_pytree`` of the native-layout state — the within-layout
        artifact snapshots, merged records and replay audits verify."""
        from repro.core import hashing
        return hashing.hash_pytree(self.memory)

    def snapshot_bytes(self) -> bytes:
        if self._layout_sharded:
            raise ValueError(
                "sharded engines snapshot through checkpoint() (per-shard "
                "v2 snapshots + merged hash record), not one flat blob")
        return snapshot.snapshot_bytes(self.memory)

    def replay_log_fresh(self) -> int:
        """Re-apply the audit trail to S_0 with the one-command-at-a-time
        reference ``machine.replay``; returns the native-layout hash — must
        equal ``state_hash()`` (the paper's replayability guarantee). In
        sharded mode each shard's (routed, padded) log replays on its
        genesis slice and the merge is hashed — the sharded form of the
        same audit.

        Re-links mutate the graph without a logged command, so the replay
        interleaves ``hnsw.relink`` at the recorded ``relink_ts`` cursors —
        the flat cursor is the global log index, a per-shard cursor is the
        per-shard padded log offset, so slicing each log at the recorded
        cursors replays exactly the prefix each firing saw (DESIGN.md §11).
        """
        from repro.core import hashing
        if not self._layout_sharded:
            st = init_state(self.sc.capacity, self.cfg.d_model,
                            contract=self.sc.contract)
            pos = 0
            for t in self.relink_ts:
                st = machine.replay(st, self.log.slice(pos, t))
                st = hnsw.relink(st)
                pos = t
            st = machine.replay(st, self.log.slice(pos, len(self.log)))
            return hashing.hash_pytree(st)
        genesis = distributed.init_sharded_host(
            self.n_shards, self.sc.capacity // self.n_shards,
            self.cfg.d_model, contract=self.sc.contract)
        parts = []
        for s in range(self.n_shards):
            st = distributed.shard_slice(genesis, s, self.n_shards)
            log = self._shard_logs[s]
            pos = 0
            for t in self.relink_ts:
                st = machine.replay(st, log.slice(pos, t))
                st = hnsw.relink(st)
                pos = t
            parts.append(machine.replay(st, log.slice(pos, len(log))))
        return hashing.hash_pytree(distributed.merge_shards(parts))
