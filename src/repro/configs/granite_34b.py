"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1 ⇒ MQA) d_ff=24576
vocab=49152 — llama-arch, code. [arXiv:2405.04324; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    attn_pattern="full",
    rope_theta=10_000.0,
    activation="swiglu",
)

REDUCED = ModelConfig(
    name="granite-34b-reduced",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    attn_pattern="full",
    activation="swiglu",
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
