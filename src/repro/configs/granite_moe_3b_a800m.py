"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) vocab=49155,
MoE 40 experts top-8, expert d_ff=512.

Note: the assignment line reads "MoE 40e top-8" with a bracketed hf pointer to
the 1b-a400m sibling (32e); we implement the listed 40e/top-8 spec (recorded
in DESIGN.md §Arch-applicability). [hf:ibm-granite]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    attn_pattern="full",
    rope_theta=10_000.0,
    activation="swiglu",
    tie_embeddings=True,
    num_experts=40,
    num_experts_per_tok=8,
    expert_d_ff=512,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    attn_pattern="full",
    activation="swiglu",
    tie_embeddings=True,
    num_experts=8,
    num_experts_per_tok=2,
    expert_d_ff=32,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
