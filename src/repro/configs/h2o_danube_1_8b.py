"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, sliding-window attention. [arXiv:2401.16818; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attn_pattern="swa",
    sliding_window=4096,
    rope_theta=10_000.0,
    activation="swiglu",
)

REDUCED = ModelConfig(
    name="h2o-danube-1.8b-reduced",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    attn_pattern="swa",
    sliding_window=16,
    activation="swiglu",
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = True  # SWA ⇒ KV cache bounded by window
