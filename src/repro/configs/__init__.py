"""Assigned-architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

Each module defines CONFIG (exact published dims) and REDUCED (same family,
tiny dims) for CPU smoke tests. ``LONG_CONTEXT_OK`` marks archs with a
sub-quadratic long-context path (ssm/hybrid/swa/local_global) that run the
long_500k cell; pure full-attention archs skip it (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "gemma2_2b",
    "granite_34b",
    "h2o_danube_1_8b",
    "codeqwen1_5_7b",
    "mamba2_130m",
    "qwen2_vl_7b",
    "granite_moe_3b_a800m",
    "phi3_5_moe_42b_a6_6b",
    "musicgen_large",
    "zamba2_2_7b",
]

# canonical ids as listed in the assignment (dashes/dots)
CANONICAL = {
    "gemma2-2b": "gemma2_2b",
    "granite-34b": "granite_34b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "musicgen-large": "musicgen_large",
    "zamba2-2.7b": "zamba2_2_7b",
}


def _norm(arch: str) -> str:
    return CANONICAL.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.REDUCED


def long_context_ok(arch: str) -> bool:
    mod = importlib.import_module(f"repro.configs.{_norm(arch)}")
    return mod.LONG_CONTEXT_OK


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
