"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (sections 16/24/24), dynamic resolution.
Vision frontend is a STUB per assignment: input_specs() supplies precomputed
patch embeddings; the backbone is exercised end to end. [arXiv:2409.12191; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    attn_pattern="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    activation="swiglu",
    external_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen2-vl-7b-reduced",
    family="vlm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    attn_pattern="full",
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(4, 2, 2),
    activation="swiglu",
    external_embeddings=True,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
