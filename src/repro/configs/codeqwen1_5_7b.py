"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (kv=32 ⇒ MHA) d_ff=13440
vocab=92416 — qwen1.5 arch (qkv bias, rope theta 1e6). [hf:Qwen/CodeQwen1.5-7B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attn_pattern="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
)

REDUCED = ModelConfig(
    name="codeqwen1.5-7b-reduced",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=8,
    d_ff=128,
    vocab_size=512,
    attn_pattern="full",
    qkv_bias=True,
    activation="swiglu",
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
