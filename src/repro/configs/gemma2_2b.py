"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local+global alternating attention (window 4096), attn/final logit softcaps,
head_dim 256 (explicit: 8·256 ≠ d_model), query scale 1/sqrt(256), GeGLU,
sandwich (pre+post) norms, tied + scaled embeddings. [arXiv:2408.00118; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    attn_pattern="local_global",
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale_dim=256,
    rope_theta=10_000.0,
    activation="geglu",
    norm_style="pre_post",
    tie_embeddings=True,
    scale_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma2-2b-reduced",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    attn_pattern="local_global",
    sliding_window=16,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale_dim=16,
    activation="geglu",
    norm_style="pre_post",
    tie_embeddings=True,
    scale_embeddings=True,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

# half the layers are 4k-windowed; global layers are O(S) per decoded token —
# long-context decode is tractable (see DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = True
