"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) vocab=32064,
MoE 16 experts top-2, expert d_ff=6400. [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    attn_pattern="full",
    rope_theta=10_000.0,
    activation="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    expert_d_ff=6400,
)

REDUCED = ModelConfig(
    name="phi3.5-moe-reduced",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    attn_pattern="full",
    activation="swiglu",
    num_experts=4,
    num_experts_per_tok=2,
    expert_d_ff=64,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
