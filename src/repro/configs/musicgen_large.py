"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32 ⇒ MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens.

Backbone only per assignment: the EnCodec frontend + codebook interleaving is
a STUB (input_specs() supplies frame embeddings); the decoder predicts one
codebook stream (vocab 2048). Deviations recorded in DESIGN.md: RoPE replaces
MusicGen's sinusoidal positions (TPU-idiomatic, no persistent buffers);
cross-attention text conditioning is out of backbone scope.
[arXiv:2306.05284; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    attn_pattern="full",
    rope_theta=10_000.0,
    activation="gelu_mlp",
    external_embeddings=True,
)

REDUCED = ModelConfig(
    name="musicgen-large-reduced",
    family="audio",
    num_layers=4,
    d_model=64,
    num_heads=8,
    num_kv_heads=8,
    d_ff=128,
    vocab_size=128,
    attn_pattern="full",
    activation="gelu_mlp",
    external_embeddings=True,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = False  # pure full attention → long_500k skipped
