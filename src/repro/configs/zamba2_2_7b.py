"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560 ssm_state=64
vocab=32000 + 2 alternating shared attention blocks (32H kv=32, d_ff=10240)
hit every 6 mamba layers. [arXiv:2411.15242; hf]

Deviation recorded in DESIGN.md: the shared block consumes the hidden stream
directly (Zamba2 concatenates the original embedding and LoRA-specializes
each invocation; both are orthogonal to the memory-substrate study here).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    hybrid_period=6,
    num_shared_blocks=2,
    rope_theta=10_000.0,
    activation="swiglu",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    hybrid_period=2,
    num_shared_blocks=2,
    activation="swiglu",
    tie_embeddings=True,
    flash_threshold=64,
    flash_q_chunk=16,
    flash_kv_chunk=16,
)

LONG_CONTEXT_OK = True  # O(1) mamba state + 9 shared-attn caches
