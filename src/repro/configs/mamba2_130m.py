"""mamba2-130m [ssm] — 24L d_model=768, attention-free SSD, ssm_state=128,
vocab=50280, expand 2 (d_inner 1536), headdim 64 (24 heads), 1 group, conv 4.
[arXiv:2405.21060]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    num_layers=4,
    d_model=64,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=16,
    tie_embeddings=True,
)

LONG_CONTEXT_OK = True  # O(1) decode state — long_500k is the showcase cell
