import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract memory/cost/collective artifacts.

The two lines above MUST precede any jax import (device count locks at init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

import repro  # noqa: F401  (x64 for the memory substrate)
from repro.configs import ARCH_IDS, CANONICAL
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models.config import SHAPES
from repro.roofline import analysis as roofline
from repro.core import compat

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             verbose: bool = True) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    out_path = out_dir / f"{tag}.json"
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        cell = build_cell(arch, shape_name, mesh)
        if cell.skip_reason:
            record.update(status="skip", reason=cell.skip_reason)
        else:
            with compat.use_mesh(mesh):
                jitted = jax.jit(cell.step_fn, donate_argnums=cell.donate,
                                 out_shardings=cell.out_shardings)
                lowered = jitted.lower(*cell.args)
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            mem_rec = {}
            for field in ("generated_code_size_in_bytes",
                          "argument_size_in_bytes", "output_size_in_bytes",
                          "alias_size_in_bytes", "temp_size_in_bytes"):
                v = getattr(mem, field, None)
                if v is not None:
                    mem_rec[field] = int(v)
            cost = compat.cost_analysis(compiled)
            rf = roofline.analyze(compiled, chips)
            record.update(
                status="ok",
                chips=chips,
                memory_analysis=mem_rec,
                cost={k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float))},
                roofline=rf.to_dict(),
                compile_seconds=round(time.time() - t0, 1),
            )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2))
    if verbose:
        status = record["status"]
        extra = ""
        if status == "ok":
            rl = record["roofline"]
            extra = (f" dominant={rl['dominant']}"
                     f" compute={rl['compute_s']:.2e}s"
                     f" memory={rl['memory_s']:.2e}s"
                     f" coll={rl['collective_s']:.2e}s"
                     f" compile={record['compile_seconds']}s")
        elif status == "error":
            extra = " " + record["error"][:200]
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id (canonical or module name) or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [
        CANONICAL.get(args.arch, args.arch.replace("-", "_").replace(".", "_"))
    ]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, out_dir)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
