"""ShapeDtypeStruct stand-ins for every (arch × shape × mesh) dry-run cell.

No device allocation anywhere: params/optimizer/caches come from
jax.eval_shape and are re-wrapped with their NamedShardings; batches are
built directly. ``build_cell`` returns everything dryrun.py needs to lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, long_context_ok
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train import step as step_lib


def _with_shardings(shape_tree: Any, sharding_tree: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree,
    )


def params_struct(cfg: ModelConfig, mesh: Mesh) -> Any:
    shapes = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    shardings = shd.param_shardings(shapes, cfg, mesh)
    return _with_shardings(shapes, shardings)


def opt_struct(cfg: ModelConfig, mesh: Mesh, params_sds: Any) -> Any:
    shapes = jax.eval_shape(adamw_init, params_sds)
    # m/v mirror params; step is replicated
    p_shard = shd.param_shardings(
        jax.tree.map(lambda s: s, params_sds), cfg, mesh
    )
    rep = NamedSharding(mesh, P())
    shardings = {"m": p_shard, "v": p_shard, "step": rep}
    return _with_shardings(shapes, shardings)


def train_batch_struct(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> Any:
    B, L = shape.global_batch, shape.seq_len
    specs = shd.train_batch_specs(cfg, mesh, B)
    out = {}
    if cfg.external_embeddings:
        out["embeds"] = jax.ShapeDtypeStruct(
            (B, L, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, specs["embeds"]))
    else:
        out["tokens"] = jax.ShapeDtypeStruct(
            (B, L), jnp.int32, sharding=NamedSharding(mesh, specs["tokens"]))
    out["labels"] = jax.ShapeDtypeStruct(
        (B, L), jnp.int32, sharding=NamedSharding(mesh, specs["labels"]))
    return out


def cache_struct(cfg: ModelConfig, mesh: Mesh, batch: int, s_cache: int) -> Any:
    shapes = jax.eval_shape(lambda: tf.init_caches(cfg, batch, s_cache))
    specs = shd.cache_specs(cfg, mesh, batch, shapes)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return _with_shardings(shapes, shardings)


# --------------------------------------------------------------------------- #
# cells
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    step_fn: Callable
    args: Tuple[Any, ...]
    donate: Tuple[int, ...]
    skip_reason: str = ""
    # explicit output shardings: required — shard_map(EP) inside scan produces
    # GSPMD shardings jax cannot infer back to NamedShardings (KeyError in
    # parse_flatten_op_sharding); specifying outputs sidesteps inference.
    out_shardings: Any = None


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               optc: AdamWConfig | None = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    optc = optc or AdamWConfig()

    if shape.name == "long_500k" and not long_context_ok(arch):
        return Cell(arch, shape, cfg, None, (), (), skip_reason=(
            "pure full-attention arch: 500k-context decode cache/attention "
            "has no sub-quadratic path (DESIGN.md §Arch-applicability)"))

    rep = NamedSharding(mesh, P())

    def shardings_of(tree):
        return jax.tree.map(lambda s: s.sharding, tree)

    if shape.kind == "train":
        fn = step_lib.make_train_step(cfg, optc)
        p = params_struct(cfg, mesh)
        o = opt_struct(cfg, mesh, p)
        b = train_batch_struct(cfg, mesh, shape)
        metrics_sh = {k: rep for k in ("loss", "ce", "aux", "grad_norm", "lr")}
        outs = (shardings_of(p), shardings_of(o), metrics_sh)
        return Cell(arch, shape, cfg, fn, (p, o, b), donate=(0, 1),
                    out_shardings=outs)

    bspec = shd._bspec(cfg, mesh, shape.global_batch)
    logits_sh = NamedSharding(
        mesh, P(bspec, "model" if cfg.padded_vocab % mesh.shape["model"] == 0
                else None))

    if shape.kind == "prefill":
        fn = step_lib.make_prefill_step(cfg, s_cache=shape.seq_len)
        p = params_struct(cfg, mesh)
        b = train_batch_struct(cfg, mesh, shape)
        b.pop("labels")
        c = cache_struct(cfg, mesh, shape.global_batch, s_cache=shape.seq_len)
        outs = (logits_sh, shardings_of(c))
        return Cell(arch, shape, cfg, fn, (p, b), donate=(),
                    out_shardings=outs)

    # decode: one new token against a seq_len-deep cache
    B = shape.global_batch
    p = params_struct(cfg, mesh)
    c = cache_struct(cfg, mesh, B, s_cache=shape.seq_len)
    tok_sh = NamedSharding(mesh, P(bspec, None))
    positions = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
    outs = (logits_sh, shardings_of(c))
    if cfg.external_embeddings:
        emb = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.dtype(cfg.dtype),
                                   sharding=NamedSharding(mesh, P(bspec, None, None)))
        base = step_lib.make_decode_step(cfg)
        fn = lambda params, caches, positions, embeds: base(
            params, caches, None, positions, embeds=embeds)
        return Cell(arch, shape, cfg, fn, (p, c, positions, emb), donate=(1,),
                    out_shardings=outs)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
    fn = step_lib.make_decode_step(cfg)
    return Cell(arch, shape, cfg, fn, (p, c, tokens, positions), donate=(1,),
                out_shardings=outs)
