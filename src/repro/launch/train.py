"""End-to-end training driver.

Usage (host-scale example; production would launch the same file per pod):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
      --steps 100 --batch 8 --seq 128

Wires together: config → params/optimizer init → deterministic pipeline →
pjit'd train step with FSDP/TP shardings → fault-tolerant coordinator
(checkpoint/restart) → metrics log.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, DeterministicPipeline
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.coordinator import Coordinator, RunConfig
from repro.train.step import make_train_step
from repro.core import compat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.external_embeddings:
        raise SystemExit(
            f"{cfg.name} takes stub embeddings; use examples/train_lm.py "
            "with a token arch instead")

    mesh = make_host_mesh()
    print(f"mesh: {dict(mesh.shape)} devices={mesh.size}")
    optc = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    data = DeterministicPipeline(DataConfig(
        seq_len=args.seq, global_batch=args.batch, vocab_size=cfg.vocab_size,
        seed=args.seed))

    step_fn = make_train_step(cfg, optc)

    def init_state_fn():
        params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)
        return {"params": params, "opt": opt}

    with compat.use_mesh(mesh):
        params_shapes = jax.eval_shape(
            lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
        p_sh = shd.param_shardings(params_shapes, cfg, mesh)

        jitted = jax.jit(
            lambda s, b: _wrap_step(step_fn, s, b), donate_argnums=(0,))

        def train_one(state, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            return jitted(state, batch)

        coord = Coordinator(
            RunConfig(total_steps=args.steps,
                      checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.checkpoint_dir),
            train_step=_logging_step(train_one, args.log_every),
            batch_fn=lambda step: data.batch(step),
            init_state_fn=init_state_fn,
        )
        t0 = time.time()
        state = coord.train()
        dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / max(dt, 1e-9):.2f} steps/s); "
          f"events={len(coord.events)}")


def _wrap_step(step_fn, state, batch):
    params, opt, metrics = step_fn(state["params"], state["opt"], batch)
    return {"params": params, "opt": opt}, metrics


def _logging_step(fn, every: int):
    def wrapped(state, batch):
        state, metrics = fn(state, batch)
        step = int(np.asarray(state["opt"]["step"]))
        if step % every == 0 or step == 1:
            loss = float(np.asarray(metrics["loss"]))
            gn = float(np.asarray(metrics["grad_norm"]))
            print(f"step {step:5d}  loss {loss:8.4f}  gnorm {gn:8.3f}",
                  flush=True)
        return state, metrics
    return wrapped


if __name__ == "__main__":
    main()
