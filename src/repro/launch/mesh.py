"""Production mesh definitions (TPU v5e pod slices).

A FUNCTION, not a module constant, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None, data: int | None = None):
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if model is None:
        model = 1
        for m in (8, 4, 2):
            if n % m == 0 and n >= m:
                model = m
                break
    data = data or (n // model)
    return compat.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry pure data parallelism."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)
