"""Memory-augmented serving driver (the paper-native e2e example).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --docs 64 --requests 8

Boots a model, ingests documents through the Valori boundary, serves batched
retrieval-augmented generation, and proves the audit-trail property: replaying
the command log reproduces the memory hash bit-for-bit.

Topology flags (DESIGN.md §7, §8):

  --shards N           sharded-layout engine in one process
  --spawn-shards N     spawn N shard-server subprocesses
                       (``python -m repro.net.server``) and serve through
                       them over the wire protocol — the networked engine
  --hosts a:p,b:p      attach to already-running shard servers instead
  --durable-dir DIR    durable store / coordinator metadata directory
                       (required for --hosts; defaulted for --spawn-shards)
  --replicas K         attach K verified read replicas per shard
                       (DESIGN.md §9); retrieval routes to the pool once
                       the replicas prove the flush cursor. Needs
                       --durable-dir (defaulted when absent).
  --route R            force the read route (exact | hnsw | coarse) or
                       leave the planner to choose (auto, the default);
                       the recorded QueryPlan route is reported either way
  --ef-coarse N        candidate-set size for the compressed coarse tier
                       (DESIGN.md §10); defaulted to cover the corpus when
                       --route coarse is forced without it
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import get_config, get_reduced_config
from repro.core import hnsw
from repro.models import transformer as tf
from repro.net.replica import FollowerPolicy
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig


def _spawn_shard_servers(n: int, capacity: int, dim: int, workdir: str):
    """Start n shard-server subprocesses on ephemeral ports; returns
    (procs, ["127.0.0.1:<port>", ...]) once every server printed its
    LISTENING line (i.e. is accepting connections)."""
    procs, hosts = [], []
    for s in range(n):
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.net.server",
             "--dir", os.path.join(workdir, f"shard_{s}"),
             "--capacity", str(capacity // n), "--dim", str(dim),
             "--port", "0"],
            stdout=subprocess.PIPE, text=True, env=dict(os.environ))
        line = proc.stdout.readline().strip()
        if not line.startswith("LISTENING "):
            raise RuntimeError(f"shard server {s} failed to start: {line!r}")
        hosts.append(f"127.0.0.1:{int(line.split()[1])}")
        procs.append(proc)
    return procs, hosts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--docs", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--doc-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--spawn-shards", type=int, default=0,
                    help="spawn N shard-server subprocesses and serve "
                         "through the wire protocol")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated host:port shard servers "
                         "(needs --durable-dir)")
    ap.add_argument("--durable-dir", default=None)
    ap.add_argument("--replicas", type=int, default=0,
                    help="verified read replicas per shard; retrieval "
                         "routes to the pool at proven cursors")
    ap.add_argument("--follow", action="store_true",
                    help="run the replica pool as live followers: each "
                         "replica tails the primary on a background "
                         "thread (DESIGN.md §12), so reads route to the "
                         "pool without a manual sync_replicas()")
    ap.add_argument("--follow-delay", type=float, default=0.05,
                    help="follower staleness bound in seconds "
                         "(FollowerPolicy.max_delay_s)")
    ap.add_argument("--route", default="auto",
                    choices=["auto", "exact", "hnsw", "coarse"],
                    help="read route: planner's choice (auto) or forced")
    ap.add_argument("--ef-coarse", type=int, default=0,
                    help="coarse-tier candidate-set size (0 disables the "
                         "compressed tier under auto routing)")
    ap.add_argument("--churn", type=int, default=0,
                    help="delete N of the ingested docs before serving — "
                         "exercises entry-point repair + the re-link pass")
    ap.add_argument("--relink-dead-ratio", type=float, default=0.0,
                    help="schedule the deterministic HNSW re-link pass at "
                         "this dead fraction (DESIGN.md §11); 0 disables")
    args = ap.parse_args()
    if args.route == "coarse" and args.ef_coarse <= 0:
        # a forced coarse route needs a candidate-set size; cover the
        # whole corpus, which also makes the answer bit-equal to exact
        args.ef_coarse = max(args.docs, 1)

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.external_embeddings:
        raise SystemExit(f"{cfg.name} takes stub embeddings; pick a token arch")

    hosts = args.hosts.split(",") if args.hosts else None
    n = args.spawn_shards or (len(hosts) if hosts else max(args.shards, 1))
    capacity = max(args.docs * 2, 256)
    capacity += (-capacity) % n  # divide evenly across shards
    durable_dir = args.durable_dir

    procs = []
    if args.spawn_shards:
        workdir = tempfile.mkdtemp(prefix="valori-net-")
        procs, hosts = _spawn_shard_servers(args.spawn_shards, capacity,
                                            cfg.d_model, workdir)
        if durable_dir is None:
            durable_dir = os.path.join(workdir, "coord")
        print(f"spawned {len(procs)} shard servers: {', '.join(hosts)}")

    if args.replicas and durable_dir is None:
        # replicas tail a durable WAL; default one rather than refusing
        durable_dir = tempfile.mkdtemp(prefix="valori-serve-")

    try:
        rng = np.random.default_rng(args.seed)
        params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
        engine = MemoryAugmentedEngine(cfg, params, ServeConfig(
            capacity=capacity, max_new_tokens=args.max_new,
            s_cache=args.doc_len + args.prompt_len + args.max_new + 32,
            context_tokens=min(32, args.doc_len),
            shards=args.shards if hosts is None else 1,
            hosts=hosts, durable_dir=durable_dir,
            replicas=args.replicas,
            follow=(FollowerPolicy(max_delay_s=args.follow_delay)
                    if args.follow else None),
            route=args.route, ef_coarse=args.ef_coarse,
            # floors scaled to the demo corpus so the pass actually fires
            # at launcher scale; production defaults are the dataclass's
            relink=(hnsw.RelinkPolicy(dead_ratio=args.relink_dead_ratio,
                                      min_deletes=1, check_every=1)
                    if args.relink_dead_ratio > 0 else None)))

        docs = rng.integers(0, cfg.vocab_size, (args.docs, args.doc_len),
                            dtype=np.int32)
        t0 = time.time()
        ids = engine.insert_documents(docs)
        print(f"ingested {len(ids)} docs in {time.time() - t0:.2f}s; "
              f"memory hash {engine.memory_hash():#x}")

        if args.churn:
            victims = ids[:min(args.churn, len(ids))]
            removed = engine.delete_documents(victims)
            print(f"churned {removed} docs; graph_gen={engine.graph_gen} "
                  f"(re-links at {engine.relink_ts}); "
                  f"memory hash {engine.memory_hash():#x}")

        if args.replicas and not args.follow:
            lag = engine.sync_replicas()
            print(f"synced {args.replicas} replicas/shard "
                  f"(residual lag {lag} commands)")
        elif args.replicas:
            # live followers: no manual barrier — wait until the pool
            # proves the flush cursor, bounded so a fault is visible
            flush_t = engine.flush()
            deadline = time.time() + 30.0
            while (min(r.t for pool in engine.read_replicas for r in pool)
                   < flush_t):
                if time.time() > deadline:
                    raise SystemExit("followers failed to reach the "
                                     f"flush cursor t={flush_t}")
                time.sleep(0.01)
            print(f"{args.replicas} followers/shard tailed to proven "
                  f"cursor t={flush_t} (no sync_replicas call)")

        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, args.prompt_len),
                               dtype=np.int32)
        nn_ids, scores = engine.retrieve(prompts)
        print("retrieved neighbors:", nn_ids[:, 0].tolist())
        print(f"planned route: {engine.last_plan.route} "
              f"({engine.last_plan.reason}) "
              f"graph_gen={engine.last_plan.graph_gen}")
        if args.replicas:
            print(f"served by: {engine.last_plan.served_by}")

        t0 = time.time()
        out = engine.generate(prompts)
        dt = time.time() - t0
        print(f"generated {args.requests}x{args.max_new} tokens in {dt:.2f}s "
              f"({args.requests * args.max_new / dt:.1f} tok/s)")

        replay_hash = engine.replay_log_fresh()
        live_hash = engine.state_hash()
        assert replay_hash == live_hash, "replay diverged!"
        print(f"audit: replay(S0, log) hash {replay_hash:#x} == live state ✓")
        engine.close()
    finally:
        for proc in procs:
            proc.terminate()
        for proc in procs:
            proc.wait(timeout=10)


if __name__ == "__main__":
    main()
