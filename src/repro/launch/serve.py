"""Memory-augmented serving driver (the paper-native e2e example).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --docs 64 --requests 8

Boots a model, ingests documents through the Valori boundary, serves batched
retrieval-augmented generation, and proves the audit-trail property: replaying
the command log reproduces the memory hash bit-for-bit.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import get_config, get_reduced_config
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--docs", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--doc-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.external_embeddings:
        raise SystemExit(f"{cfg.name} takes stub embeddings; pick a token arch")

    rng = np.random.default_rng(args.seed)
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=max(args.docs * 2, 256), max_new_tokens=args.max_new,
        s_cache=args.doc_len + args.prompt_len + args.max_new + 32,
        context_tokens=min(32, args.doc_len)))

    docs = rng.integers(0, cfg.vocab_size, (args.docs, args.doc_len),
                        dtype=np.int32)
    t0 = time.time()
    ids = engine.insert_documents(docs)
    print(f"ingested {len(ids)} docs in {time.time() - t0:.2f}s; "
          f"memory hash {engine.memory_hash():#x}")

    prompts = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len),
                           dtype=np.int32)
    nn_ids, scores = engine.retrieve(prompts)
    print("retrieved neighbors:", nn_ids[:, 0].tolist())

    t0 = time.time()
    out = engine.generate(prompts)
    dt = time.time() - t0
    print(f"generated {args.requests}x{args.max_new} tokens in {dt:.2f}s "
          f"({args.requests * args.max_new / dt:.1f} tok/s)")

    replay_hash = engine.replay_log_fresh()
    live_hash = engine.state_hash()
    assert replay_hash == live_hash, "replay diverged!"
    print(f"audit: replay(S0, log) hash {replay_hash:#x} == live state ✓")


if __name__ == "__main__":
    main()
