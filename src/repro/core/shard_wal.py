"""Per-shard WALs under one global clock (DESIGN.md §6).

PR 3 made a single-host log durable; this module makes *distributed*
ingest durable without that single-host log. Each shard owns a full
``durability.DurableStore`` (its own hash-chained WAL + chunked snapshots);
a ``ShardedDurableStore`` keeps the fleet in lockstep on one global
applied-command cursor ``t``:

  * every appended batch is routed with ``distributed.route_commands``
    (pure integer id hash) and NOP-padded to one common length, so every
    shard's WAL advances by exactly the same amount per batch — per-shard
    cursors are the global cursor;
  * a group commit (``append_many``, the sink ``wal.GroupCommitWriter``
    drives) flushes each shard's share of the group under one fsync per
    shard;
  * recovery reconciles: each shard recovers its own durable prefix, the
    global cursor is the *minimum* (a command is globally durable only
    when every shard's share of its batch is), and shards that got ahead —
    a crash landed between per-shard flushes — roll their never-globally-
    acked suffix back with ``DurableStore.rollback_to``. The torn-group
    contract thus extends across shards: recovery lands on the last
    globally-whole batch boundary prefix, never a partial group;
  * the merged restore verifies one number: ``hash_pytree`` of the merged
    sharded-layout state, the same whole-state hash ``snapshot_sharded``'s
    merged manifest carries — a pod and a single-kernel holder of the same
    content agree on it.

Shards share one content-addressed ``ChunkStore`` (identical chunks — e.g.
untouched arena regions — are stored once across shards); the sharded
store owns the cross-shard sweep, per-shard ``retain`` never deletes a
chunk another shard still references.

Layout of a store directory:
  store.json                 n_shards
  chunks/<key:016x>.chk      chunk store shared by all shards
  merged/t_<t:020d>.json     global-cursor records: {"t", "hash"}
  shard_<s:04d>/             a full DurableStore per shard (own WAL,
                             snapshots, store.json; chunks redirected up)
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, hashing, hnsw, machine, search, snapshot, wal
from repro.core.commands import CommandLog
from repro.core.durability import _RESTORE_ERRORS, DurableStore
from repro.core.state import MemoryState


class ShardedDurableStore:
    """n_shards lockstep ``DurableStore``s under one global cursor.

    Invariant (healthy store): every shard's durable cursor equals the
    global ``t``, and ``restore_at(t)`` merged across shards is hash-
    identical to applying the same routed batches to a fresh sharded
    genesis — the sharded twin of ``DurableStore``'s replay contract.
    """

    def __init__(self, directory: str | os.PathLike,
                 genesis: Optional[MemoryState] = None, *,
                 n_shards: Optional[int] = None,
                 chunk_size: int = snapshot.DEFAULT_CHUNK_SIZE,
                 segment_records: int = 1024,
                 compaction: Optional[wal.CompactionPolicy] = None,
                 backends: Optional[Sequence] = None):
        """``backends`` makes the store transport-pluggable: instead of
        creating local per-shard ``DurableStore``s, the coordinator drives
        the given shard handles — anything with the ``DurableStore``
        surface (``append_many`` / ``checkpoint`` / ``restore_at`` /
        ``recover`` / ``rollback_to`` / ``retain`` / ``t`` /
        ``wal.read_range``), in practice ``net.RemoteShardClient``s over
        subprocess shard hosts. The directory then holds only the
        coordinator's own artifacts (store.json, merged-hash records);
        each backend owns its chunks and sweeps them itself."""
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        meta_path = self.dir / "store.json"

        if backends is not None:
            if n_shards is not None and n_shards != len(backends):
                raise ValueError(
                    f"{len(backends)} backends given, n_shards={n_shards}")
            n_shards = len(backends)
        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            if n_shards is not None and n_shards != meta["n_shards"]:
                raise ValueError(
                    f"store has {meta['n_shards']} shards, {n_shards} given")
            n_shards = meta["n_shards"]
        else:
            if n_shards is None or (genesis is None and backends is None):
                raise ValueError(
                    f"{self.dir} is not a ShardedDurableStore and no "
                    "(genesis, n_shards) was given to create one")
            tmp = meta_path.with_suffix(".tmp")
            with open(tmp, "w") as f:  # tmp+fsync+rename: a crash leaves a
                f.write(json.dumps({"n_shards": n_shards}))  # stale .tmp,
                f.flush()                                    # never a torn
                os.fsync(f.fileno())                         # store.json
            tmp.rename(meta_path)

        self.n_shards = n_shards
        self._merged_dir = self.dir / "merged"
        self._merged_dir.mkdir(exist_ok=True)
        if backends is not None:
            self.chunks = None  # each backend owns (and sweeps) its chunks
            self.shards = list(backends)
        else:
            self.chunks = snapshot.ChunkStore(self.dir / "chunks")
            self.shards: List[DurableStore] = [
                DurableStore(
                    self.dir / f"shard_{s:04d}",
                    distributed.shard_slice(genesis, s, n_shards)
                    if genesis is not None else None,
                    chunk_size=chunk_size, segment_records=segment_records,
                    compaction=compaction, chunks=self.chunks)
                for s in range(n_shards)
            ]

    # ------------------------------------------------------------------ #
    # the global command stream
    # ------------------------------------------------------------------ #

    @property
    def t(self) -> int:
        """Globally durable logical time: the minimum shard cursor (a
        command counts only once every shard's share of its batch is on
        disk). Equal to every shard's cursor in a healthy store."""
        return min(s.t for s in self.shards)

    def shard_ts(self) -> List[int]:
        """Per-shard durable cursors (diagnostic; all equal when healthy)."""
        return [s.t for s in self.shards]

    def planned_advance(self, log: CommandLog) -> int:
        """Global-cursor advance appending ``log`` will cause: the batch's
        NOP-padded common per-shard length (its heaviest shard's share,
        min 1) — what ``GroupCommitWriter.target_t`` must add per batch
        instead of the raw command count."""
        if len(log) == 0:
            return 0
        owners = np.asarray(distributed.shard_of_id(
            jnp.asarray(np.asarray(log.arg0)), self.n_shards))
        counts = np.bincount(owners, minlength=self.n_shards)
        return max(int(counts.max()), 1)

    def append(self, log: CommandLog, *,
               routed: Optional[CommandLog] = None) -> int:
        """Route one global batch to the shards and durably append each
        share (one fsync per shard); returns the new global cursor. Every
        shard advances by the batch's common padded length. A caller that
        already routed the batch passes ``routed`` to skip re-routing."""
        if routed is not None and len(log):
            return self.append_many_routed([routed])
        return self.append_many([log])

    def append_many(self, logs: Sequence[CommandLog]) -> int:
        """Group commit across shards: each batch is routed exactly as
        ``append`` would route it (per-batch NOP padding, so cursors are
        identical whether or not batches were grouped), then each shard
        commits its whole share of the group under one fsync. Shards are
        flushed in shard order — a crash mid-flush leaves a *prefix* of
        shards with the group, which ``recover()`` rolls back to the last
        globally-complete point."""
        logs = [log for log in logs if len(log)]
        if not logs:
            return self.t
        return self.append_many_routed(
            [distributed.route_commands(log, self.n_shards) for log in logs])

    def append_many_routed(self, routed_logs: Sequence[CommandLog]) -> int:
        """``append_many`` minus the re-route: batches arrive as the
        ``[n_shards, L]`` shares ``distributed.route_commands`` emits (the
        serve engine routes once for audit + apply + durability). Same
        refusal discipline, same per-shard fsync, same bytes. Callers must
        route with this store's shard count and filter empty batches
        themselves (routing pads an empty batch to one NOP, which would
        advance the cursor)."""
        routed_logs = list(routed_logs)
        if not routed_logs:
            return self.t
        for r in routed_logs:
            if r.opcode.shape[0] != self.n_shards:
                raise ValueError(
                    f"routed batch has {r.opcode.shape[0]} shares, store "
                    f"has {self.n_shards} shards")
        # refuse BEFORE anything is fsynced: appending to an unreconciled
        # post-crash store would durably put different batches at the same
        # logical offset on different shards — run recover() first
        if len(set(self.shard_ts())) != 1:
            raise RuntimeError(
                f"shard cursors diverged ({self.shard_ts()}): the store "
                "needs recover() before it can accept new appends")
        per_shard: List[List[CommandLog]] = [
            [jax.tree.map(lambda a, s=s: a[s], r) for r in routed_logs]
            for s in range(self.n_shards)]
        ts = [self.shards[s].append_many(per_shard[s])
              for s in range(self.n_shards)]
        assert len(set(ts)) == 1, f"lockstep violated: {ts}"
        return ts[0]

    # ------------------------------------------------------------------ #
    # checkpoints + the merged-hash contract
    # ------------------------------------------------------------------ #

    def _merged_path(self, t: int) -> pathlib.Path:
        return self._merged_dir / f"t_{t:020d}.json"

    def merged_records(self) -> List[int]:
        """Cursors with a recorded merged whole-state hash, ascending."""
        return sorted(int(p.stem.split("_")[1])
                      for p in self._merged_dir.glob("t_*.json"))

    def checkpoint(self, state: MemoryState) -> Dict[str, int]:
        """Snapshot a sharded-layout state: one v2 snapshot per shard (into
        the shared chunk store) plus a merged record carrying the whole-
        state hash — the same combined-hash contract as
        ``distributed.snapshot_sharded``, so restore can verify the merge
        against one number. The state's per-shard cursors must agree (a
        mid-batch or diverged state is not a global checkpoint)."""
        host = jax.tree.map(np.asarray, state)
        versions = {int(v) for v in np.asarray(host.version)}
        if len(versions) != 1:
            raise ValueError(
                f"per-shard cursors disagree ({sorted(versions)}): "
                "checkpoint only at global batch boundaries")
        t = versions.pop()
        stats: Dict[str, int] = {"t": t, "bytes_written": 0}
        for s in range(self.n_shards):
            sh = self.shards[s].checkpoint(
                distributed.shard_slice(host, s, self.n_shards))
            stats["bytes_written"] += sh.get("bytes_written", 0)
        record = {"t": t, "hash": f"{hashing.hash_pytree(host):#018x}"}
        tmp = self._merged_path(t).with_suffix(".tmp")
        with open(tmp, "w") as f:
            f.write(json.dumps(record))
            f.flush()
            os.fsync(f.fileno())
        tmp.rename(self._merged_path(t))
        return stats

    def _verify_merged(self, t: int, h: int) -> None:
        path = self._merged_path(t)
        if not path.exists():
            return
        stored = int(json.loads(path.read_text())["hash"], 16)
        if stored != h:
            raise ValueError(
                f"merged-state hash mismatch at t={t}: manifest "
                f"{stored:#x}, restored {h:#x}")

    # ------------------------------------------------------------------ #
    # restore + recovery
    # ------------------------------------------------------------------ #

    def restore_at(self, t: int, *, ef_construction: int = 32
                   ) -> Tuple[MemoryState, int]:
        """The merged sharded-layout state as of global command ``t`` —
        each shard restores its own cursor-``t`` state (nearest snapshot +
        WAL tail), the merge is hash-verified against the merged record
        when one exists at ``t``. Returns (state, hash)."""
        parts = [s.restore_at(t, ef_construction=ef_construction)[0]
                 for s in self.shards]
        state = distributed.merge_shards(parts)
        h = hashing.hash_pytree(state)
        self._verify_merged(t, h)
        return state, h

    def recover(self, *, ef_construction: int = 32
                ) -> Tuple[MemoryState, int, int]:
        """Crash recovery with cross-shard reconciliation. Each shard
        recovers its own durable prefix; the global cursor is the minimum
        (commands beyond it were never globally acked); shards that got
        ahead — the crash hit between per-shard group flushes — roll back
        their unacked suffix so the fleet rejoins lockstep. Returns
        (merged state, hash, t); the hash is verified against the merged
        record when one exists at the reconciled cursor.

        Reconciliation is transport-agnostic: it drives only the backend
        surface (``recover`` / ``t`` / ``rollback_to``), and the wire
        client maps server refusals into the same exception families the
        local error envelopes catch (``net.RemoteError`` is a ValueError,
        ``net.TransportError`` an OSError — both in ``_RESTORE_ERRORS``).
        A remote shard reporting a stale cursor therefore rolls the ahead
        shards back exactly as a local one does (the regression
        tests/test_replication.py pins)."""
        ts = []
        for s, shard in enumerate(self.shards):
            try:
                ts.append(shard.recover(
                    ef_construction=ef_construction)[2])
            except _RESTORE_ERRORS as e:
                raise ValueError(
                    f"shard {s} has no recoverable state") from e
        t = min(ts)
        for s, shard in enumerate(self.shards):
            if shard.t > t:
                try:
                    shard.rollback_to(t)
                except ValueError as e:
                    raise ValueError(
                        f"shard {s} cannot rejoin the global cursor t={t} "
                        f"(its durable history has a hole there); the "
                        f"store is irreconcilable without that history"
                    ) from e
        state, h = self.restore_at(t, ef_construction=ef_construction)
        return state, h, t

    def rollback_to(self, t: int) -> None:
        """Drop every durable artifact above global time ``t`` on every
        shard (per-shard ``DurableStore.rollback_to``), then prune merged
        records above ``t`` — the sharded twin of the single-store
        rollback, used by the serve engine's time travel. A failure
        partway through the shard loop leaves cursors diverged exactly
        like a crash between per-shard flushes would; ``recover()``
        reconciles it the same way (min cursor, ahead shards roll back)."""
        if t > self.t:
            raise ValueError(f"rollback_to({t}) is ahead of the globally "
                             f"durable cursor {self.t}")
        for shard in self.shards:
            if shard.t > t:
                shard.rollback_to(t)
        for rec_t in self.merged_records():
            if rec_t > t:
                self._merged_path(rec_t).unlink()

    def shard_logs(self, t0: int, t1: int) -> List[CommandLog]:
        """Each shard's durable commands [t0, t1) — the per-shard audit
        logs (routed, NOP-padded to lockstep). Replaying shard ``s``'s log
        on its genesis slice re-derives its exact state: the sharded form
        of the single-host replay audit. Raises ValueError when retention
        dropped that history on any shard."""
        return [s.wal.read_range(t0, t1) for s in self.shards]

    # ------------------------------------------------------------------ #
    # retention
    # ------------------------------------------------------------------ #

    def retain(self, keep: int) -> Dict[str, int]:
        """Keep the newest ``keep`` snapshots per shard, then sweep shared
        chunks no *surviving manifest of any shard* references — the cross-
        shard gc a per-shard retain cannot safely do. Merged records below
        the new window are pruned with the snapshots they described."""
        stats = {"snapshots_dropped": 0, "wal_segments_dropped": 0,
                 "chunks_dropped": 0}
        oldest_parts = []
        for shard in self.shards:
            sh = shard.retain(keep)
            stats["snapshots_dropped"] += sh["snapshots_dropped"]
            stats["wal_segments_dropped"] += sh["wal_segments_dropped"]
            oldest_parts.append(sh["oldest_snapshot"])
            if self.chunks is None:
                # remote backends own their chunks and already swept them;
                # their per-shard counts roll up instead of a local sweep
                stats["chunks_dropped"] += sh.get("chunks_dropped", 0)
        if self.chunks is not None:
            referenced = set()
            for shard in self.shards:
                referenced |= shard.referenced_chunk_keys()
            for key in self.chunks.keys():
                if key not in referenced:
                    self.chunks.delete(key)
                    stats["chunks_dropped"] += 1
        oldest = min(oldest_parts, default=0)
        for t in self.merged_records():
            if t < oldest:
                self._merged_path(t).unlink()
        return stats


# --------------------------------------------------------------------------- #
# host-side sharded apply + search (the mesh-free twins of distributed.py)
# --------------------------------------------------------------------------- #


def live_count(state: MemoryState) -> int:
    """Total live rows of a MemoryState in either layout (flat scalar
    ``count`` or sharded ``[n_shards]`` counts) — the planner fact the
    serve engine feeds ``query.plan_query`` regardless of mode."""
    return int(np.asarray(state.count).sum())


def bulk_apply_sharded(state: MemoryState, log: CommandLog, n_shards: int,
                       *, ef_construction: int = 32,
                       routed: Optional[CommandLog] = None,
                       device: Optional[bool] = None) -> MemoryState:
    """Route a global batch and apply each shard's share to its slice of a
    sharded-layout state — the in-memory reference for what a
    ``ShardedDurableStore`` ingest makes durable: applying the same batches
    here and recovering the store yield hash-identical merged states.
    Callers that already routed the batch (the serve engine routes once for
    audit + apply + append) pass ``routed`` to skip re-routing.

    ``device`` picks the apply driver. ``True`` runs every shard's share in
    one jitted vmapped scan on device (``apply_routed_device``); ``False``
    runs the host loop of per-shard ``machine.bulk_apply`` (whose
    segmentation planner is host-side but wins on long shares); ``None``
    (default) auto-selects: device for shares up to ``_DEVICE_APPLY_MAX``
    commands — the serve-traffic regime — host for bulk loads beyond it.
    All three are bit-identical (``bulk_apply == replay`` is proven by
    tests/test_bulk_apply.py; the device path IS the replay scan)."""
    if routed is None:
        routed = distributed.route_commands(log, n_shards)
    if device is None:
        device = int(routed.opcode.shape[1]) <= _DEVICE_APPLY_MAX
    if device:
        return apply_routed_device(state, routed, n_shards,
                                   ef_construction=ef_construction)
    parts = []
    for s in range(n_shards):
        local = distributed.shard_slice(state, s, n_shards)
        local_log = jax.tree.map(lambda a, s=s: a[s], routed)
        parts.append(machine.bulk_apply(local, local_log,
                                        ef_construction=ef_construction))
    return distributed.merge_shards(parts)


# --------------------------------------------------------------------------- #
# device-side routed apply (DESIGN.md §11): no host round-trip per shard
# --------------------------------------------------------------------------- #

# auto-route threshold: shares at or under this many commands take the
# vmapped device scan; longer shares amortize bulk_apply's host-side
# segmentation planner instead
_DEVICE_APPLY_MAX = 128


def shard_stack(state: MemoryState, n_shards: int) -> MemoryState:
    """Sharded layout → stacked layout: every array gains a leading
    [n_shards] axis whose lanes are exactly ``distributed.shard_slice``'s
    per-shard states (pure reshapes/transposes, no copies of row data).
    The result is a vmap-ready pytree, not a valid flat MemoryState —
    ``shard_unstack`` is the inverse."""
    cap = state.capacity // n_shards

    def rows(a):  # [n_shards*cap, ...] → [n_shards, cap, ...]
        return a.reshape((n_shards, cap) + a.shape[1:])

    nb = state.hnsw_neighbors  # [levels, n_shards*cap, degree]
    nb = jnp.moveaxis(
        nb.reshape(nb.shape[0], n_shards, cap, nb.shape[2]), 1, 0)
    return dataclasses.replace(
        state,
        vectors=rows(state.vectors), ids=rows(state.ids),
        valid=rows(state.valid), links=rows(state.links),
        meta=rows(state.meta), hnsw_neighbors=nb,
        hnsw_levels=rows(state.hnsw_levels),
        # hnsw_entry / cursor / count / version are already [n_shards]
    )


def shard_unstack(stacked: MemoryState, n_shards: int) -> MemoryState:
    """Inverse of ``shard_stack``: back to the shard-major sharded layout."""
    def rows(a):  # [n_shards, cap, ...] → [n_shards*cap, ...]
        return a.reshape((-1,) + a.shape[2:])

    nb = jnp.moveaxis(stacked.hnsw_neighbors, 0, 1)  # [lv, ns, cap, deg]
    nb = nb.reshape(nb.shape[0], -1, nb.shape[3])
    return dataclasses.replace(
        stacked,
        vectors=rows(stacked.vectors), ids=rows(stacked.ids),
        valid=rows(stacked.valid), links=rows(stacked.links),
        meta=rows(stacked.meta), hnsw_neighbors=nb,
        hnsw_levels=rows(stacked.hnsw_levels),
    )


def _pad_routed(routed: CommandLog, target: int) -> CommandLog:
    """NOP-pad every shard's share from its routed length to ``target``
    (pow2 buckets keep jit shapes logarithmic, exactly like
    ``machine._pad_log``). All-zero records are NOPs."""
    n = int(routed.opcode.shape[1])
    if n == target:
        return routed
    pad = target - n
    ns = int(routed.opcode.shape[0])

    def z(a):
        return jnp.concatenate(
            [a, jnp.zeros((ns, pad) + a.shape[2:], a.dtype)], axis=1)

    return CommandLog(opcode=z(routed.opcode), arg0=z(routed.arg0),
                      arg1=z(routed.arg1), arg2=z(routed.arg2),
                      vec=z(routed.vec))


@partial(jax.jit, static_argnames=("ef_construction",))
def _apply_routed_stacked(stacked: MemoryState, routed: CommandLog,
                          n_real: jax.Array, *, ef_construction: int
                          ) -> MemoryState:
    """vmap-of-scan: every shard replays its (padded) share in lockstep on
    device. ``n_real`` is the routed share length — the pow2 NOP padding
    must not advance logical time, so ``version`` is pinned to base +
    n_real afterwards (the ``_apply_seq_segment`` rule; the routing NOPs
    *inside* the share do advance it, as on every other path)."""
    def per_shard(local: MemoryState, share: CommandLog) -> MemoryState:
        def step(s, rec):
            return machine.apply_command(
                s, rec, ef_construction=ef_construction), None

        out, _ = jax.lax.scan(step, local, share)
        return dataclasses.replace(out, version=local.version + n_real)

    return jax.vmap(per_shard)(stacked, routed)


def apply_routed_device(state: MemoryState, routed: CommandLog,
                        n_shards: int, *, ef_construction: int = 32
                        ) -> MemoryState:
    """Apply an already-routed batch to a sharded-layout state entirely on
    device: one reshape in, one jitted vmapped scan, one reshape out — no
    per-shard host loop, no host-side segmentation round-trip. Bit-identical
    to the host ``bulk_apply`` driver (both equal per-shard ``replay``)."""
    n_real = int(routed.opcode.shape[1])
    padded = _pad_routed(routed, machine._pow2(n_real))
    stacked = shard_stack(state, n_shards)
    out = _apply_routed_stacked(
        stacked, padded, jnp.asarray(n_real, stacked.version.dtype),
        ef_construction=ef_construction)
    return shard_unstack(out, n_shards)


def relink_sharded(state: MemoryState, n_shards: int, *,
                   ef_construction: int = 32) -> MemoryState:
    """Re-link every shard's graph from its own live rows (DESIGN.md §11):
    the sharded twin of ``hnsw.relink``, applied slice-by-slice so each
    shard lands on exactly the graph ``hnsw.fresh_build`` of its slice
    lands on. Arena untouched; only the graph arrays and entries move."""
    parts = []
    for s in range(n_shards):
        local = distributed.shard_slice(state, s, n_shards)
        parts.append(hnsw.relink(local, ef_construction=ef_construction))
    return distributed.merge_shards(parts)


def exact_search_sharded(state: MemoryState, n_shards: int,
                         queries_raw: jax.Array, k: int, *,
                         metric: str = search.METRIC_L2,
                         use_kernel: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    """Exact k-NN over a host-side sharded-layout state: per-shard top-k
    then the one shared (score, id) combine — bit-identical to
    ``distributed.distributed_search`` on a mesh and to a single kernel
    holding the same rows (the merge is permutation-invariant). Returns
    (ids [nq, k], scores [nq, k])."""
    ids_parts, score_parts = [], []
    for s in range(n_shards):
        local = distributed.shard_slice(state, s, n_shards)
        ids, scores = search.exact_search(local, queries_raw, k,
                                          metric=metric,
                                          use_kernel=use_kernel)
        ids_parts.append(ids)
        score_parts.append(scores)
    flat_ids = jnp.concatenate(ids_parts, axis=-1)
    flat_scores = jnp.concatenate(score_parts, axis=-1)
    s_out, i_out = search.merge_candidates(flat_scores, flat_ids, k)
    return i_out, s_out


def coarse_search_sharded(state: MemoryState, n_shards: int,
                          queries_raw: jax.Array, k: int, *,
                          ef_coarse: int, metric: str = search.METRIC_L2,
                          use_kernel: bool = False,
                          tables: Optional[Sequence] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """The compressed tier over a host-side sharded-layout state: each
    shard coarse-scans its own int8 code table and re-ranks exactly, the
    per-shard top-k candidates combine with the one shared (score, id)
    merge — the sharded twin of ``search.coarse_search``. Served scores
    are exact Q16.16 on every path, so whenever every shard's candidate
    set covers its slice (``ef_coarse`` >= per-shard live count) the
    answer is bit-identical to ``exact_search_sharded`` — and therefore
    to the flat scan (DESIGN.md §10). ``tables[s]``, when given, must be
    ``codes.build`` of shard s's slice (the engine maintains exactly
    that); otherwise each shard derives its table on the spot. Returns
    (ids [nq, k], scores [nq, k])."""
    from repro.core import codes as codes_lib  # lazy: leaf-level module

    ids_parts, score_parts = [], []
    for s in range(n_shards):
        local = distributed.shard_slice(state, s, n_shards)
        table = tables[s] if tables is not None else codes_lib.build(local)
        ids, scores = search.coarse_search(local, table, queries_raw, k,
                                           ef_coarse=ef_coarse,
                                           metric=metric,
                                           use_kernel=use_kernel)
        ids_parts.append(ids)
        score_parts.append(scores)
    flat_ids = jnp.concatenate(ids_parts, axis=-1)
    flat_scores = jnp.concatenate(score_parts, axis=-1)
    s_out, i_out = search.merge_candidates(flat_scores, flat_ids, k)
    return i_out, s_out


def hnsw_search_sharded(state: MemoryState, n_shards: int,
                        queries_raw: jax.Array, k: int, *, ef: int = 64
                        ) -> Tuple[jax.Array, jax.Array]:
    """ANN over a host-side sharded-layout state: each shard runs the
    vmapped deterministic beam search over its own graph, candidates
    combine with the one order-invariant (score, id) merge — the mesh-free
    twin of ``distributed.distributed_hnsw_search``. Deterministic for any
    shard count; bit-identical to a flat graph's answer whenever every
    beam is exhaustive over its slice (``ef`` >= per-shard live count),
    which is the regime the conformance suite pins (DESIGN.md §7).
    Returns (ids [nq, k], dists [nq, k])."""
    from repro.core import query as query_lib  # lazy: query imports us lazily

    ids_parts, dist_parts = [], []
    for s in range(n_shards):
        local = distributed.shard_slice(state, s, n_shards)
        ids, dists, _ = query_lib.batched_hnsw_search(local, queries_raw, k,
                                                      ef=ef)
        ids_parts.append(ids)
        dist_parts.append(dists)
    flat_ids = jnp.concatenate(ids_parts, axis=-1)
    flat_d = jnp.concatenate(dist_parts, axis=-1)
    d_out, i_out = search.merge_candidates(flat_d, flat_ids, k)
    return i_out, d_out
