"""Segmented write-ahead log for the command stream (DESIGN.md §5).

The command log IS the memory (paper §3.1) — so durability means making the
log itself durable, not the state. This module persists ``CommandLog``
records in append-only segment files with a per-segment FNV-1a hash chain:

Segment file ``seg_<base_t:020d>.wal`` (all little-endian):

  header:  magic 'VWSG' | u32 fmt=1 | u32 dim | u32 vec-itemsize
           | u64 base_t (logical index of the first command in the file)
           | str contract (u32 len + utf8)
           | u64 chain_0 = FNV-1a(header bytes)      — seeds the chain
  record:  u32 storage-op | i64 arg0 | i64 arg1 | i64 arg2
           | vec payload (dim * itemsize bytes, INSERT records only)
           | u64 chain_i = (chain_{i-1} ^ digest(record bytes)) * FNV_PRIME
             — an FNV-1a chain over per-record word digests
             (hashing.digest_bytes: vectorized, so appends stay cheap)

Storage ops are the machine opcodes (0..5) plus ``NOP_RUN`` (0xFFFFFFFE):
a run of k zero-argument NOPs stored as one record with arg0 = k. NOPs are
what routing pads with and what ``compact_log`` folds dead commands into,
so run-length encoding them is where compaction's disk win comes from.
Non-INSERT records carry no vector payload (F never reads ``vec`` outside
INSERT), so the WAL canonicalizes those payloads to zero on read-back —
replay of a round-tripped log is bit-identical by construction.

Crash safety: a torn write leaves a partial record or a record whose chain
word no longer matches; ``_read_segment(strict=False)`` keeps the longest
valid record prefix, which is exactly the durable prefix of the log. On
open, ``WriteAheadLog`` truncates a torn tail in place so later appends
extend a clean chain. Group commit (``append_many`` /
``GroupCommitWriter``) batches many logs under one fsync; the torn-tail
contract is unchanged and record-granular — a crash mid-group keeps the
longest whole-record prefix of the group, never a partial record
(DESIGN.md §6).

``compact_log`` rewrites provably-dead commands as NOPs while keeping the
log the same length (logical time must not shift), under the *bit-exact*
contract ``hash(bulk_apply(genesis, compact(log))) == hash(replay(genesis,
log))`` — see DESIGN.md §5 for which folds are admissible and why INSERT→
DELETE pairs are not (slot allocation and HNSW waypoints survive deletion).
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import pathlib
import struct
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.commands import (DELETE, INSERT, LINK, NOP, SET_META, UNLINK,
                                 CommandLog)
from repro.core.contracts import (DEFAULT_CONTRACT, PrecisionContract,
                                  get_contract)
from repro.core.state import MemoryState

SEGMENT_MAGIC = b"VWSG"
SEGMENT_FORMAT = 1
NOP_RUN = 0xFFFFFFFE  # storage-only opcode: arg0 zero-NOPs in one record

_U64 = (1 << 64) - 1


_fnv1a = hashing._fnv1a_bytes  # header hashing (small payloads)


def _chain_step(chain: int, body: bytes) -> int:
    """One FNV-1a step over the record's word digest."""
    return ((chain ^ hashing.digest_bytes(body)) * hashing.FNV_PRIME) & _U64


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


# --------------------------------------------------------------------------- #
# durability policies (DESIGN.md §6)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class GroupCommitPolicy:
    """When a ``GroupCommitWriter`` flushes its pending group.

    ``max_batch``: flush once this many commands are pending (the batched-
    fsync knob — one fsync then covers the whole group). ``max_delay_s``:
    flush when the oldest pending command has waited this long. By default
    the deadline is checked at ``submit()``/``flush()`` time only (no timer
    thread), so pair it with a sync-on-read barrier for a hard visibility
    bound. With ``timer_flush=True`` the writer runs a daemon thread that
    flushes the pending group when the oldest command's deadline passes —
    ``max_delay_s`` then holds as a wall-clock durability bound even when
    no read or submit ever arrives (DESIGN.md §7)."""
    max_batch: int = 64
    max_delay_s: float = 0.010
    timer_flush: bool = False

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When scheduled compaction rewrites the WAL (DESIGN.md §6).

    Every ``check_every`` appended commands (and only once the log holds at
    least ``min_commands``), the dead-command ratio is measured with one
    host mirror pass; the on-disk rewrite runs only when folded / n reaches
    ``dead_ratio`` — so a write-once workload never pays a rewrite, and a
    churn-heavy one compacts as soon as enough of its history is provably
    dead."""
    dead_ratio: float = 0.5
    min_commands: int = 1024
    check_every: int = 1024

    def __post_init__(self):
        if not 0.0 < self.dead_ratio <= 1.0:
            raise ValueError("dead_ratio must be in (0, 1]")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")


# --------------------------------------------------------------------------- #
# segment encode / decode
# --------------------------------------------------------------------------- #


def _segment_header(dim: int, itemsize: int, base_t: int,
                    contract_name: str) -> bytes:
    hdr = (SEGMENT_MAGIC + struct.pack("<III", SEGMENT_FORMAT, dim, itemsize)
           + struct.pack("<Q", base_t) + _pack_str(contract_name))
    return hdr + struct.pack("<Q", _fnv1a(hdr))


def _encode_record(op: int, a0: int, a1: int, a2: int,
                   vec_bytes: bytes, chain: int) -> Tuple[bytes, int]:
    body = struct.pack("<Iqqq", op, a0, a1, a2)
    if op == INSERT:
        body += vec_bytes
    chain = _chain_step(chain, body)
    return body + struct.pack("<Q", chain), chain


@dataclasses.dataclass
class _SegmentData:
    base_t: int
    n_commands: int          # logical commands (NOP runs expanded)
    clean: bool              # chain verified through EOF
    valid_bytes: int         # offset of the last valid record boundary
    chain: int               # chain value at the last valid record
    contract_name: str       # precision contract recorded in the header
    fields: Dict[str, np.ndarray]  # opcode/arg0/arg1/arg2/vec, expanded
    header_bytes: int        # byte offset where records start
    bounds: List[Tuple[int, int]]  # per record: (offset after, cum commands)


def _read_segment(path: pathlib.Path, *, strict: bool = True,
                  expect_dim: Optional[int] = None) -> _SegmentData:
    data = path.read_bytes()
    off = 0

    def fail(msg):
        raise ValueError(f"{path.name}: {msg}")

    if data[:4] != SEGMENT_MAGIC:
        fail("not a WAL segment")
    fmt, dim, itemsize = struct.unpack_from("<III", data, 4)
    if fmt != SEGMENT_FORMAT:
        fail(f"unsupported WAL format {fmt}")
    off = 16
    (base_t,) = struct.unpack_from("<Q", data, off)
    off += 8
    (n,) = struct.unpack_from("<I", data, off)
    contract_name = data[off + 4:off + 4 + n].decode()
    off += 4 + n
    get_contract(contract_name)  # validates
    if expect_dim is not None and dim != expect_dim:
        fail(f"dim mismatch: segment {dim}, expected {expect_dim}")
    (chain,) = struct.unpack_from("<Q", data, off)
    if chain != _fnv1a(data[:off]):
        fail("corrupt segment header")
    off += 8

    vec_nbytes = dim * itemsize
    header_bytes = off
    bounds: List[Tuple[int, int]] = []
    ops: List[int] = []
    a0s: List[int] = []
    a1s: List[int] = []
    a2s: List[int] = []
    vecs: List[Tuple[int, bytes]] = []  # (record index, payload) sparse
    clean = True
    valid_bytes = off
    n_commands = 0
    while off < len(data):
        if off + 28 + 8 > len(data):
            clean = False
            break
        op, a0, a1, a2 = struct.unpack_from("<Iqqq", data, off)
        body_len = 28 + (vec_nbytes if op == INSERT else 0)
        if off + body_len + 8 > len(data):
            clean = False
            break
        body = data[off:off + body_len]
        (stored,) = struct.unpack_from("<Q", data, off + body_len)
        next_chain = _chain_step(chain, body)
        if stored != next_chain:
            clean = False
            break
        chain = next_chain
        off += body_len + 8
        valid_bytes = off
        if op == NOP_RUN:
            if a0 < 0:
                clean = False
                valid_bytes -= body_len + 8
                break
            ops.extend([NOP] * a0)
            a0s.extend([0] * a0)
            a1s.extend([0] * a0)
            a2s.extend([0] * a0)
            n_commands += int(a0)
        else:
            if op == INSERT:
                vecs.append((len(ops), body[28:]))
            ops.append(op)
            a0s.append(a0)
            a1s.append(a1)
            a2s.append(a2)
            n_commands += 1
        bounds.append((off, n_commands))
    if strict and not clean:
        fail(f"torn/corrupt record at byte {valid_bytes}")

    vdt = np.dtype(f"<i{itemsize}")
    vec = np.zeros((n_commands, dim), vdt)
    for idx, payload in vecs:
        vec[idx] = np.frombuffer(payload, dtype=vdt)
    fields = dict(
        opcode=np.asarray(ops, np.int32), arg0=np.asarray(a0s, np.int64),
        arg1=np.asarray(a1s, np.int64), arg2=np.asarray(a2s, np.int64),
        vec=vec,
    )
    return _SegmentData(base_t=base_t, n_commands=n_commands, clean=clean,
                        valid_bytes=valid_bytes, chain=chain,
                        contract_name=contract_name, fields=fields,
                        header_bytes=header_bytes, bounds=bounds)


# --------------------------------------------------------------------------- #
# the WAL
# --------------------------------------------------------------------------- #


class WriteAheadLog:
    """Append-only, segmented, hash-chained command log on disk.

    ``t`` is the monotone applied-command cursor: the logical index of the
    next command to be appended. ``read_range(t0, t1)`` returns the commands
    [t0, t1) as a ``CommandLog``; replaying a round-tripped range is
    bit-identical to replaying the original commands.
    """

    def __init__(self, directory: str | os.PathLike, dim: Optional[int] = None,
                 contract: Optional[PrecisionContract] = None, *,
                 segment_records: int = 1024):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.contract = contract  # None ⇒ adopt from segment headers
        self.segment_records = segment_records
        self.torn_tail_dropped = 0  # bytes truncated from a torn tail on open

        self._repair_interrupted_compaction()
        for stale in self.dir.glob("*.tmp"):  # stillborn segment creations
            if stale.is_file():
                stale.unlink()

        self._segments: List[Tuple[int, pathlib.Path, int]] = []  # (base, path, n)
        paths = sorted(self.dir.glob("seg_*.wal"))
        self._dim = dim
        tail_seg: Optional[_SegmentData] = None
        for i, p in enumerate(paths):
            last = i == len(paths) - 1
            if last:
                try:
                    seg = _read_segment(p, strict=False)
                except (ValueError, struct.error):  # short header ⇒ struct
                    # stillborn tail: the crash tore the header itself.
                    # Headers are fsynced at creation before any record can
                    # be appended, so an unreadable header implies zero
                    # durable records — dropping the file loses nothing.
                    self.torn_tail_dropped += p.stat().st_size
                    p.unlink()
                    continue
                if (self._dim is not None
                        and seg.fields["vec"].shape[1] != self._dim):
                    raise ValueError(
                        f"{p.name}: dim {seg.fields['vec'].shape[1]} != "
                        f"expected {self._dim}")
            else:
                seg = _read_segment(p, strict=True, expect_dim=self._dim)
            if self._dim is None:
                self._dim = seg.fields["vec"].shape[1]
            # the header is authoritative for the storage contract: reopening
            # with a mismatched (or defaulted) contract would wrap-cast
            # read_range payloads into the wrong dtype with no error
            hdr_contract = get_contract(seg.contract_name)
            if self.contract is None:
                self.contract = hdr_contract
            elif self.contract.name != hdr_contract.name:
                raise ValueError(
                    f"{p.name}: segment contract {hdr_contract.name!r} != "
                    f"given contract {self.contract.name!r}")
            if not seg.clean:
                # torn tail: truncate to the longest valid record prefix so
                # future appends extend a verified chain
                self.torn_tail_dropped += p.stat().st_size - seg.valid_bytes
                with open(p, "r+b") as f:
                    f.truncate(seg.valid_bytes)
                    f.flush()
                    os.fsync(f.fileno())
            self._segments.append((seg.base_t, p, seg.n_commands))
            if last:
                tail_seg = seg
        if self._dim is None:
            raise ValueError("empty WAL directory needs an explicit dim")
        if self.contract is None:  # fresh, empty WAL with no override
            self.contract = DEFAULT_CONTRACT
        self._last_compact_check = 0  # cursor at the last policy check

        if self._segments:
            if tail_seg is None:  # stillborn tail was dropped: the previous
                tail_seg = _read_segment(  # segment is the live tail now
                    self._segments[-1][1], strict=True, expect_dim=self._dim)
            base, _, n = self._segments[-1]
            self.t = base + n
            self._chain = tail_seg.chain
            self._cur_records = n
        else:
            self.t = 0
            self._chain = None   # set when the first segment is created
            self._cur_records = 0

    # ------------------------------------------------------------------ #
    @property
    def dim(self) -> int:
        return self._dim

    def segments(self) -> List[Tuple[int, int]]:
        """[(base_t, n_commands)] in order."""
        return [(b, n) for b, _, n in self._segments]

    def _itemsize(self) -> int:
        return np.dtype(jnp.dtype(self.contract.storage_dtype).name).itemsize

    def _open_segment(self) -> None:
        path = self.dir / f"seg_{self.t:020d}.wal"
        hdr = _segment_header(self._dim, self._itemsize(), self.t,
                              self.contract.name)
        tmp = path.with_suffix(".wal.tmp")
        with open(tmp, "wb") as f:  # fsync+rename: a crash can leave a
            f.write(hdr)            # stale .tmp (ignored on open), never a
            f.flush()               # torn header at the live name
            os.fsync(f.fileno())
        tmp.rename(path)
        self._chain = _fnv1a(hdr[:-8])
        self._segments.append((self.t, path, 0))
        self._cur_records = 0

    # ------------------------------------------------------------------ #
    def _validated_fields(self, log: CommandLog) -> Tuple[np.ndarray, ...]:
        opcode = np.asarray(log.opcode)
        arg0 = np.asarray(log.arg0)
        arg1 = np.asarray(log.arg1)
        arg2 = np.asarray(log.arg2)
        vec = np.asarray(log.vec)
        if vec.shape[1] != self._dim:
            raise ValueError(f"log dim {vec.shape[1]} != WAL dim {self._dim}")
        expected = np.dtype(jnp.dtype(self.contract.storage_dtype).name)
        if vec.dtype != expected:
            # a mismatched itemsize would desync record framing — every
            # later record would read as torn and be silently discarded
            raise ValueError(
                f"log vec dtype {vec.dtype} != WAL storage dtype {expected}")
        return opcode, arg0, arg1, arg2, vec

    def append(self, log: CommandLog) -> int:
        """Durably append a command log; returns the new cursor ``t``.

        Invariant: on return every record is fsynced, so a crash can only
        lose commands the caller was never acked for. One fsync per touched
        segment — batching commands into one ``append`` (or using
        ``append_many`` / ``GroupCommitWriter``) amortizes that cost."""
        if len(log) == 0:
            return self.t
        return self._append_fields(*self._validated_fields(log))

    def append_many(self, logs: Sequence[CommandLog]) -> int:
        """Group commit: durably append several command logs with a single
        fsync per touched segment (usually exactly one), instead of one per
        log. Returns the new cursor ``t``.

        Durability is acknowledged for the whole group at once; the torn-
        tail contract is unchanged and record-granular — a crash inside the
        group's write leaves the longest valid *record* prefix (never a
        partial record, possibly a partial group), which recovery truncates
        to exactly as for single appends."""
        logs = [log for log in logs if len(log)]
        if not logs:
            return self.t
        fields = [self._validated_fields(log) for log in logs]
        # NOP runs must not merge across log boundaries: each log's records
        # are encoded exactly as a lone append would encode them, so the
        # grouped segment bytes equal the ungrouped ones (the §6 audit
        # contract tests/test_group_commit.py pins byte-for-byte)
        breaks, acc = set(), 0
        for f in fields[:-1]:
            acc += len(f[0])
            breaks.add(acc)
        return self._append_fields(
            *(np.concatenate([f[j] for f in fields]) for j in range(5)),
            run_breaks=frozenset(breaks))

    def _append_fields(self, opcode, arg0, arg1, arg2, vec, *,
                       run_breaks: frozenset = frozenset()) -> int:
        n = len(opcode)
        vdt = vec.dtype.newbyteorder("<")

        i = 0
        while i < n:
            if self._chain is None or self._cur_records >= self.segment_records:
                self._open_segment()
            room = self.segment_records - self._cur_records
            stop = min(n, i + room)
            buf = bytearray()
            chain = self._chain
            wrote = 0
            while i < stop:
                op = int(opcode[i])
                if (op == NOP and arg0[i] == 0 and arg1[i] == 0
                        and arg2[i] == 0):
                    j = i
                    while (j < stop and opcode[j] == NOP and arg0[j] == 0
                           and arg1[j] == 0 and arg2[j] == 0
                           and (j == i or j not in run_breaks)):
                        j += 1
                    rec, chain = _encode_record(NOP_RUN, j - i, 0, 0, b"",
                                                chain)
                    wrote += j - i
                    i = j
                else:
                    vb = vec[i].astype(vdt, copy=False).tobytes() \
                        if op == INSERT else b""
                    rec, chain = _encode_record(op, int(arg0[i]), int(arg1[i]),
                                                int(arg2[i]), vb, chain)
                    wrote += 1
                    i += 1
                buf += rec
            base, path, cnt = self._segments[-1]
            with open(path, "ab") as f:
                f.write(bytes(buf))
                f.flush()
                os.fsync(f.fileno())
            self._chain = chain
            self._cur_records = cnt + wrote
            self._segments[-1] = (base, path, self._cur_records)
            self.t += wrote
        return self.t

    # ------------------------------------------------------------------ #
    def read_range(self, t0: int, t1: int) -> CommandLog:
        """Commands [t0, t1) as a CommandLog (strict: chain must verify)."""
        if not 0 <= t0 <= t1 <= self.t:
            raise ValueError(f"range [{t0}, {t1}) outside WAL [0, {self.t})")
        parts = []
        cover = t0
        for base, path, cnt in self._segments:
            if base + cnt <= t0 or base >= t1:
                continue
            if base > cover:
                raise ValueError(
                    f"WAL gap at [{cover}, {base}): that history was "
                    "dropped by retention or lost to a torn tail")
            seg = _read_segment(path, strict=True, expect_dim=self._dim)
            lo = max(t0 - base, 0)
            hi = min(t1 - base, cnt)
            parts.append({k: v[lo:hi] for k, v in seg.fields.items()})
            cover = base + cnt
        if cover < t1:
            raise ValueError(
                f"WAL gap at [{cover}, {t1}): that history was dropped by "
                "retention or lost to a torn tail")
        if not parts:
            parts = [dict(
                opcode=np.zeros((0,), np.int32),
                arg0=np.zeros((0,), np.int64), arg1=np.zeros((0,), np.int64),
                arg2=np.zeros((0,), np.int64),
                vec=np.zeros((0, self._dim),
                             np.dtype(f"<i{self._itemsize()}")),
            )]
        cat = {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
        return CommandLog(
            opcode=jnp.asarray(cat["opcode"], jnp.int32),
            arg0=jnp.asarray(cat["arg0"], jnp.int64),
            arg1=jnp.asarray(cat["arg1"], jnp.int64),
            arg2=jnp.asarray(cat["arg2"], jnp.int64),
            vec=jnp.asarray(cat["vec"], self.contract.storage_dtype),
        )

    def tail(self, t0: int, max_commands: int = 0
             ) -> Tuple[CommandLog, int]:
        """Stream the durable tail from ``t0``: the commands
        [t0, t_end) with ``t_end = min(t, t0 + max_commands)``
        (``max_commands=0`` means everything durable). Returns
        (log, t_end). This is the log-shipping read a replica paginates
        catch-up with (net/replica.py): bounding ``max_commands`` bounds
        both the shipped frame and the per-step replay, and the strict
        ``read_range`` chain verification applies to every shipped byte."""
        if not 0 <= t0 <= self.t:
            raise ValueError(f"tail from t={t0} outside WAL [0, {self.t}]")
        t_end = self.t if max_commands <= 0 \
            else min(self.t, t0 + max_commands)
        return self.read_range(t0, t_end), t_end

    # ------------------------------------------------------------------ #
    def drop_below(self, t: int) -> int:
        """Delete whole segments entirely below ``t`` (retention). Returns
        the number of segments removed; partial segments are kept."""
        removed = 0
        keep = []
        for base, path, cnt in self._segments:
            if base + cnt <= t and base + cnt <= self.t:
                path.unlink()
                removed += 1
            else:
                keep.append((base, path, cnt))
        if removed and (not keep
                        or keep[-1][1] != self._segments[-1][1]):
            # the active tail segment itself was dropped: the next append
            # must open a fresh segment at the current cursor, not write
            # into the unlinked file's stale bookkeeping
            self._chain = None
            self._cur_records = 0
        self._segments = keep
        return removed

    def reset_to(self, t: int) -> None:
        """Advance the cursor past a lost region (recovery found a snapshot
        newer than the durable WAL prefix). The gap [self.t, t) becomes a
        permanent hole: ``read_range`` refuses it, and the next append
        opens a fresh segment at base ``t`` so new commands can never
        collide with the lost offsets."""
        if t < self.t:
            raise ValueError(f"cannot reset cursor backwards ({t} < {self.t})")
        if t == self.t:
            return
        self.t = t
        self._chain = None
        self._cur_records = 0

    def truncate_to(self, t: int) -> None:
        """Roll the log back to logical time ``t``: every record at or above
        ``t`` is deleted from disk. The inverse of a partial group commit —
        a distributed store uses it to drop a shard's durable-but-never-
        globally-acked suffix so all shards rejoin lockstep at one global
        cursor (shard_wal.ShardedDurableStore.recover).

        A NOP run straddling ``t`` is split: the segment is truncated at the
        record boundary below the run and a shorter run is re-appended.
        Raises if ``t`` falls inside a lost gap (reset_to hole) — that
        history cannot be re-entered."""
        if not 0 <= t <= self.t:
            raise ValueError(f"truncate_to({t}) outside WAL [0, {self.t}]")
        if t == self.t:
            return
        # refuse BEFORE deleting anything: t must sit inside or at the end
        # of a live segment (t=0 with no retained prefix is the empty log)
        covered = t == 0 and (not self._segments
                              or self._segments[0][0] == 0)
        covered = covered or any(base <= t <= base + cnt
                                 for base, _, cnt in self._segments)
        if not covered:
            raise ValueError(
                f"truncate_to({t}): t falls inside a lost gap or retained-"
                "away history; that history cannot be re-entered")
        nop_remainder = 0
        for base, path, cnt in list(self._segments):
            if base >= t:
                path.unlink()
            elif base + cnt > t:
                # straddling segment: cut at the last whole-record boundary
                # at/below t, using the framing the (verifying) segment
                # parse itself derived — no second record walk
                seg = _read_segment(path, strict=True, expect_dim=self._dim)
                target = t - base
                cut, cum = seg.header_bytes, 0
                for off_after, cum_after in seg.bounds:
                    if cum_after > target:
                        break  # record straddles t (only a NOP run can)
                    cut, cum = off_after, cum_after
                nop_remainder = target - cum
                with open(path, "r+b") as f:
                    f.truncate(cut)
                    f.flush()
                    os.fsync(f.fileno())
        fresh = WriteAheadLog(self.dir, self._dim, self.contract,
                              segment_records=self.segment_records)
        self.__dict__.update(fresh.__dict__)
        if nop_remainder:
            self.append(CommandLog(
                opcode=jnp.zeros((nop_remainder,), jnp.int32),
                arg0=jnp.zeros((nop_remainder,), jnp.int64),
                arg1=jnp.zeros((nop_remainder,), jnp.int64),
                arg2=jnp.zeros((nop_remainder,), jnp.int64),
                vec=jnp.zeros((nop_remainder, self._dim),
                              self.contract.storage_dtype)))
        if self.t < t:
            # coverage was verified before any deletion, so a short cursor
            # here means exactly one thing: every segment at/above t was
            # deleted whole and a pre-existing reset_to hole ends at t —
            # preserve the hole rather than refuse or fabricate history
            self.reset_to(t)
        assert self.t == t, f"truncate_to({t}) landed at {self.t}"

    def _repair_interrupted_compaction(self) -> None:
        """Finish or roll back a compaction the process died inside of. The
        commit marker lists the new segment set; it is written (fsynced)
        only after that set is complete in compact.tmp, so: marker present
        ⇒ roll forward (the swap is replayable from the list), marker
        absent ⇒ roll back (discard the partial build, old WAL intact)."""
        marker = self.dir / "compact.commit"
        tmp = self.dir / "compact.tmp"
        if marker.exists():
            keep = set(marker.read_text().split())
            for p in self.dir.glob("seg_*.wal"):
                if p.name not in keep:
                    p.unlink()          # old segment superseded by the swap
            if tmp.exists():
                for p in sorted(tmp.glob("seg_*.wal")):
                    os.replace(p, self.dir / p.name)
                for p in tmp.iterdir():
                    p.unlink()
                tmp.rmdir()
            marker.unlink()
        elif tmp.exists():
            for p in tmp.iterdir():
                p.unlink()
            tmp.rmdir()

    def compact(self, genesis: MemoryState, *,
                min_dead_ratio: float = 0.0) -> Dict[str, int]:
        """Rewrite the whole WAL with dead commands folded to NOPs (and NOP
        runs RLE'd on disk). Replay-equivalent by the ``compact_log``
        contract; logical time is preserved exactly. Crash-safe: the new
        segment set is built and fsynced aside, committed with a marker,
        then swapped in — an interruption anywhere leaves either the old
        or the new WAL fully intact (see _repair_interrupted_compaction).

        ``min_dead_ratio`` gates the rewrite on the measured dead-command
        ratio (folded / n): below it — or when nothing folds — the fold
        analysis still runs (one host mirror pass) but the on-disk WAL is
        left untouched and ``stats["skipped"]`` is 1. This is what
        ``CompactionPolicy`` scheduling drives."""
        if self._segments and self._segments[0][0] != 0:
            raise ValueError("cannot compact a WAL whose head was retained "
                             "away (needs the full history from t=0)")
        raw = self.read_range(0, self.t)
        before = sum(p.stat().st_size for _, p, _ in self._segments)
        compacted, stats = compact_log(genesis, raw)
        stats["dead_ratio"] = stats["folded"] / max(stats["n"], 1)
        if stats["folded"] == 0 or stats["dead_ratio"] < min_dead_ratio:
            stats.update(skipped=1, bytes_before=before, bytes_after=before)
            return stats
        stats["skipped"] = 0

        marker = self.dir / "compact.commit"
        tmp = self.dir / "compact.tmp"
        self._repair_interrupted_compaction()  # clear any previous leftovers
        tmp.mkdir()
        new = WriteAheadLog(tmp, self._dim, self.contract,
                            segment_records=self.segment_records)
        new.append(compacted)
        assert new.t == self.t, "compaction must preserve logical time"
        names = sorted(p.name for p in tmp.glob("seg_*.wal"))
        with open(marker, "wb") as f:  # commit point
            f.write("\n".join(names).encode())
            f.flush()
            os.fsync(f.fileno())
        self._repair_interrupted_compaction()  # roll the swap forward
        fresh = WriteAheadLog(self.dir, self._dim, self.contract,
                              segment_records=self.segment_records)
        self.__dict__.update(fresh.__dict__)
        after = sum(p.stat().st_size for _, p, _ in self._segments)
        stats["bytes_before"] = before
        stats["bytes_after"] = after
        return stats

    def maybe_compact(self, genesis,
                      policy: Optional[CompactionPolicy]
                      ) -> Optional[Dict[str, int]]:
        """Run ``compact`` iff the scheduling policy says it is due — the
        dead-command-ratio-driven automatic path (DESIGN.md §6). Returns
        the compact stats when a check ran, else None. No-ops (cheaply)
        when no policy is set, the check interval has not elapsed, the log
        is still small, or retention dropped the head (compaction needs the
        full history from t=0). ``genesis`` may be the t=0 state or a
        zero-arg callable returning it — callers with an expensive genesis
        (DurableStore restores it from the t=0 snapshot) pay only when a
        check actually runs; the callable may return None to skip the
        check (genesis legitimately unavailable)."""
        if policy is None:
            return None
        if self.t - self._last_compact_check < policy.check_every:
            return None
        self._last_compact_check = self.t
        if self.t < policy.min_commands:
            return None
        if self._segments and self._segments[0][0] != 0:
            return None  # head retained away: nothing to fold from genesis
        if callable(genesis):
            genesis = genesis()
        if genesis is None:
            return None  # caller could not produce the t=0 state: skip
        stats = self.compact(genesis, min_dead_ratio=policy.dead_ratio)
        self._last_compact_check = self.t  # compact() reloads bookkeeping
        return stats


# --------------------------------------------------------------------------- #
# group commit
# --------------------------------------------------------------------------- #


class GroupCommitWriter:
    """Batches submitted command logs and commits them with one fsync per
    group — the high-QPS ingest path (DESIGN.md §6).

    ``sink`` is anything with ``append_many(logs) -> t`` and a durable
    cursor ``t`` (``WriteAheadLog``, ``durability.DurableStore``,
    ``shard_wal.ShardedDurableStore``). ``submit`` buffers a log and flushes
    when the policy's batch or delay bound is hit; ``flush`` forces the
    pending group durable. By default deadlines are only observed at
    ``submit``/``flush`` calls (no timer thread): a serving layer gets a
    hard bound by calling ``flush()`` before any read that could observe
    pending commands (the sync-on-read barrier, serve/engine.py). With
    ``policy.timer_flush`` a daemon thread watches the oldest pending
    command's deadline and flushes when it passes, so ``max_delay_s``
    holds as a wall-clock bound with no read barrier required; submits,
    foreground flushes and timer flushes serialize on one lock, so the
    commit order is exactly the submit order either way.

    Crash contract: commands in a flushed group are durable (fsynced)
    before ``flush`` returns; commands still pending are not — they were
    never acked. A crash inside a flush leaves the longest valid record
    prefix of the group (torn-group truncation, wal.py module docs)."""

    def __init__(self, sink, policy: GroupCommitPolicy = GroupCommitPolicy(),
                 *, pre_flush=None):
        self.sink = sink
        self.policy = policy
        # pre_flush runs (under the writer lock) immediately before the sink
        # commit of every flush — foreground, policy-driven or timer-driven.
        # The serve engine syncs its doc side table here, so cache durability
        # can never lag command durability whichever path triggered the fsync
        self.pre_flush = pre_flush
        self._pending: List[CommandLog] = []
        self._routed: List[Optional[CommandLog]] = []  # pre-routed shares
        self._advance: List[int] = []  # cursor advance each log will cause
        self._pending_n = 0
        self._oldest: Optional[float] = None
        self.groups = 0        # flushes that wrote something
        self.submitted = 0     # commands ever submitted
        self.timer_flushes = 0  # flushes the deadline thread initiated
        self._cv = threading.Condition(threading.RLock())
        self._closed = False
        self._timer: Optional[threading.Thread] = None
        if policy.timer_flush:
            self._timer = threading.Thread(target=self._timer_loop,
                                           daemon=True)
            self._timer.start()

    @property
    def pending(self) -> int:
        """Commands buffered but not yet durable."""
        with self._cv:
            return self._pending_n

    @property
    def target_t(self) -> int:
        """The cursor the sink will reach once pending commands flush.
        Exact for every sink: sharded sinks advance by each batch's padded
        common length, not its raw command count, so the writer asks the
        sink (``planned_advance``) when it knows better than ``len``."""
        with self._cv:
            return self.sink.t + sum(self._advance)

    def _sink_advance(self, log: CommandLog) -> int:
        fn = getattr(self.sink, "planned_advance", None)
        return fn(log) if fn is not None else len(log)

    def submit(self, log: CommandLog, *,
               routed: Optional[CommandLog] = None) -> int:
        """Buffer a log for the next group commit; returns ``target_t``.
        The commands are NOT durable until the group flushes — the caller
        must not ack them upstream before ``flush()`` (or a policy-driven
        flush) covers their offsets. A caller that already routed the log
        for a sharded sink (the serve engine routes once for audit + apply)
        passes the ``[n_shards, L]`` ``routed`` shares so neither the
        advance prediction nor the sink re-routes."""
        with self._cv:
            if len(log):
                self._pending.append(log)
                self._routed.append(routed)
                self._advance.append(
                    # a routed batch's padded common share length IS its
                    # global-cursor advance — no second shard_of_id pass
                    int(routed.opcode.shape[1]) if routed is not None
                    else self._sink_advance(log))
                self._pending_n += len(log)
                self.submitted += len(log)
                if self._oldest is None:
                    self._oldest = time.monotonic()
                    self._cv.notify_all()  # the timer re-arms its deadline
            if (self._pending_n >= self.policy.max_batch
                    or (self._oldest is not None
                        and time.monotonic() - self._oldest
                        >= self.policy.max_delay_s)):
                self._flush_locked()
            return self.sink.t + sum(self._advance)

    def flush(self) -> int:
        """Make every pending command durable (one group commit); returns
        the sink's durable cursor. On a sink failure, whatever prefix the
        sink already made durable (it fsyncs per segment) is dropped from
        the buffer and the rest stays retryable — a retry can neither
        duplicate durable commands nor silently lose pending ones."""
        with self._cv:
            return self._flush_locked()

    def _flush_locked(self) -> int:
        if not self._pending:
            # nothing buffered: make sure no stale deadline survives (a
            # timer thread re-checking an expired _oldest must wait, not
            # spin through no-op flushes)
            self._oldest = None
        if self._pending:
            if self.pre_flush is not None:
                self.pre_flush()
            t0 = self.sink.t
            append_routed = getattr(self.sink, "append_many_routed", None)
            try:
                if (append_routed is not None
                        and all(r is not None for r in self._routed)):
                    append_routed(self._routed)
                else:
                    self.sink.append_many(self._pending)
            except BaseException:
                self._drop_landed(self.sink.t - t0)
                raise
            self._pending = []
            self._routed = []
            self._advance = []
            self._pending_n = 0
            self._oldest = None
            self.groups += 1
        return self.sink.t

    def _timer_loop(self) -> None:
        # Deadline watcher (policy.timer_flush): flush when the oldest
        # pending command has waited max_delay_s. Runs under the same lock
        # as submit/flush, so a timer flush can never interleave inside a
        # submit or reorder the group relative to the submit order.
        with self._cv:
            while not self._closed:
                if self._oldest is None:
                    self._cv.wait()
                    continue
                delay = self._oldest + self.policy.max_delay_s \
                    - time.monotonic()
                if delay > 0:
                    self._cv.wait(delay)
                    continue
                try:
                    self.timer_flushes += 1
                    self._flush_locked()
                except BaseException:  # noqa: BLE001 — the group stays
                    # pending (flush's retry contract); the next deadline
                    # or foreground flush retries and surfaces the error
                    self._cv.wait(self.policy.max_delay_s or 0.001)

    def close(self) -> None:
        """Flush any pending group and stop the deadline thread (no-op
        without ``timer_flush``). The writer stays usable afterwards, just
        without background flushes."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            self._flush_locked()
        if self._timer is not None:
            self._timer.join(timeout=5)
            self._timer = None

    def _drop_landed(self, landed: int) -> None:
        """Remove what a failed flush already made durable, in the SINK'S
        cursor units. Single-host sinks advance one-per-command (NOP runs
        count their length), so ``landed`` maps onto raw pending commands
        and a mid-log remainder is sliced off for retry. Sinks with
        ``planned_advance`` (sharded) advance in *padded batch* units:
        whole batches whose advance landed are popped, and a batch the
        failure cut mid-way is popped too — its durable prefix is already
        on the shards (and the store refuses further appends until
        ``recover()`` reconciles), so re-queueing any part of it could
        only duplicate durable commands. Never-acked work may be dropped;
        durable work must never repeat."""
        batch_units = getattr(self.sink, "planned_advance", None) is not None
        while landed > 0 and self._pending:
            log = self._pending[0]
            if batch_units:
                adv = self._advance[0]
                self._pending_n -= len(log)
                self._pending.pop(0)
                self._routed.pop(0)
                self._advance.pop(0)
                landed = landed - adv if landed >= adv else 0
            elif len(log) <= landed:
                landed -= len(log)
                self._pending_n -= len(log)
                self._pending.pop(0)
                self._routed.pop(0)
                self._advance.pop(0)
            else:
                self._pending[0] = log.slice(landed, len(log))
                self._routed[0] = None  # a sliced log needs re-routing
                self._advance[0] = self._sink_advance(self._pending[0])
                self._pending_n -= landed
                landed = 0
        if not self._pending:
            # nothing left to flush: clear the deadline too, or a timer
            # thread would see an expired _oldest with an empty buffer and
            # spin on no-op flushes forever
            self._oldest = None


# --------------------------------------------------------------------------- #
# compaction: fold provably-dead commands to NOPs
# --------------------------------------------------------------------------- #
#
# The contract is *bit-exact* final-state equality, so a command may only be
# folded when replacing it with NOP provably leaves every leaf of the final
# state unchanged (NOP advances ``version`` exactly like any command, so
# logical time is never disturbed). Admissible folds, proven by a host-side
# mirror of F's bookkeeping:
#
#   * apply-time no-ops: INSERT rejected by a full arena, DELETE of an
#     absent id, duplicate DELETE, LINK that is a duplicate / has no free
#     row entry / names an absent id, UNLINK with no matching entry;
#   * superseded SET_META: an earlier write to meta cell (slot, col) that a
#     later SET_META overwrites — nothing in F ever reads ``meta``, so the
#     intermediate value is unobservable;
#   * superseded upsert INSERT: an overwrite-in-place vector write that a
#     later write to the same slot overwrites, provided no *fresh* INSERT
#     ran in between (graph construction reads vectors — including
#     tombstoned waypoints — so an intermediate value could steer edges);
#   * cancelled LINK/UNLINK pairs on an otherwise-untouched row (any other
#     LINK/UNLINK that resolves the same row in between blocks the fold:
#     it observed the row's free/match layout).
#
# NOT admissible, ever: folding a fresh INSERT (it allocates a slot, bumps
# ``cursor`` and builds HNSW edges that survive deletion) or an INSERT→
# DELETE pair (the tombstoned row's vector bytes, graph level and inbound
# edges all remain in — and hash into — the final state).


def compact_log(genesis: MemoryState,
                log: CommandLog) -> Tuple[CommandLog, Dict[str, int]]:
    """Return (same-length log with dead commands folded to zero-NOPs,
    stats). ``hash(bulk_apply(genesis, out)) == hash(replay(genesis, log))``
    bit-exactly (tests/test_durability.py proves this on randomized logs)."""
    cap = genesis.capacity
    meta_cols = genesis.meta.shape[1]

    ids_h = np.asarray(genesis.ids)
    valid_h = np.asarray(genesis.valid)
    links_h = np.asarray(genesis.links).copy()
    id2slot = {int(i): s for s, i in enumerate(ids_h) if valid_h[s]}
    free = [int(s) for s in np.nonzero(~valid_h)[0]]  # sorted ⇒ a valid heap

    opcode = np.asarray(log.opcode)
    arg0 = np.asarray(log.arg0)
    arg1 = np.asarray(log.arg1)
    n = len(opcode)
    dead = np.zeros((n,), bool)

    pending_vec: Dict[int, int] = {}              # slot -> foldable upsert idx
    pending_meta: Dict[Tuple[int, int], int] = {} # (slot, col) -> write idx
    row_pending: Dict[int, Dict[int, int]] = {}   # slot_a -> {slot_b: link idx}
    last_fresh = -1                               # idx of last fresh INSERT

    for i in range(n):
        op = min(max(int(opcode[i]), 0), 5)  # F clips, mirror clips
        a = int(arg0[i])
        if op == NOP:
            continue
        if op == INSERT:
            slot = id2slot.get(a)
            if slot is not None:  # upsert: in-place vector write
                prev = pending_vec.get(slot)
                if prev is not None and last_fresh < prev:
                    dead[prev] = True
                pending_vec[slot] = i
            elif free:            # fresh insert
                slot = heapq.heappop(free)
                id2slot[a] = slot
                prev = pending_vec.pop(slot, None)
                if prev is not None and last_fresh < prev:
                    dead[prev] = True
                last_fresh = i
            else:                 # arena full: rejected, pure no-op
                dead[i] = True
        elif op == DELETE:
            slot = id2slot.pop(a, None)
            if slot is None:
                dead[i] = True
            else:
                heapq.heappush(free, slot)
        elif op in (LINK, UNLINK):
            b = int(arg1[i])
            sa = id2slot.get(a)
            sb = id2slot.get(b)
            if sa is None or sb is None:
                dead[i] = True
                continue
            row = links_h[sa]
            pend = row_pending.setdefault(sa, {})
            if op == LINK:
                if (row == sb).any() or not (row < 0).any():
                    dead[i] = True  # duplicate / row full: no write
                    pend.clear()    # but it DID observe the row layout
                else:
                    pos = int(np.argmax(row < 0))
                    row[pos] = sb
                    pend.clear()
                    pend[sb] = i    # foldable if unlinked untouched
            else:  # UNLINK
                if not (row == sb).any():
                    dead[i] = True
                    pend.clear()
                else:
                    prev = pend.get(sb)
                    if prev is not None:
                        dead[prev] = True
                        dead[i] = True
                    row[row == sb] = -1
                    pend.clear()
        elif op == SET_META:
            slot = id2slot.get(a)
            if slot is None:
                dead[i] = True
            else:
                col = min(max(int(arg1[i]), 0), meta_cols - 1)
                prev = pending_meta.get((slot, col))
                if prev is not None:
                    dead[prev] = True
                pending_meta[(slot, col)] = i

    folded = int(dead.sum())
    if folded == 0:
        return log, {"n": n, "folded": 0}
    keep = ~dead
    out = CommandLog(
        opcode=jnp.asarray(np.where(keep, opcode, NOP), jnp.int32),
        arg0=jnp.asarray(np.where(keep, arg0, 0), jnp.int64),
        arg1=jnp.asarray(np.where(keep, arg1, 0), jnp.int64),
        arg2=jnp.asarray(np.where(keep, np.asarray(log.arg2), 0), jnp.int64),
        vec=jnp.asarray(np.where(keep[:, None], np.asarray(log.vec), 0),
                        log.vec.dtype),
    )
    return out, {"n": n, "folded": folded}
