"""Segmented write-ahead log for the command stream (DESIGN.md §5).

The command log IS the memory (paper §3.1) — so durability means making the
log itself durable, not the state. This module persists ``CommandLog``
records in append-only segment files with a per-segment FNV-1a hash chain:

Segment file ``seg_<base_t:020d>.wal`` (all little-endian):

  header:  magic 'VWSG' | u32 fmt=1 | u32 dim | u32 vec-itemsize
           | u64 base_t (logical index of the first command in the file)
           | str contract (u32 len + utf8)
           | u64 chain_0 = FNV-1a(header bytes)      — seeds the chain
  record:  u32 storage-op | i64 arg0 | i64 arg1 | i64 arg2
           | vec payload (dim * itemsize bytes, INSERT records only)
           | u64 chain_i = (chain_{i-1} ^ digest(record bytes)) * FNV_PRIME
             — an FNV-1a chain over per-record word digests
             (hashing.digest_bytes: vectorized, so appends stay cheap)

Storage ops are the machine opcodes (0..5) plus ``NOP_RUN`` (0xFFFFFFFE):
a run of k zero-argument NOPs stored as one record with arg0 = k. NOPs are
what routing pads with and what ``compact_log`` folds dead commands into,
so run-length encoding them is where compaction's disk win comes from.
Non-INSERT records carry no vector payload (F never reads ``vec`` outside
INSERT), so the WAL canonicalizes those payloads to zero on read-back —
replay of a round-tripped log is bit-identical by construction.

Crash safety: a torn write leaves a partial record or a record whose chain
word no longer matches; ``_read_segment(strict=False)`` keeps the longest
valid record prefix, which is exactly the durable prefix of the log. On
open, ``WriteAheadLog`` truncates a torn tail in place so later appends
extend a clean chain.

``compact_log`` rewrites provably-dead commands as NOPs while keeping the
log the same length (logical time must not shift), under the *bit-exact*
contract ``hash(bulk_apply(genesis, compact(log))) == hash(replay(genesis,
log))`` — see DESIGN.md §5 for which folds are admissible and why INSERT→
DELETE pairs are not (slot allocation and HNSW waypoints survive deletion).
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import pathlib
import struct
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.commands import (DELETE, INSERT, LINK, NOP, SET_META, UNLINK,
                                 CommandLog)
from repro.core.contracts import (DEFAULT_CONTRACT, PrecisionContract,
                                  get_contract)
from repro.core.state import MemoryState

SEGMENT_MAGIC = b"VWSG"
SEGMENT_FORMAT = 1
NOP_RUN = 0xFFFFFFFE  # storage-only opcode: arg0 zero-NOPs in one record

_U64 = (1 << 64) - 1


_fnv1a = hashing._fnv1a_bytes  # header hashing (small payloads)


def _chain_step(chain: int, body: bytes) -> int:
    """One FNV-1a step over the record's word digest."""
    return ((chain ^ hashing.digest_bytes(body)) * hashing.FNV_PRIME) & _U64


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


# --------------------------------------------------------------------------- #
# segment encode / decode
# --------------------------------------------------------------------------- #


def _segment_header(dim: int, itemsize: int, base_t: int,
                    contract_name: str) -> bytes:
    hdr = (SEGMENT_MAGIC + struct.pack("<III", SEGMENT_FORMAT, dim, itemsize)
           + struct.pack("<Q", base_t) + _pack_str(contract_name))
    return hdr + struct.pack("<Q", _fnv1a(hdr))


def _encode_record(op: int, a0: int, a1: int, a2: int,
                   vec_bytes: bytes, chain: int) -> Tuple[bytes, int]:
    body = struct.pack("<Iqqq", op, a0, a1, a2)
    if op == INSERT:
        body += vec_bytes
    chain = _chain_step(chain, body)
    return body + struct.pack("<Q", chain), chain


@dataclasses.dataclass
class _SegmentData:
    base_t: int
    n_commands: int          # logical commands (NOP runs expanded)
    clean: bool              # chain verified through EOF
    valid_bytes: int         # offset of the last valid record boundary
    chain: int               # chain value at the last valid record
    contract_name: str       # precision contract recorded in the header
    fields: Dict[str, np.ndarray]  # opcode/arg0/arg1/arg2/vec, expanded


def _read_segment(path: pathlib.Path, *, strict: bool = True,
                  expect_dim: Optional[int] = None) -> _SegmentData:
    data = path.read_bytes()
    off = 0

    def fail(msg):
        raise ValueError(f"{path.name}: {msg}")

    if data[:4] != SEGMENT_MAGIC:
        fail("not a WAL segment")
    fmt, dim, itemsize = struct.unpack_from("<III", data, 4)
    if fmt != SEGMENT_FORMAT:
        fail(f"unsupported WAL format {fmt}")
    off = 16
    (base_t,) = struct.unpack_from("<Q", data, off)
    off += 8
    (n,) = struct.unpack_from("<I", data, off)
    contract_name = data[off + 4:off + 4 + n].decode()
    off += 4 + n
    get_contract(contract_name)  # validates
    if expect_dim is not None and dim != expect_dim:
        fail(f"dim mismatch: segment {dim}, expected {expect_dim}")
    (chain,) = struct.unpack_from("<Q", data, off)
    if chain != _fnv1a(data[:off]):
        fail("corrupt segment header")
    off += 8

    vec_nbytes = dim * itemsize
    ops: List[int] = []
    a0s: List[int] = []
    a1s: List[int] = []
    a2s: List[int] = []
    vecs: List[Tuple[int, bytes]] = []  # (record index, payload) sparse
    clean = True
    valid_bytes = off
    n_commands = 0
    while off < len(data):
        if off + 28 + 8 > len(data):
            clean = False
            break
        op, a0, a1, a2 = struct.unpack_from("<Iqqq", data, off)
        body_len = 28 + (vec_nbytes if op == INSERT else 0)
        if off + body_len + 8 > len(data):
            clean = False
            break
        body = data[off:off + body_len]
        (stored,) = struct.unpack_from("<Q", data, off + body_len)
        next_chain = _chain_step(chain, body)
        if stored != next_chain:
            clean = False
            break
        chain = next_chain
        off += body_len + 8
        valid_bytes = off
        if op == NOP_RUN:
            if a0 < 0:
                clean = False
                valid_bytes -= body_len + 8
                break
            ops.extend([NOP] * a0)
            a0s.extend([0] * a0)
            a1s.extend([0] * a0)
            a2s.extend([0] * a0)
            n_commands += int(a0)
        else:
            if op == INSERT:
                vecs.append((len(ops), body[28:]))
            ops.append(op)
            a0s.append(a0)
            a1s.append(a1)
            a2s.append(a2)
            n_commands += 1
    if strict and not clean:
        fail(f"torn/corrupt record at byte {valid_bytes}")

    vdt = np.dtype(f"<i{itemsize}")
    vec = np.zeros((n_commands, dim), vdt)
    for idx, payload in vecs:
        vec[idx] = np.frombuffer(payload, dtype=vdt)
    fields = dict(
        opcode=np.asarray(ops, np.int32), arg0=np.asarray(a0s, np.int64),
        arg1=np.asarray(a1s, np.int64), arg2=np.asarray(a2s, np.int64),
        vec=vec,
    )
    return _SegmentData(base_t=base_t, n_commands=n_commands, clean=clean,
                        valid_bytes=valid_bytes, chain=chain,
                        contract_name=contract_name, fields=fields)


# --------------------------------------------------------------------------- #
# the WAL
# --------------------------------------------------------------------------- #


class WriteAheadLog:
    """Append-only, segmented, hash-chained command log on disk.

    ``t`` is the monotone applied-command cursor: the logical index of the
    next command to be appended. ``read_range(t0, t1)`` returns the commands
    [t0, t1) as a ``CommandLog``; replaying a round-tripped range is
    bit-identical to replaying the original commands.
    """

    def __init__(self, directory: str | os.PathLike, dim: Optional[int] = None,
                 contract: Optional[PrecisionContract] = None, *,
                 segment_records: int = 1024):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.contract = contract  # None ⇒ adopt from segment headers
        self.segment_records = segment_records
        self.torn_tail_dropped = 0  # bytes truncated from a torn tail on open

        self._repair_interrupted_compaction()
        for stale in self.dir.glob("*.tmp"):  # stillborn segment creations
            if stale.is_file():
                stale.unlink()

        self._segments: List[Tuple[int, pathlib.Path, int]] = []  # (base, path, n)
        paths = sorted(self.dir.glob("seg_*.wal"))
        self._dim = dim
        tail_seg: Optional[_SegmentData] = None
        for i, p in enumerate(paths):
            last = i == len(paths) - 1
            if last:
                try:
                    seg = _read_segment(p, strict=False)
                except (ValueError, struct.error):  # short header ⇒ struct
                    # stillborn tail: the crash tore the header itself.
                    # Headers are fsynced at creation before any record can
                    # be appended, so an unreadable header implies zero
                    # durable records — dropping the file loses nothing.
                    self.torn_tail_dropped += p.stat().st_size
                    p.unlink()
                    continue
                if (self._dim is not None
                        and seg.fields["vec"].shape[1] != self._dim):
                    raise ValueError(
                        f"{p.name}: dim {seg.fields['vec'].shape[1]} != "
                        f"expected {self._dim}")
            else:
                seg = _read_segment(p, strict=True, expect_dim=self._dim)
            if self._dim is None:
                self._dim = seg.fields["vec"].shape[1]
            # the header is authoritative for the storage contract: reopening
            # with a mismatched (or defaulted) contract would wrap-cast
            # read_range payloads into the wrong dtype with no error
            hdr_contract = get_contract(seg.contract_name)
            if self.contract is None:
                self.contract = hdr_contract
            elif self.contract.name != hdr_contract.name:
                raise ValueError(
                    f"{p.name}: segment contract {hdr_contract.name!r} != "
                    f"given contract {self.contract.name!r}")
            if not seg.clean:
                # torn tail: truncate to the longest valid record prefix so
                # future appends extend a verified chain
                self.torn_tail_dropped += p.stat().st_size - seg.valid_bytes
                with open(p, "r+b") as f:
                    f.truncate(seg.valid_bytes)
                    f.flush()
                    os.fsync(f.fileno())
            self._segments.append((seg.base_t, p, seg.n_commands))
            if last:
                tail_seg = seg
        if self._dim is None:
            raise ValueError("empty WAL directory needs an explicit dim")
        if self.contract is None:  # fresh, empty WAL with no override
            self.contract = DEFAULT_CONTRACT

        if self._segments:
            if tail_seg is None:  # stillborn tail was dropped: the previous
                tail_seg = _read_segment(  # segment is the live tail now
                    self._segments[-1][1], strict=True, expect_dim=self._dim)
            base, _, n = self._segments[-1]
            self.t = base + n
            self._chain = tail_seg.chain
            self._cur_records = n
        else:
            self.t = 0
            self._chain = None   # set when the first segment is created
            self._cur_records = 0

    # ------------------------------------------------------------------ #
    @property
    def dim(self) -> int:
        return self._dim

    def segments(self) -> List[Tuple[int, int]]:
        """[(base_t, n_commands)] in order."""
        return [(b, n) for b, _, n in self._segments]

    def _itemsize(self) -> int:
        return np.dtype(jnp.dtype(self.contract.storage_dtype).name).itemsize

    def _open_segment(self) -> None:
        path = self.dir / f"seg_{self.t:020d}.wal"
        hdr = _segment_header(self._dim, self._itemsize(), self.t,
                              self.contract.name)
        tmp = path.with_suffix(".wal.tmp")
        with open(tmp, "wb") as f:  # fsync+rename: a crash can leave a
            f.write(hdr)            # stale .tmp (ignored on open), never a
            f.flush()               # torn header at the live name
            os.fsync(f.fileno())
        tmp.rename(path)
        self._chain = _fnv1a(hdr[:-8])
        self._segments.append((self.t, path, 0))
        self._cur_records = 0

    # ------------------------------------------------------------------ #
    def append(self, log: CommandLog) -> int:
        """Durably append a command log; returns the new cursor ``t``."""
        n = len(log)
        if n == 0:
            return self.t
        opcode = np.asarray(log.opcode)
        arg0 = np.asarray(log.arg0)
        arg1 = np.asarray(log.arg1)
        arg2 = np.asarray(log.arg2)
        vec = np.asarray(log.vec)
        if vec.shape[1] != self._dim:
            raise ValueError(f"log dim {vec.shape[1]} != WAL dim {self._dim}")
        expected = np.dtype(jnp.dtype(self.contract.storage_dtype).name)
        if vec.dtype != expected:
            # a mismatched itemsize would desync record framing — every
            # later record would read as torn and be silently discarded
            raise ValueError(
                f"log vec dtype {vec.dtype} != WAL storage dtype {expected}")
        vdt = vec.dtype.newbyteorder("<")

        i = 0
        while i < n:
            if self._chain is None or self._cur_records >= self.segment_records:
                self._open_segment()
            room = self.segment_records - self._cur_records
            stop = min(n, i + room)
            buf = bytearray()
            chain = self._chain
            wrote = 0
            while i < stop:
                op = int(opcode[i])
                if (op == NOP and arg0[i] == 0 and arg1[i] == 0
                        and arg2[i] == 0):
                    j = i
                    while (j < stop and opcode[j] == NOP and arg0[j] == 0
                           and arg1[j] == 0 and arg2[j] == 0):
                        j += 1
                    rec, chain = _encode_record(NOP_RUN, j - i, 0, 0, b"",
                                                chain)
                    wrote += j - i
                    i = j
                else:
                    vb = vec[i].astype(vdt, copy=False).tobytes() \
                        if op == INSERT else b""
                    rec, chain = _encode_record(op, int(arg0[i]), int(arg1[i]),
                                                int(arg2[i]), vb, chain)
                    wrote += 1
                    i += 1
                buf += rec
            base, path, cnt = self._segments[-1]
            with open(path, "ab") as f:
                f.write(bytes(buf))
                f.flush()
                os.fsync(f.fileno())
            self._chain = chain
            self._cur_records = cnt + wrote
            self._segments[-1] = (base, path, self._cur_records)
            self.t += wrote
        return self.t

    # ------------------------------------------------------------------ #
    def read_range(self, t0: int, t1: int) -> CommandLog:
        """Commands [t0, t1) as a CommandLog (strict: chain must verify)."""
        if not 0 <= t0 <= t1 <= self.t:
            raise ValueError(f"range [{t0}, {t1}) outside WAL [0, {self.t})")
        parts = []
        cover = t0
        for base, path, cnt in self._segments:
            if base + cnt <= t0 or base >= t1:
                continue
            if base > cover:
                raise ValueError(
                    f"WAL gap at [{cover}, {base}): that history was "
                    "dropped by retention or lost to a torn tail")
            seg = _read_segment(path, strict=True, expect_dim=self._dim)
            lo = max(t0 - base, 0)
            hi = min(t1 - base, cnt)
            parts.append({k: v[lo:hi] for k, v in seg.fields.items()})
            cover = base + cnt
        if cover < t1:
            raise ValueError(
                f"WAL gap at [{cover}, {t1}): that history was dropped by "
                "retention or lost to a torn tail")
        if not parts:
            parts = [dict(
                opcode=np.zeros((0,), np.int32),
                arg0=np.zeros((0,), np.int64), arg1=np.zeros((0,), np.int64),
                arg2=np.zeros((0,), np.int64),
                vec=np.zeros((0, self._dim),
                             np.dtype(f"<i{self._itemsize()}")),
            )]
        cat = {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}
        return CommandLog(
            opcode=jnp.asarray(cat["opcode"], jnp.int32),
            arg0=jnp.asarray(cat["arg0"], jnp.int64),
            arg1=jnp.asarray(cat["arg1"], jnp.int64),
            arg2=jnp.asarray(cat["arg2"], jnp.int64),
            vec=jnp.asarray(cat["vec"], self.contract.storage_dtype),
        )

    # ------------------------------------------------------------------ #
    def drop_below(self, t: int) -> int:
        """Delete whole segments entirely below ``t`` (retention). Returns
        the number of segments removed; partial segments are kept."""
        removed = 0
        keep = []
        for base, path, cnt in self._segments:
            if base + cnt <= t and base + cnt <= self.t:
                path.unlink()
                removed += 1
            else:
                keep.append((base, path, cnt))
        if removed and (not keep
                        or keep[-1][1] != self._segments[-1][1]):
            # the active tail segment itself was dropped: the next append
            # must open a fresh segment at the current cursor, not write
            # into the unlinked file's stale bookkeeping
            self._chain = None
            self._cur_records = 0
        self._segments = keep
        return removed

    def reset_to(self, t: int) -> None:
        """Advance the cursor past a lost region (recovery found a snapshot
        newer than the durable WAL prefix). The gap [self.t, t) becomes a
        permanent hole: ``read_range`` refuses it, and the next append
        opens a fresh segment at base ``t`` so new commands can never
        collide with the lost offsets."""
        if t < self.t:
            raise ValueError(f"cannot reset cursor backwards ({t} < {self.t})")
        if t == self.t:
            return
        self.t = t
        self._chain = None
        self._cur_records = 0

    def _repair_interrupted_compaction(self) -> None:
        """Finish or roll back a compaction the process died inside of. The
        commit marker lists the new segment set; it is written (fsynced)
        only after that set is complete in compact.tmp, so: marker present
        ⇒ roll forward (the swap is replayable from the list), marker
        absent ⇒ roll back (discard the partial build, old WAL intact)."""
        marker = self.dir / "compact.commit"
        tmp = self.dir / "compact.tmp"
        if marker.exists():
            keep = set(marker.read_text().split())
            for p in self.dir.glob("seg_*.wal"):
                if p.name not in keep:
                    p.unlink()          # old segment superseded by the swap
            if tmp.exists():
                for p in sorted(tmp.glob("seg_*.wal")):
                    os.replace(p, self.dir / p.name)
                for p in tmp.iterdir():
                    p.unlink()
                tmp.rmdir()
            marker.unlink()
        elif tmp.exists():
            for p in tmp.iterdir():
                p.unlink()
            tmp.rmdir()

    def compact(self, genesis: MemoryState) -> Dict[str, int]:
        """Rewrite the whole WAL with dead commands folded to NOPs (and NOP
        runs RLE'd on disk). Replay-equivalent by the ``compact_log``
        contract; logical time is preserved exactly. Crash-safe: the new
        segment set is built and fsynced aside, committed with a marker,
        then swapped in — an interruption anywhere leaves either the old
        or the new WAL fully intact (see _repair_interrupted_compaction)."""
        if self._segments and self._segments[0][0] != 0:
            raise ValueError("cannot compact a WAL whose head was retained "
                             "away (needs the full history from t=0)")
        raw = self.read_range(0, self.t)
        before = sum(p.stat().st_size for _, p, _ in self._segments)
        compacted, stats = compact_log(genesis, raw)

        marker = self.dir / "compact.commit"
        tmp = self.dir / "compact.tmp"
        self._repair_interrupted_compaction()  # clear any previous leftovers
        tmp.mkdir()
        new = WriteAheadLog(tmp, self._dim, self.contract,
                            segment_records=self.segment_records)
        new.append(compacted)
        assert new.t == self.t, "compaction must preserve logical time"
        names = sorted(p.name for p in tmp.glob("seg_*.wal"))
        with open(marker, "wb") as f:  # commit point
            f.write("\n".join(names).encode())
            f.flush()
            os.fsync(f.fileno())
        self._repair_interrupted_compaction()  # roll the swap forward
        fresh = WriteAheadLog(self.dir, self._dim, self.contract,
                              segment_records=self.segment_records)
        self.__dict__.update(fresh.__dict__)
        after = sum(p.stat().st_size for _, p, _ in self._segments)
        stats["bytes_before"] = before
        stats["bytes_after"] = after
        return stats


# --------------------------------------------------------------------------- #
# compaction: fold provably-dead commands to NOPs
# --------------------------------------------------------------------------- #
#
# The contract is *bit-exact* final-state equality, so a command may only be
# folded when replacing it with NOP provably leaves every leaf of the final
# state unchanged (NOP advances ``version`` exactly like any command, so
# logical time is never disturbed). Admissible folds, proven by a host-side
# mirror of F's bookkeeping:
#
#   * apply-time no-ops: INSERT rejected by a full arena, DELETE of an
#     absent id, duplicate DELETE, LINK that is a duplicate / has no free
#     row entry / names an absent id, UNLINK with no matching entry;
#   * superseded SET_META: an earlier write to meta cell (slot, col) that a
#     later SET_META overwrites — nothing in F ever reads ``meta``, so the
#     intermediate value is unobservable;
#   * superseded upsert INSERT: an overwrite-in-place vector write that a
#     later write to the same slot overwrites, provided no *fresh* INSERT
#     ran in between (graph construction reads vectors — including
#     tombstoned waypoints — so an intermediate value could steer edges);
#   * cancelled LINK/UNLINK pairs on an otherwise-untouched row (any other
#     LINK/UNLINK that resolves the same row in between blocks the fold:
#     it observed the row's free/match layout).
#
# NOT admissible, ever: folding a fresh INSERT (it allocates a slot, bumps
# ``cursor`` and builds HNSW edges that survive deletion) or an INSERT→
# DELETE pair (the tombstoned row's vector bytes, graph level and inbound
# edges all remain in — and hash into — the final state).


def compact_log(genesis: MemoryState,
                log: CommandLog) -> Tuple[CommandLog, Dict[str, int]]:
    """Return (same-length log with dead commands folded to zero-NOPs,
    stats). ``hash(bulk_apply(genesis, out)) == hash(replay(genesis, log))``
    bit-exactly (tests/test_durability.py proves this on randomized logs)."""
    cap = genesis.capacity
    meta_cols = genesis.meta.shape[1]

    ids_h = np.asarray(genesis.ids)
    valid_h = np.asarray(genesis.valid)
    links_h = np.asarray(genesis.links).copy()
    id2slot = {int(i): s for s, i in enumerate(ids_h) if valid_h[s]}
    free = [int(s) for s in np.nonzero(~valid_h)[0]]  # sorted ⇒ a valid heap

    opcode = np.asarray(log.opcode)
    arg0 = np.asarray(log.arg0)
    arg1 = np.asarray(log.arg1)
    n = len(opcode)
    dead = np.zeros((n,), bool)

    pending_vec: Dict[int, int] = {}              # slot -> foldable upsert idx
    pending_meta: Dict[Tuple[int, int], int] = {} # (slot, col) -> write idx
    row_pending: Dict[int, Dict[int, int]] = {}   # slot_a -> {slot_b: link idx}
    last_fresh = -1                               # idx of last fresh INSERT

    for i in range(n):
        op = min(max(int(opcode[i]), 0), 5)  # F clips, mirror clips
        a = int(arg0[i])
        if op == NOP:
            continue
        if op == INSERT:
            slot = id2slot.get(a)
            if slot is not None:  # upsert: in-place vector write
                prev = pending_vec.get(slot)
                if prev is not None and last_fresh < prev:
                    dead[prev] = True
                pending_vec[slot] = i
            elif free:            # fresh insert
                slot = heapq.heappop(free)
                id2slot[a] = slot
                prev = pending_vec.pop(slot, None)
                if prev is not None and last_fresh < prev:
                    dead[prev] = True
                last_fresh = i
            else:                 # arena full: rejected, pure no-op
                dead[i] = True
        elif op == DELETE:
            slot = id2slot.pop(a, None)
            if slot is None:
                dead[i] = True
            else:
                heapq.heappush(free, slot)
        elif op in (LINK, UNLINK):
            b = int(arg1[i])
            sa = id2slot.get(a)
            sb = id2slot.get(b)
            if sa is None or sb is None:
                dead[i] = True
                continue
            row = links_h[sa]
            pend = row_pending.setdefault(sa, {})
            if op == LINK:
                if (row == sb).any() or not (row < 0).any():
                    dead[i] = True  # duplicate / row full: no write
                    pend.clear()    # but it DID observe the row layout
                else:
                    pos = int(np.argmax(row < 0))
                    row[pos] = sb
                    pend.clear()
                    pend[sb] = i    # foldable if unlinked untouched
            else:  # UNLINK
                if not (row == sb).any():
                    dead[i] = True
                    pend.clear()
                else:
                    prev = pend.get(sb)
                    if prev is not None:
                        dead[prev] = True
                        dead[i] = True
                    row[row == sb] = -1
                    pend.clear()
        elif op == SET_META:
            slot = id2slot.get(a)
            if slot is None:
                dead[i] = True
            else:
                col = min(max(int(arg1[i]), 0), meta_cols - 1)
                prev = pending_meta.get((slot, col))
                if prev is not None:
                    dead[prev] = True
                pending_meta[(slot, col)] = i

    folded = int(dead.sum())
    if folded == 0:
        return log, {"n": n, "folded": 0}
    keep = ~dead
    out = CommandLog(
        opcode=jnp.asarray(np.where(keep, opcode, NOP), jnp.int32),
        arg0=jnp.asarray(np.where(keep, arg0, 0), jnp.int64),
        arg1=jnp.asarray(np.where(keep, arg1, 0), jnp.int64),
        arg2=jnp.asarray(np.where(keep, np.asarray(log.arg2), 0), jnp.int64),
        vec=jnp.asarray(np.where(keep[:, None], np.asarray(log.vec), 0),
                        log.vec.dtype),
    )
    return out, {"n": n, "folded": folded}
