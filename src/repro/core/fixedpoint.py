"""Q-format fixed-point arithmetic in JAX (paper §5.1).

All values are stored as signed integers where the low ``frac_bits`` bits are
the fractional part. Because every operation here reduces to integer ALU
instructions, results are bit-identical on any backend (CPU/TPU/GPU/WASM) and
invariant to reduction order, SIMD width, and compiler fusion — the property
the paper builds its determinism argument on.

Conventions
-----------
* "raw" values are the integer representations (dtype = contract.storage_dtype).
* Multiplication widens to ``contract.acc_dtype`` before the shift-back;
  dot products accumulate in the wide type and renormalize once at the end
  (exactly the paper's i64-accumulator rule).
* All narrowing saturates (clamps) rather than wrapping, matching the paper's
  "checking for saturation" overhead note (§8.2).
* Rounding is round-half-up via ``(x + half) >> frac_bits`` on the widened
  value: fully defined, branch-free, platform-independent.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract

# --------------------------------------------------------------------------- #
# encode / decode across the float <-> fixed boundary
# --------------------------------------------------------------------------- #


def encode(x: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Quantize floats into raw fixed-point integers (saturating).

    This is THE determinism boundary: floats produced by nondeterministic
    model inference enter; deterministic integers leave. Round-half-away-from-
    zero on the scaled value, then clamp to the contract range.

    Canonically computed in float32: every step (mul, abs, +0.5, floor) is a
    single correctly-rounded IEEE op — bit-identical on any IEEE machine and
    representable on TPU (no f64 there), so the Pallas qboundary kernel and
    this reference produce the same bits. Exactness note: for |x·one| < 2^23
    (e.g. |x| ≤ 128 at Q16.16 — embeddings are unit-norm, far inside) the
    f32 pipeline rounds identically to infinite precision.
    """
    scaled = jnp.asarray(x, jnp.float32) * jnp.float32(contract.one)
    # round half away from zero: sign(x) * floor(|x| + 0.5)
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + jnp.float32(0.5))
    lo, hi = _f32_safe_bounds(contract)
    clamped = jnp.clip(rounded, lo, hi)
    return clamped.astype(contract.storage_dtype)


def _f32_safe_bounds(contract: PrecisionContract):
    """Largest/smallest float32 clamp bounds that convert exactly into the
    storage integer range (float32(2^31-1) would round UP to 2^31 and
    overflow the convert)."""
    import numpy as np

    hi = np.float32(contract.max_raw)
    if hi > contract.max_raw:
        hi = np.nextafter(hi, np.float32(0), dtype=np.float32)
    lo = np.float32(contract.min_raw)
    if lo < contract.min_raw:
        lo = np.nextafter(lo, np.float32(0), dtype=np.float32)
    return lo, hi


def decode(raw: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Raw fixed-point → float64 (exact: every raw value is representable)."""
    return raw.astype(jnp.float64) / contract.one


def decode_f32(raw: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    return raw.astype(jnp.float32) / jnp.float32(contract.one)


# --------------------------------------------------------------------------- #
# saturating helpers
# --------------------------------------------------------------------------- #


def saturate(wide: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Clamp a wide-integer value into the contract's raw range and narrow."""
    clamped = jnp.clip(
        wide,
        jnp.asarray(contract.min_raw, wide.dtype),
        jnp.asarray(contract.max_raw, wide.dtype),
    )
    return clamped.astype(contract.storage_dtype)


def _shift_back(wide: jax.Array, contract: PrecisionContract) -> jax.Array:
    """Divide a wide product by 2^frac_bits with round-half-up (arith shift)."""
    half = jnp.asarray(1 << (contract.frac_bits - 1), wide.dtype)
    return (wide + half) >> contract.frac_bits


# --------------------------------------------------------------------------- #
# arithmetic
# --------------------------------------------------------------------------- #


def qadd(a: jax.Array, b: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    wide = a.astype(contract.acc_dtype) + b.astype(contract.acc_dtype)
    return saturate(wide, contract)


def qsub(a: jax.Array, b: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    wide = a.astype(contract.acc_dtype) - b.astype(contract.acc_dtype)
    return saturate(wide, contract)


def _require_wide_products(contract: PrecisionContract) -> None:
    """Products need 2x the storage width; int64 storage would need int128.

    Q32.32 (the paper's Table 2 "future" enterprise contract) is served by
    the dedicated limb-based routines below (qmul_q32 / qdot_q32) — the
    generic narrow-contract paths refuse loudly instead of wrapping.
    """
    if jnp.dtype(contract.storage_dtype).itemsize >= 8:
        raise NotImplementedError(
            f"{contract.name}: products need >64-bit accumulation; "
            "use qmul_q32/qdot_q32 (core.limbs) for Q32.32"
        )


# --------------------------------------------------------------------------- #
# Q32.32 via 128-bit limb arithmetic (core.limbs) — the paper's "future"
# enterprise contract, realized. Exact, order-invariant, saturating.
# --------------------------------------------------------------------------- #


def qmul_q32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact Q32.32 multiply: 64×64→128-bit limbs, >>32, saturate to int64."""
    from repro.core import limbs
    return limbs.q32_dot_to_q32(a[..., None], b[..., None], axis=-1)


def qdot_q32(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    """Exact Q32.32 dot product (128-bit accumulation), Q32.32 result."""
    from repro.core import limbs
    if axis != -1:
        a = jnp.moveaxis(a, axis, -1)
        b = jnp.moveaxis(b, axis, -1)
    return limbs.q32_dot_to_q32(a, b, axis=-1)


def qmul(a: jax.Array, b: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Fixed-point multiply: widen, multiply exactly, shift back, saturate."""
    _require_wide_products(contract)
    wide = a.astype(contract.acc_dtype) * b.astype(contract.acc_dtype)
    return saturate(_shift_back(wide, contract), contract)


def qneg(a: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    return saturate(-a.astype(contract.acc_dtype), contract)


def qdiv(a: jax.Array, b: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Fixed-point divide. b == 0 saturates to the signed max of matching sign."""
    wide_a = a.astype(contract.acc_dtype) << contract.frac_bits
    wide_b = b.astype(contract.acc_dtype)
    safe_b = jnp.where(wide_b == 0, jnp.ones_like(wide_b), wide_b)
    q = _int_div_round_to_nearest(wide_a, safe_b)
    sat = jnp.where(
        a >= 0,
        jnp.asarray(contract.max_raw, contract.acc_dtype),
        jnp.asarray(contract.min_raw, contract.acc_dtype),
    )
    q = jnp.where(wide_b == 0, sat, q)
    return saturate(q, contract)


def _int_div_round_to_nearest(a: jax.Array, b: jax.Array) -> jax.Array:
    """Integer division rounded to nearest (half away from zero), exact.

    Works from the truncating |a|//|b| so behaviour is symmetric in sign.
    """
    abs_a, abs_b = jnp.abs(a), jnp.abs(b)
    q = abs_a // abs_b
    rem = abs_a - abs_b * q
    adjust = (2 * rem >= abs_b).astype(a.dtype)
    sign = jnp.where((a < 0) ^ (b < 0), -1, 1).astype(a.dtype)
    return sign * (q + adjust)


# --------------------------------------------------------------------------- #
# reductions: the heart of the determinism argument
# --------------------------------------------------------------------------- #


def qdot(a: jax.Array, b: jax.Array, axis: int = -1,
         contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Fixed-point dot product along ``axis``.

    Products are exact in the wide accumulator; the sum over the axis is an
    integer sum (order-invariant); a single shift-back at the end renormalizes.
    For Q16.16 over D ≤ 2^15 dimensions with |x| ≤ 1 this cannot overflow i64.
    """
    _require_wide_products(contract)
    wa = a.astype(contract.acc_dtype)
    wb = b.astype(contract.acc_dtype)
    acc = jnp.sum(wa * wb, axis=axis)
    return saturate(_shift_back(acc, contract), contract)


def qdot_wide(a: jax.Array, b: jax.Array, axis: int = -1,
              contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Like qdot but returns the *wide* (unshifted) accumulator.

    Used by the search path: raw Q(2f)-scaled scores preserve full precision
    for ranking (monotone in the true dot product) and stay exactly integer.
    """
    _require_wide_products(contract)
    wa = a.astype(contract.acc_dtype)
    wb = b.astype(contract.acc_dtype)
    return jnp.sum(wa * wb, axis=axis)


def ql2sq_wide(a: jax.Array, b: jax.Array, axis: int = -1,
               contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """Squared L2 distance in the wide accumulator (exact, Q(2f) scale)."""
    wa = a.astype(contract.acc_dtype)
    wb = b.astype(contract.acc_dtype)
    d = wa - wb
    return jnp.sum(d * d, axis=axis)


def qsum(a: jax.Array, axis=None, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    wide = jnp.sum(a.astype(contract.acc_dtype), axis=axis)
    return saturate(wide, contract)


def qmean(a: jax.Array, axis=None, contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    wide = jnp.sum(a.astype(contract.acc_dtype), axis=axis)
    n = a.shape[axis] if isinstance(axis, int) else a.size
    return saturate(_int_div_round_to_nearest(wide, jnp.asarray(n, wide.dtype)), contract)


# --------------------------------------------------------------------------- #
# integer sqrt + normalization (needed for cosine / unit-norm boundary)
# --------------------------------------------------------------------------- #


def isqrt(x: jax.Array) -> jax.Array:
    """Exact integer floor-sqrt for non-negative int64 via bit-by-bit method.

    32 iterations of the classic branch-free digit recurrence (bit runs over
    every power of four from 2^62 down); fully deterministic, no floating
    point anywhere. Shapes are preserved.
    """
    x = x.astype(jnp.int64)

    def body(i, carry):
        rem, res = carry
        bit = jnp.int64(1) << (62 - 2 * i)
        take = rem >= res + bit
        rem = jnp.where(take, rem - (res + bit), rem)
        res = jnp.where(take, (res >> 1) + bit, res >> 1)
        return rem, res

    _, res = jax.lax.fori_loop(0, 32, body, (x, jnp.zeros_like(x)))
    return res


def qnorm(v: jax.Array, axis: int = -1,
          contract: PrecisionContract = DEFAULT_CONTRACT) -> jax.Array:
    """L2-normalize fixed-point vectors, staying entirely in integers.

    ||v||^2 is exact in the wide accumulator at Q(2f) scale, so
    isqrt(sum v_i^2) is the norm at Q(f) scale. Each component is then
    (v_i << f) / norm_raw, rounded to nearest — deterministic unit vectors.
    Zero vectors pass through unchanged.
    """
    wide = v.astype(contract.acc_dtype)
    sq = jnp.sum(wide * wide, axis=axis, keepdims=True)
    norm_raw = isqrt(sq.astype(jnp.int64)).astype(contract.acc_dtype)  # Q(f) scale
    safe = jnp.where(norm_raw == 0, jnp.ones_like(norm_raw), norm_raw)
    num = wide << contract.frac_bits
    out = _int_div_round_to_nearest(num, safe)
    out = jnp.where(norm_raw == 0, wide, out)
    return saturate(out, contract)
