"""Batched deterministic query engine — the read-path twin of bulk_apply.

``machine.bulk_apply`` made the write path fast under the equivalence
contract (DESIGN.md §3). This module is the same move for the read path
(DESIGN.md §4): every batched / planned / sharded search below is
bit-identical to the per-query reference loop over ``hnsw.hnsw_search`` /
``search.exact_search`` — same ids, same wide scores, same tie order.

Three layers:

* ``batched_hnsw_search`` — B queries through the HNSW graph under one jit:
  a ``vmap`` over the fixed-shape beam state in ``hnsw.py``. Every ranking
  decision inside the beam is the same ``(dist, slot)`` lexicographic
  integer compare, and a vmapped ``while_loop`` freezes each lane's carry
  once its own predicate goes false, so lane b computes exactly the values
  the single-query call computes.
* ``exact route`` — ``search.exact_search``, optionally kernel-backed
  (Pallas qgemm scoring + qtopk selection) with the pure-jnp path as both
  fallback and oracle.
* ``plan_query`` / ``execute_plan`` / ``sharded_query`` — a planner that
  picks exact-scan vs HNSW per request from *static host facts only*
  (live count, k, ef), so the route itself is replayable, and fans out
  across shards via ``distributed.py``, merging with the order-invariant
  ``merge_topk`` combine.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw as hnsw_lib
from repro.core import search
from repro.core.state import MemoryState

INF = search.INF

ROUTE_EXACT = "exact"
ROUTE_HNSW = "hnsw"
ROUTE_COARSE = "coarse"


# --------------------------------------------------------------------------- #
# batched HNSW: vmap over the fixed-shape beam
# --------------------------------------------------------------------------- #


@partial(jax.jit, static_argnames=("k", "ef"))
def batched_hnsw_search(state: MemoryState, queries_raw: jax.Array, k: int,
                        *, ef: int = 64
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ANN for B queries under one jit: (ids [B,k], dists [B,k], slots [B,k]).

    Bit-identical to calling ``hnsw.hnsw_search`` once per row
    (tests/test_query_engine.py asserts this on randomized logs).
    """
    return jax.vmap(
        lambda q: hnsw_lib.hnsw_search(state, q, k, ef=ef)
    )(queries_raw)


# --------------------------------------------------------------------------- #
# query planner: static facts in, deterministic route out
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A replayable routing decision. Pure data: two plans built from the
    same facts compare equal, and the facts are recorded for audit."""
    route: str               # ROUTE_EXACT | ROUTE_HNSW | ROUTE_COARSE
    k: int
    ef: int
    use_kernel: bool         # exact/coarse routes (HNSW gathers row-wise)
    live_count: int          # the fact the decision was made from
    reason: str
    # who answered: "primary", or "replica:<i>" when the serve engine's
    # read pool served this request at a proven cursor (DESIGN.md §9) —
    # recorded so replica-served answers are replayable audit artifacts
    # like every other planner choice
    served_by: str = "primary"
    # compressed-tier facts (DESIGN.md §10): candidate-set size for the
    # coarse route (0 = tier disabled) and the vector dimension the
    # decision was made from — recorded so a coarse answer is replayable
    # from (plan, log cursor, query) like every other route
    ef_coarse: int = 0
    dim: int = 0
    # churn audit (DESIGN.md §11): how many re-link passes the serving
    # graph has absorbed when this plan was made. A replayed plan is then
    # checkable against the engine's re-link schedule — the same log prefix
    # plus the same graph generation must reproduce this answer bit-exactly
    graph_gen: int = 0


def plan_query(live_count: int, k: int, ef: int, *,
               use_kernel: bool = False, exact_threshold: int = 1024,
               route: str = "auto", ef_coarse: int = 0,
               dim: int = 0, graph_gen: int = 0) -> QueryPlan:
    """Pick exact-scan vs HNSW vs the compressed coarse tier from static
    facts — host ints only, so the same request against the same memory
    plans identically everywhere.

    Rules (DESIGN.md §4, §10), first match wins:
      1. forced route (``route != "auto"``) — operator override (forcing
         "hnsw" with k > ef, or "coarse" with k > ef_coarse, raises: the
         candidate set cannot return k results);
      2. ``k > ef`` → exact (an ef-beam cannot return k results);
      3. ``live_count <= exact_threshold`` → exact (the scan is cheap and
         exact; no reason to pay graph traversal);
      4. ``ef >= live_count`` → exact (the beam would cover the whole
         corpus anyway — a scan does the same work without the gathers);
      5. ``0 < k <= ef_coarse`` and ``4 * ef_coarse <= 3 * live_count``
         and ``dim <= 8192`` → coarse: the int8 scan streams 1/4 the
         bytes of the exact scan, so bytes beat exact once the re-rank
         pool is under 3/4 of the corpus (the break-even of
         live*dim*1 + ef*dim*4 vs live*dim*4); the dim cap is the qcoarse
         kernel's int32 exactness bound;
      6. otherwise → HNSW — including under churn. Deletes no longer
         demote the graph to exact scan: entry-point repair keeps every
         layout's entry live and the scheduled re-link pass (recorded in
         ``graph_gen``) sweeps tombstoned waypoints, so ANN stays the
         production route on churny traffic (DESIGN.md §11).
    """
    def mk(r, why):
        return QueryPlan(route=r, k=k, ef=ef, use_kernel=use_kernel,
                         live_count=live_count, reason=why,
                         ef_coarse=ef_coarse, dim=dim, graph_gen=graph_gen)

    if route != "auto":
        if route not in (ROUTE_EXACT, ROUTE_HNSW, ROUTE_COARSE):
            raise ValueError(f"unknown route {route!r}")
        if route == ROUTE_HNSW and k > ef:
            # an ef-beam physically cannot return k results; truncating
            # silently would hand the caller [B, ef]-shaped arrays
            raise ValueError(f"route='hnsw' needs k <= ef, got k={k} ef={ef}")
        if route == ROUTE_COARSE and k > ef_coarse:
            raise ValueError(f"route='coarse' needs k <= ef_coarse, "
                             f"got k={k} ef_coarse={ef_coarse}")
        return mk(route, "forced")
    if k > ef:
        return mk(ROUTE_EXACT, f"k={k} > ef={ef}")
    if live_count <= exact_threshold:
        return mk(ROUTE_EXACT, f"live={live_count} <= {exact_threshold}")
    if ef >= live_count:
        return mk(ROUTE_EXACT, f"ef={ef} >= live={live_count}")
    if (0 < k <= ef_coarse and 4 * ef_coarse <= 3 * live_count
            and dim <= 8192):
        return mk(ROUTE_COARSE,
                  f"int8 scan + {ef_coarse}-rerank beats exact bytes at "
                  f"live={live_count}, dim={dim}")
    return mk(ROUTE_HNSW, f"live={live_count}, k={k}, ef={ef}")


def execute_plan(state: MemoryState, queries_raw: jax.Array, k: int,
                 plan: QueryPlan, *, metric: str = search.METRIC_L2,
                 codes=None) -> Tuple[jax.Array, jax.Array]:
    """Run the planned route: (ids [B,k] int64, wide scores [B,k] int64).

    All routes score with the same wide integer metric, so the planner can
    switch routes without changing a returned score's meaning. The coarse
    route takes the caller's maintained ``codes.CodeTable`` when given,
    and otherwise derives it from the state on the spot — the table is a
    pure function of the live rows, so both are bit-identical (the
    maintained table is a cost optimization, never a semantic one).
    """
    if plan.route == ROUTE_EXACT:
        return search.exact_search(state, queries_raw, k, metric=metric,
                                   use_kernel=plan.use_kernel)
    if plan.route == ROUTE_COARSE:
        from repro.core import codes as codes_lib  # lazy: leaf-level module
        table = codes if codes is not None else codes_lib.build(state)
        return search.coarse_search(state, table, queries_raw, k,
                                    ef_coarse=plan.ef_coarse, metric=metric,
                                    use_kernel=plan.use_kernel)
    ids, dists, _ = batched_hnsw_search(state, queries_raw, k, ef=plan.ef)
    return ids, dists


# --------------------------------------------------------------------------- #
# shard fan-out
# --------------------------------------------------------------------------- #


def sharded_query(mesh, axis: str, state: MemoryState, queries_raw: jax.Array,
                  k: int, plan: QueryPlan, *,
                  metric: str = search.METRIC_L2,
                  query_axis: Optional[str] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Fan the planned query out across shards (``distributed.py``).

    Every shard runs the planned route locally; candidates combine with the
    order-invariant integer ``merge_topk`` sort, so the answer is
    independent of shard count — and, for the exact route, bit-identical
    to the single-kernel scan.
    """
    from repro.core import distributed  # local import: avoids cycle at init

    if plan.route == ROUTE_EXACT:
        return distributed.distributed_search(
            mesh, axis, state, queries_raw, k, metric=metric,
            use_kernel=plan.use_kernel, query_axis=query_axis)
    return distributed.distributed_hnsw_search(
        mesh, axis, state, queries_raw, k, ef=plan.ef, query_axis=query_axis)


def sharded_host_query(state: MemoryState, n_shards: int,
                       queries_raw: jax.Array, k: int, plan: QueryPlan, *,
                       metric: str = search.METRIC_L2,
                       tables=None) -> Tuple[jax.Array, jax.Array]:
    """The planned route fanned out over a *host-side* sharded-layout state
    (no mesh): per-shard execution through the ``shard_wal`` twins, one
    order-invariant merge. This is the serve engine's sharded read path.

    Exact route: bit-identical to the single-kernel scan on the same live
    content (the merge is permutation- and layout-invariant). HNSW route:
    deterministic for a fixed shard count; bit-identical to the flat graph
    whenever every per-shard beam is exhaustive (``plan.ef`` >= per-shard
    live count) — the conformance regime DESIGN.md §7 pins. Coarse route:
    per-shard int8 scan + exact re-rank; bit-identical to flat exact
    whenever every shard's candidate set covers its slice
    (``plan.ef_coarse`` >= per-shard live count — DESIGN.md §10).
    ``tables`` optionally carries the engine's maintained per-shard code
    tables; absent, each shard derives its table from its slice.
    """
    from repro.core import shard_wal  # lazy: shard_wal imports us lazily

    if plan.route == ROUTE_EXACT:
        return shard_wal.exact_search_sharded(
            state, n_shards, queries_raw, k, metric=metric,
            use_kernel=plan.use_kernel)
    if plan.route == ROUTE_COARSE:
        return shard_wal.coarse_search_sharded(
            state, n_shards, queries_raw, k, ef_coarse=plan.ef_coarse,
            metric=metric, use_kernel=plan.use_kernel, tables=tables)
    return shard_wal.hnsw_search_sharded(state, n_shards, queries_raw, k,
                                         ef=plan.ef)


# --------------------------------------------------------------------------- #
# retrieval-set hash: the read path's audit artifact
# --------------------------------------------------------------------------- #


def retrieval_hash(ids: jax.Array, scores: jax.Array) -> int:
    """Platform-invariant hash of a retrieval set — the read-path analogue
    of the state hash: two runs agree iff every (id, score) bit agrees."""
    from repro.core import hashing
    return hashing.hash_pytree((jnp.asarray(ids).astype(jnp.int64),
                                jnp.asarray(scores).astype(jnp.int64)))
