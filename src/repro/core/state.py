"""MemoryState: the Valori kernel state as a JAX pytree (paper §5.2).

The Rust reference keeps vectors, graph and metadata inside a ``Kernel``
struct on the heap; the TPU adaptation is a statically-shaped arena:

* ``vectors``   int{8,16,32}[capacity, dim]   raw Q-format rows
* ``ids``       int64[capacity]               external ids (-1 = empty slot)
* ``valid``     bool[capacity]                live mask (delete = clear bit)
* ``links``     int32[capacity, max_links]    typed graph edges (`link` cmd)
* ``meta``      int64[capacity, meta_slots]   opaque per-row metadata words
* ``hnsw_*``    deterministic HNSW adjacency (see hnsw.py)
* scalars: ``cursor`` (next insert slot), ``count`` (live rows), ``version``
  (logical time t — increments once per applied command).

Everything is integer-typed; no float ever lives in the state, so the state
hash is platform-invariant by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract, get_contract


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MemoryState:
    # arena
    vectors: jax.Array      # [capacity, dim] raw fixed-point
    ids: jax.Array          # [capacity] int64, -1 = empty
    valid: jax.Array        # [capacity] bool
    links: jax.Array        # [capacity, max_links] int32 slot indices, -1 = none
    meta: jax.Array         # [capacity, meta_slots] int64

    # hnsw graph (dense, fixed degree per level; -1 = no edge)
    hnsw_neighbors: jax.Array  # [levels, capacity, degree] int32
    hnsw_levels: jax.Array     # [capacity] int32 — top level of each node, -1 empty
    hnsw_entry: jax.Array      # [] int32 — entry slot (paper: fixed to first node)

    # scalars
    cursor: jax.Array       # [] int32
    count: jax.Array        # [] int32
    version: jax.Array      # [] int64 — logical time t

    # static metadata (aux_data, not traced)
    contract_name: str = dataclasses.field(
        default=DEFAULT_CONTRACT.name, metadata=dict(static=True)
    )

    # ------------------------------------------------------------------ #
    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def contract(self) -> PrecisionContract:
        return get_contract(self.contract_name)

    @property
    def max_links(self) -> int:
        return self.links.shape[1]

    @property
    def hnsw_degree(self) -> int:
        return self.hnsw_neighbors.shape[2]

    @property
    def hnsw_max_levels(self) -> int:
        return self.hnsw_neighbors.shape[0]

    @property
    def t(self) -> jax.Array:
        """Monotone applied-command cursor: commands applied since genesis
        (``version`` — F bumps it exactly once per command, including
        rejected ones, so it is the logical time the durability layer keys
        snapshots and WAL offsets by; see DESIGN.md §5)."""
        return self.version


def init_state(
    capacity: int,
    dim: int,
    *,
    contract: PrecisionContract = DEFAULT_CONTRACT,
    max_links: int = 4,
    meta_slots: int = 2,
    hnsw_levels: int = 4,
    hnsw_degree: int = 16,
) -> MemoryState:
    """A fresh, empty kernel state S_0. Deterministic: all-zero/all-empty."""
    return MemoryState(
        vectors=jnp.zeros((capacity, dim), dtype=contract.storage_dtype),
        ids=jnp.full((capacity,), -1, dtype=jnp.int64),
        valid=jnp.zeros((capacity,), dtype=jnp.bool_),
        links=jnp.full((capacity, max_links), -1, dtype=jnp.int32),
        meta=jnp.zeros((capacity, meta_slots), dtype=jnp.int64),
        hnsw_neighbors=jnp.full(
            (hnsw_levels, capacity, hnsw_degree), -1, dtype=jnp.int32
        ),
        hnsw_levels=jnp.full((capacity,), -1, dtype=jnp.int32),
        hnsw_entry=jnp.asarray(-1, dtype=jnp.int32),
        cursor=jnp.asarray(0, dtype=jnp.int32),
        count=jnp.asarray(0, dtype=jnp.int32),
        version=jnp.asarray(0, dtype=jnp.int64),
        contract_name=contract.name,
    )


def live_mask(state: MemoryState) -> jax.Array:
    return state.valid


def slot_of_id(state: MemoryState, ext_id: jax.Array) -> jax.Array:
    """Slot index holding ``ext_id`` (or -1). Deterministic linear probe:
    ids are unique among valid rows, argmax of the match mask is stable."""
    match = (state.ids == ext_id) & state.valid
    any_match = jnp.any(match)
    slot = jnp.argmax(match).astype(jnp.int32)
    return jnp.where(any_match, slot, jnp.int32(-1))
