"""Deterministic HNSW (paper §7), adapted from pointer-chasing to TPU form.

The paper removes the three stochastic ingredients of classic HNSW:
  1. *Fixed ordering* — batches are applied in sorted id order (see
     ``commands.canonicalize_batch``); the command log fixes the order.
  2. *Data-dependent level assignment* — instead of an RNG draw, a node's
     level is a pure function of its external id (trailing-zero count of a
     SplitMix64 avalanche), giving the same geometric(1/2) level profile with
     zero state.
  3. *Deterministic entry point* — the first inserted node is the entry
     until a DELETE tombstones it; then ``ensure_live_entry`` promotes the
     live node with the greatest *raw* (id-derived) level, lowest id first
     (DESIGN.md §11) — a pure integer rule, so every layout picks the same
     replacement. (Consequence: node levels are capped at the entry's
     stored level at insert time; higher levels would be unreachable from
     the entry. Recorded deviation: classic HNSW promotes the entry
     opportunistically, here promotion happens only on entry death and by
     integer order.)

TPU adaptation (DESIGN.md §2): the adjacency is a dense
``[levels, capacity, degree]`` int32 array; search is a ``lax.while_loop``
beam over gathered neighbor rows; all distance comparisons use *wide* integer
L2 scores with (distance, slot) lexicographic tie-breaks, so every decision
is a pure integer comparison — bit-identical everywhere.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import MemoryState

# large sentinel distance: safely above any real wide score, well below int64 max
INF = jnp.int64(1) << 62


# --------------------------------------------------------------------------- #
# level assignment: deterministic, data-dependent (paper §7.2)
# --------------------------------------------------------------------------- #


def splitmix64(x: jax.Array) -> jax.Array:
    """SplitMix64 avalanche — the stable 'randomness' source. uint64 wraps."""
    z = x.astype(jnp.uint64) + jnp.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    return z ^ (z >> jnp.uint64(31))


def level_of_id(ext_id: jax.Array, max_levels: int) -> jax.Array:
    """Geometric(1/2) level from the id's hash: count trailing ones.

    P(level ≥ k) = 2^-k exactly, like HNSW's mL=1/ln(2) draw, but replayable.
    """
    h = splitmix64(ext_id)
    # trailing ones of h == trailing zeros of ~h
    tz = jnp.int32(0)

    def body(i, carry):
        tz, done = carry
        bit = (h >> jnp.uint64(i)) & jnp.uint64(1)
        take = jnp.logical_and(jnp.logical_not(done), bit == 1)
        tz = jnp.where(take, tz + 1, tz)
        done = jnp.logical_or(done, bit == 0)
        return tz, done

    tz, _ = jax.lax.fori_loop(0, max_levels - 1, body, (tz, jnp.bool_(False)))
    return jnp.minimum(tz, max_levels - 1).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# distances
# --------------------------------------------------------------------------- #


def _wide_l2(state: MemoryState, q_raw: jax.Array, slots: jax.Array) -> jax.Array:
    """Exact wide squared-L2 from query to the given slots; invalid → INF."""
    rows = state.vectors[slots].astype(jnp.int64)  # [n, dim]
    d = rows - q_raw.astype(jnp.int64)[None, :]
    dist = jnp.sum(d * d, axis=-1)
    ok = (slots >= 0) & state.valid[jnp.clip(slots, 0, state.capacity - 1)]
    return jnp.where(ok, dist, INF)


def _wide_l2_traverse(state: MemoryState, q_raw: jax.Array,
                      slots: jax.Array) -> jax.Array:
    """Traversal distance: like ``_wide_l2`` but tombstoned rows keep their
    true score (their vectors are still stored). The query-time beam ranks
    dead nodes as waypoints — the classic soft-delete traversal — and the
    caller masks them out of the *answer*; masking them out of the frontier
    instead would strand every live node whose only paths run through a
    tombstone (DESIGN.md §11). On a tombstone-free state this is exactly
    ``_wide_l2``."""
    safe = jnp.clip(slots, 0, state.capacity - 1)
    rows = state.vectors[safe].astype(jnp.int64)  # [n, dim]
    d = rows - q_raw.astype(jnp.int64)[None, :]
    dist = jnp.sum(d * d, axis=-1)
    return jnp.where(slots >= 0, dist, INF)


def _lex_less(d_a, s_a, d_b, s_b):
    """(distance, slot) lexicographic less-than — the deterministic tie-break."""
    return (d_a < d_b) | ((d_a == d_b) & (s_a < s_b))


def _sort_by_dist(d: jax.Array, s: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort candidate arrays by (distance, slot): a single integer key sort.

    Key packs distance (< 2^62) and slot into a sortable composite via
    stable two-key lax.sort.
    """
    d_sorted, s_sorted = jax.lax.sort((d, s), num_keys=2)
    return d_sorted, s_sorted


def _sort_dedup(d: jax.Array, s: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort by (distance, slot) and blank duplicate slots.

    A duplicated slot has an identical (d, s) pair, so duplicates are
    adjacent post-sort; the second copy is replaced by the (INF, pad)
    sentinel and a re-sort pushes it to the tail. Pure integer ops.
    """
    pad = jnp.int32(2**31 - 1)
    d, s = jax.lax.sort((d, s), num_keys=2)
    dup = jnp.zeros_like(s, dtype=jnp.bool_).at[1:].set(
        (s[1:] == s[:-1]) & (s[1:] != pad))
    d = jnp.where(dup, INF, d)
    s = jnp.where(dup, pad, s)
    return jax.lax.sort((d, s), num_keys=2)


# --------------------------------------------------------------------------- #
# greedy descent (beam = 1) for upper levels
# --------------------------------------------------------------------------- #


def greedy_step_level(state: MemoryState, q_raw: jax.Array, level: jax.Array,
                      start_slot: jax.Array,
                      neighbors_full: jax.Array | None = None,
                      static_level: int | None = None) -> jax.Array:
    """Walk to the locally-nearest node at ``level`` starting from start_slot."""

    def cond(carry):
        cur, cur_d, moved, it = carry
        return moved & (it < jnp.int32(state.capacity))

    def body(carry):
        cur, cur_d, _, it = carry
        nbrs = (neighbors_full[static_level, cur]
                if neighbors_full is not None
                else jax.lax.dynamic_index_in_dim(
                    state.hnsw_neighbors, level, axis=0, keepdims=False
                )[cur])  # [degree]
        nd = _wide_l2(state, q_raw, nbrs)
        best = jnp.argmin(nd)  # ties → lowest index; nbr lists are sorted by (d,slot)
        best_d = nd[best]
        best_s = nbrs[best]
        better = _lex_less(best_d, best_s, cur_d, cur)
        nxt = jnp.where(better, best_s, cur)
        nxt_d = jnp.where(better, best_d, cur_d)
        return nxt.astype(jnp.int32), nxt_d, better, it + 1

    d0 = _wide_l2(state, q_raw, start_slot[None])[0]
    cur, _, _, _ = jax.lax.while_loop(
        cond, body, (start_slot.astype(jnp.int32), d0, jnp.bool_(True), jnp.int32(0))
    )
    return cur


# --------------------------------------------------------------------------- #
# beam search at one level
# --------------------------------------------------------------------------- #


def search_layer(
    state: MemoryState,
    q_raw: jax.Array,
    entry_slot: jax.Array,
    level: jax.Array,
    ef: int,
    max_iters: int | None = None,
    fast: bool = False,
    neighbors_l: jax.Array | None = None,
    neighbors_full: jax.Array | None = None,
    static_level: int | None = None,
    dead_ok: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """ef-beam search at ``level``; returns (dists[ef], slots[ef]) sorted.

    Carries fixed-size arrays + a capacity-sized expansion mask. Every merge
    is a (distance, slot) sort — deterministic including ties.

    ``dead_ok=True`` (the query path under churn, DESIGN.md §11) ranks and
    expands tombstoned nodes by their true stored-vector distance instead of
    INF, so they remain traversal waypoints; the caller filters them from
    the answer. Identical to the default on tombstone-free states.

    ``fast=True`` (the bulk-ingest construction path) computes the identical
    beam with less work per expansion: the merge is a single sort — the beam
    and the fresh-masked neighbor row are disjoint by construction (``seen``
    excludes every slot ever beamed; graph rows never repeat a slot), so the
    dedup pass of ``_sort_dedup`` can never fire — expansions yielding no
    fresh neighbors skip the merge entirely (merging an all-INF row is the
    identity on a sorted beam), and expansion state rides in an ef-sized
    flag vector permuted alongside the beam instead of a capacity-sized
    scatter mask.
    """
    capacity = state.capacity
    degree = state.hnsw_degree
    if max_iters is None:
        max_iters = 2 * ef + 8
    if fast and dead_ok:
        raise ValueError("dead_ok is a query-path knob; the fast "
                         "construction path never traverses tombstones")
    dist_of = _wide_l2_traverse if dead_ok else _wide_l2

    d0 = jnp.full((ef,), INF, dtype=jnp.int64)
    s0 = jnp.full((ef,), jnp.int32(2**31 - 1), dtype=jnp.int32)
    d0 = d0.at[0].set(dist_of(state, q_raw, entry_slot[None])[0])
    s0 = s0.at[0].set(entry_slot.astype(jnp.int32))
    seen0 = jnp.zeros((capacity,), jnp.bool_).at[entry_slot].set(True)

    if neighbors_full is not None:
        # bulk path: row gathers go straight into the full [levels, capacity,
        # degree] array at a static level — no per-call slice materialization
        def row_of(cur):
            return neighbors_full[static_level, cur]
    else:
        if neighbors_l is None:
            neighbors_l = jax.lax.dynamic_index_in_dim(
                state.hnsw_neighbors, level, axis=0, keepdims=False
            )  # [capacity, degree]
        _nl = neighbors_l

        def row_of(cur):
            return _nl[cur]

    if fast:
        exp0 = jnp.zeros((ef,), jnp.bool_)

        def fcond(carry):
            d, s, exp, seen, it = carry
            return jnp.any((~exp) & (d < INF)) & (it < max_iters)

        def fbody(carry):
            d, s, exp, seen, it = carry
            unexp = (~exp) & (d < INF)
            pick = jnp.argmax(unexp)  # beam sorted ⇒ first True is nearest
            cur = jnp.clip(s[pick], 0, capacity - 1)
            exp = exp.at[pick].set(True)
            nbrs = row_of(cur)  # [degree]
            nbr_safe = jnp.clip(nbrs, 0, capacity - 1)
            fresh = (nbrs >= 0) & (~seen[nbr_safe])

            def merge(ops):
                d, s, exp, seen = ops
                nd = _wide_l2(state, q_raw, nbrs)
                nd = jnp.where(fresh, nd, INF)
                ns = jnp.where(fresh, nbr_safe, jnp.int32(2**31 - 1))
                # -1 entries route to index `capacity` and are dropped: the
                # slow path's clip-to-0 scatter writes conflicting values at
                # slot 0 (its dedup pass absorbs the fallout); here the beam
                # must stay duplicate-free, so mark only real neighbors
                tgt = jnp.where(nbrs >= 0, nbr_safe, jnp.int32(capacity))
                seen = seen.at[tgt].set(True, mode="drop")
                md = jnp.concatenate([d, nd])
                ms = jnp.concatenate([s, ns])
                mf = jnp.concatenate([exp, jnp.zeros((degree,), jnp.bool_)])
                md, ms, mf = jax.lax.sort((md, ms, mf), num_keys=2)
                return md[:ef], ms[:ef], mf[:ef], seen

            d, s, exp, seen = jax.lax.cond(
                jnp.any(fresh), merge, lambda o: o, (d, s, exp, seen))
            return d, s, exp, seen, it + 1

        d, s, _, _, _ = jax.lax.while_loop(
            fcond, fbody, (d0, s0, exp0, seen0, jnp.int32(0)))
        return d, s

    expanded0 = jnp.zeros((capacity,), jnp.bool_)

    def cond(carry):
        d, s, seen, expanded, it = carry
        safe = jnp.clip(s, 0, capacity - 1)
        unexp = (~expanded[safe]) & (d < INF)
        return jnp.any(unexp) & (it < max_iters)

    def body(carry):
        d, s, seen, expanded, it = carry
        safe = jnp.clip(s, 0, capacity - 1)
        unexp = (~expanded[safe]) & (d < INF)
        # nearest unexpanded candidate (arrays are kept sorted, so argmax of
        # the first True is the nearest)
        pick = jnp.argmax(unexp)  # first True in sorted order
        cur = safe[pick]
        expanded = expanded.at[cur].set(True)
        nbrs = row_of(cur)  # [degree]
        nbr_safe = jnp.clip(nbrs, 0, capacity - 1)
        fresh = (nbrs >= 0) & (~seen[nbr_safe])
        nd = dist_of(state, q_raw, nbrs)
        nd = jnp.where(fresh, nd, INF)
        ns = jnp.where(fresh, nbr_safe, jnp.int32(2**31 - 1))
        seen = seen.at[nbr_safe].set(seen[nbr_safe] | (nbrs >= 0))
        # merge + keep ef best (deduped: rows may repeat a neighbor)
        md = jnp.concatenate([d, nd])
        ms = jnp.concatenate([s, ns])
        md, ms = _sort_dedup(md, ms)
        return md[:ef], ms[:ef], seen, expanded, it + 1

    d, s, _, _, _ = jax.lax.while_loop(cond, body, (d0, s0, seen0, expanded0, jnp.int32(0)))
    return d, s


# --------------------------------------------------------------------------- #
# insert
# --------------------------------------------------------------------------- #


def _add_bidirectional_edges(
    state_neighbors: jax.Array,  # [capacity, degree] at one level
    vectors: jax.Array,          # [capacity, dim] raw
    valid: jax.Array,
    new_slot: jax.Array,
    cand_d: jax.Array,           # [ef] sorted candidate distances to new node
    cand_s: jax.Array,           # [ef]
    m: int,
    active: jax.Array,           # bool: is this level active for the new node
) -> jax.Array:
    """Connect new_slot ↔ its M nearest candidates, pruning to degree by
    (distance-to-owner, slot). Pure integer ordering ⇒ deterministic."""
    capacity, degree = state_neighbors.shape
    pad = jnp.int32(2**31 - 1)

    # forward edges: M best candidates (already sorted by (d, slot)), -1 padded
    idx = jnp.arange(degree)
    src = jnp.clip(idx, 0, cand_s.shape[0] - 1)
    fwd_slots = jnp.where(
        (idx < m) & (cand_d[src] < INF), cand_s[src], jnp.int32(-1)
    ).astype(jnp.int32)
    fwd = jnp.where(active, fwd_slots, state_neighbors[new_slot])
    state_neighbors = state_neighbors.at[new_slot].set(fwd)

    # reverse edges: for each of the M candidates, insert new_slot and prune
    new_vec = vectors[new_slot].astype(jnp.int64)

    def rev_one(i, nbrs_arr):
        c = cand_s[i]
        is_real = active & (cand_d[i] < INF) & (i < m) & (c != new_slot)

        def do(nbrs_arr):
            owner_vec = vectors[c].astype(jnp.int64)
            cur = nbrs_arr[c]  # [degree]
            cur_safe = jnp.clip(cur, 0, capacity - 1)
            cur_vecs = vectors[cur_safe].astype(jnp.int64)
            dd = jnp.sum((cur_vecs - owner_vec[None, :]) ** 2, axis=-1)
            dd = jnp.where(cur >= 0, dd, INF)
            d_new = jnp.sum((new_vec - owner_vec) ** 2)
            alld = jnp.concatenate([dd, d_new[None]])
            alls = jnp.concatenate(
                [jnp.where(cur >= 0, cur, pad), new_slot[None].astype(jnp.int32)]
            )
            alld, alls = _sort_dedup(alld, alls)
            kept = jnp.where(alld[:degree] < INF, alls[:degree], jnp.int32(-1))
            return nbrs_arr.at[c].set(kept)

        return jax.lax.cond(is_real, do, lambda a: a, nbrs_arr)

    state_neighbors = jax.lax.fori_loop(0, cand_s.shape[0], rev_one, state_neighbors)
    return state_neighbors


def _add_edges_fast(neighbors: jax.Array, lvl: int, vectors: jax.Array,
                    new_slot: jax.Array, cand_d: jax.Array, cand_s: jax.Array,
                    m: int) -> jax.Array:
    """Bulk-path edge update on the full [levels, capacity, degree] array.

    Equivalent to ``_add_bidirectional_edges`` at one (static) level with
    ``active=True``, but with no per-level slice round-trip: the forward row
    and the m pruned reverse rows go in as direct (level, row) scatters, and
    the per-candidate loop is one batched prune — candidates are distinct
    rows (the fast-path beam is duplicate-free), so the sequential loop's
    iterations are independent."""
    _, capacity, degree = neighbors.shape
    pad = jnp.int32(2**31 - 1)

    idx = jnp.arange(degree)
    src = jnp.clip(idx, 0, cand_s.shape[0] - 1)
    fwd = jnp.where(
        (idx < m) & (cand_d[src] < INF), cand_s[src], jnp.int32(-1)
    ).astype(jnp.int32)
    neighbors = neighbors.at[lvl, new_slot].set(fwd)

    new_vec = vectors[new_slot].astype(jnp.int64)
    mm = min(m, cand_s.shape[0])
    c = cand_s[:mm]                  # [mm]
    is_real = (cand_d[:mm] < INF) & (c != new_slot)
    c_safe = jnp.clip(c, 0, capacity - 1)
    owner_vecs = vectors[c_safe].astype(jnp.int64)     # [mm, dim]
    cur = neighbors[lvl, c_safe]                       # [mm, degree]
    cur_safe = jnp.clip(cur, 0, capacity - 1)
    cur_vecs = vectors[cur_safe].astype(jnp.int64)     # [mm, degree, dim]
    dd = jnp.sum((cur_vecs - owner_vecs[:, None, :]) ** 2, axis=-1)
    dd = jnp.where(cur >= 0, dd, INF)
    d_new = jnp.sum((new_vec[None, :] - owner_vecs) ** 2, axis=-1)
    alld = jnp.concatenate([dd, d_new[:, None]], axis=1)
    alls = jnp.concatenate(
        [jnp.where(cur >= 0, cur, pad),
         jnp.broadcast_to(new_slot.astype(jnp.int32), (mm,))[:, None]],
        axis=1)
    alld, alls = jax.lax.sort((alld, alls), num_keys=2, dimension=1)
    kept = jnp.where(alld[:, :degree] < INF, alls[:, :degree], jnp.int32(-1))
    rows = jnp.where(is_real, c_safe, jnp.int32(capacity))
    return neighbors.at[lvl, rows].set(kept, mode="drop")


def hnsw_insert(state: MemoryState, new_slot: jax.Array, *, ef_construction: int = 32,
                m: int | None = None, fast: bool = False) -> MemoryState:
    """Incrementally insert the (already stored) row at ``new_slot``.

    Fully deterministic: level from id hash, entry fixed at first node,
    all selections tie-broken by slot id.

    ``fast=True`` selects the bulk-ingest variant used by
    ``machine.bulk_apply``: per-level work is gated behind ``lax.cond`` so
    inactive levels skip their beam search at runtime, and the reverse-edge
    loop visits only the M candidates that can actually connect. Both are
    pure control-flow changes — every value the default path would *use* is
    computed identically, so the resulting state is bit-identical
    (tests/test_bulk_apply.py proves this on randomized logs).
    """
    if m is None:
        m = state.hnsw_degree // 2
    if fast and m > ef_construction:
        # with more connectable candidates than beam slots, the default
        # path's forward-edge writer clip-repeats the last candidate,
        # producing duplicate row entries its dedup-sorts absorb — the
        # fast path's duplicate-free-beam invariant does not hold there,
        # so take the reference implementation (both args are static)
        fast = False
    max_levels = state.hnsw_max_levels
    q_raw = state.vectors[new_slot]
    ext_id = state.ids[new_slot]

    is_first = state.hnsw_entry < 0
    raw_level = level_of_id(ext_id, max_levels)
    entry = jnp.where(is_first, new_slot.astype(jnp.int32), state.hnsw_entry)
    entry_level = jnp.where(
        is_first, raw_level, state.hnsw_levels[jnp.clip(entry, 0, state.capacity - 1)]
    )
    # paper: entry fixed to first node ⇒ cap level so all nodes stay reachable
    node_level = jnp.minimum(raw_level, entry_level)

    state = dataclasses.replace(
        state,
        hnsw_levels=state.hnsw_levels.at[new_slot].set(node_level),
        hnsw_entry=entry.astype(jnp.int32),
    )

    if fast:
        # Unrolled static-level variant for bulk ingest. Identical values,
        # cheaper control flow: every lax.cond carries one [capacity, degree]
        # level slice instead of the whole [levels, capacity, degree] array,
        # inactive levels skip their beam search at runtime, and the
        # reverse-edge loop is batched over the m connectable candidates.
        def build(neighbors: jax.Array) -> jax.Array:
            # phase 1: greedy descent, entry's top level → node_level+1
            cur = entry.astype(jnp.int32)
            for lvl in range(max_levels - 1, 0, -1):
                do = (jnp.int32(lvl) <= entry_level) & (jnp.int32(lvl) > node_level)
                cur = jax.lax.cond(
                    do,
                    lambda c, lvl=lvl: greedy_step_level(
                        state, q_raw, jnp.int32(lvl), c,
                        neighbors_full=neighbors, static_level=lvl),
                    lambda c: c, cur)

            # phase 2: beam search + connect at levels node_level..0
            for lvl in range(max_levels - 1, -1, -1):
                active = jnp.int32(lvl) <= node_level

                def do_level(args, lvl=lvl):
                    nbrs, c = args
                    d, s = search_layer(state, q_raw, c, jnp.int32(lvl),
                                        ef_construction, fast=True,
                                        neighbors_full=nbrs,
                                        static_level=lvl)
                    # exclude self; the beam is duplicate-free, so a plain
                    # sort pushes the blanked entry back to the tail
                    d = jnp.where(s == new_slot, INF, d)
                    s = jnp.where(s == new_slot, jnp.int32(2**31 - 1), s)
                    d, s = jax.lax.sort((d, s), num_keys=2)
                    nbrs = _add_edges_fast(
                        nbrs, lvl, state.vectors, new_slot.astype(jnp.int32),
                        d, s, m)
                    nxt = jnp.where(d[0] < INF, s[0], c).astype(jnp.int32)
                    return nbrs, nxt

                neighbors, cur = jax.lax.cond(
                    active, do_level, lambda a: a, (neighbors, cur))
            return neighbors

        neighbors = jax.lax.cond(
            jnp.logical_not(is_first), build, lambda n: n,
            state.hnsw_neighbors)
        return dataclasses.replace(state, hnsw_neighbors=neighbors)

    def not_first_insert(state: MemoryState) -> MemoryState:
        # phase 1: greedy descent from the entry's top level to node_level+1
        def descend(lvl_rev, cur):
            lvl = jnp.int32(max_levels - 1 - lvl_rev)
            do = (lvl <= entry_level) & (lvl > node_level)
            return jnp.where(
                do, greedy_step_level(state, q_raw, lvl, cur), cur
            ).astype(jnp.int32)

        cur = jax.lax.fori_loop(0, max_levels, descend, entry.astype(jnp.int32))

        # phase 2: beam search + connect at levels node_level..0
        neighbors = state.hnsw_neighbors

        def connect(lvl_rev, carry):
            neighbors, cur = carry
            lvl = jnp.int32(max_levels - 1 - lvl_rev)
            active = lvl <= node_level
            # search against a state view with current neighbor arrays
            st = dataclasses.replace(state, hnsw_neighbors=neighbors)
            d, s = search_layer(st, q_raw, cur, lvl, ef_construction)
            # exclude self from candidates
            d = jnp.where(s == new_slot, INF, d)
            s = jnp.where(s == new_slot, jnp.int32(2**31 - 1), s)
            d, s = _sort_dedup(d, s)
            lvl_nbrs = jax.lax.dynamic_index_in_dim(neighbors, lvl, 0, keepdims=False)
            lvl_nbrs = _add_bidirectional_edges(
                lvl_nbrs, state.vectors, state.valid, new_slot.astype(jnp.int32),
                d, s, m, active
            )
            neighbors = jax.lax.dynamic_update_index_in_dim(neighbors, lvl_nbrs, lvl, 0)
            # next level starts from the best found here (when this level ran)
            nxt = jnp.where(active & (d[0] < INF), s[0], cur).astype(jnp.int32)
            return neighbors, nxt

        neighbors, _ = jax.lax.fori_loop(0, max_levels, connect, (neighbors, cur))
        return dataclasses.replace(state, hnsw_neighbors=neighbors)

    return jax.lax.cond(jnp.logical_not(is_first), not_first_insert, lambda s: s, state)


# --------------------------------------------------------------------------- #
# entry-point repair on delete (DESIGN.md §11)
# --------------------------------------------------------------------------- #


def raw_levels(state: MemoryState) -> jax.Array:
    """``level_of_id`` over the whole arena: [capacity] int32.

    The *raw* (uncapped) level is a pure function of each row's external id,
    so every layout holding the same live rows computes the same values.
    The repair and re-link orders below key on it instead of the stored
    (entry-capped) ``hnsw_levels``, whose values depend on each graph's own
    entry history and therefore differ across layouts."""
    return jax.vmap(lambda i: level_of_id(i, state.hnsw_max_levels))(state.ids)


def repair_entry(state: MemoryState) -> jax.Array:
    """The deterministic replacement entry after the current one dies: the
    live slot maximizing (raw level, then lowest id) — exactly the node a
    fresh build of the same live rows makes its entry (``fresh_build``
    inserts in this order, and a first insert is never level-capped).
    Returns -1 when nothing is live. Pure integer ordering: every layout
    picks the same replacement."""
    lv = jnp.where(state.valid, raw_levels(state), jnp.int32(-1))
    best = jnp.max(lv)
    id_key = jnp.where(state.valid & (lv == best), state.ids,
                       jnp.int64(1) << 62)
    slot = jnp.argmin(id_key).astype(jnp.int32)
    return jnp.where(jnp.any(state.valid), slot, jnp.int32(-1))


def ensure_live_entry(state: MemoryState) -> MemoryState:
    """Post-delete invariant: ``hnsw_entry`` is live, or -1 when the arena
    holds no live rows (the next insert then re-seeds the graph through the
    ordinary first-insert path). When a DELETE tombstones the entry, the
    promotion rule of ``repair_entry`` runs; the level-cap rule re-anchors
    to the promoted node's stored level automatically (``hnsw_insert``
    reads ``hnsw_levels[entry]``). Repair touches ONLY ``hnsw_entry`` —
    the tombstoned node keeps its edges and stays a traversal waypoint
    until a re-link sweeps it (``relink``)."""
    entry = state.hnsw_entry
    safe = jnp.clip(entry, 0, state.capacity - 1)
    dead = (entry >= 0) & jnp.logical_not(state.valid[safe])
    new_entry = jax.lax.cond(dead, repair_entry,
                             lambda s: s.hnsw_entry, state)
    return dataclasses.replace(state, hnsw_entry=new_entry)


# --------------------------------------------------------------------------- #
# deterministic re-link: graph compaction (DESIGN.md §11)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class RelinkPolicy:
    """When the serve engine re-links (rebuilds) the HNSW graph from its
    live rows — the graph twin of ``wal.CompactionPolicy``. Every
    ``check_every`` ingested global commands (and only once at least
    ``min_deletes`` effective deletes have accrued since the last re-link),
    the pass fires when deletes reach ``dead_ratio`` of the graph's
    (dead + live) node population. All three facts derive from the global
    command stream, so flat and sharded engines fed the same batches fire
    at the same batch boundaries — the schedule itself is layout-invariant
    (per-shard cursors and per-slice tombstone counts are not, and are
    never consulted)."""
    dead_ratio: float = 0.5
    min_deletes: int = 64
    check_every: int = 64

    def __post_init__(self):
        if not 0.0 < self.dead_ratio <= 1.0:
            raise ValueError("dead_ratio must be in (0, 1]")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")
        if self.min_deletes < 1:
            raise ValueError("min_deletes must be >= 1")


def relink_order(state: MemoryState) -> jax.Array:
    """Canonical re-insertion order over the live slots: (raw level desc,
    id asc), dead slots pushed to the tail as the ``capacity`` sentinel.
    Returns [capacity] int32 slot indices. A pure function of the arena's
    (ids, valid) — every holder of the same live rows derives the same
    order, and its head is exactly ``repair_entry``'s choice."""
    cap = state.capacity
    lv = raw_levels(state)
    big = jnp.int64(1) << 40
    k1 = jnp.where(state.valid,
                   (state.hnsw_max_levels - lv).astype(jnp.int64), big)
    k2 = jnp.where(state.valid, state.ids, jnp.int64(1) << 62)
    slots = jnp.arange(cap, dtype=jnp.int32)
    k1s, _, order = jax.lax.sort((k1, k2, slots), num_keys=2)
    return jnp.where(k1s < big, order, jnp.int32(cap))


def _blank_graph(state: MemoryState) -> MemoryState:
    return dataclasses.replace(
        state,
        hnsw_neighbors=jnp.full_like(state.hnsw_neighbors, -1),
        hnsw_levels=jnp.full_like(state.hnsw_levels, -1),
        hnsw_entry=jnp.asarray(-1, jnp.int32))


@partial(jax.jit, static_argnames=("ef_construction",))
def relink(state: MemoryState, *, ef_construction: int = 32) -> MemoryState:
    """Deterministic graph compaction: rebuild the HNSW arrays from the
    live rows only, in ``relink_order``, leaving the arena (vectors / ids /
    valid / meta / links and every scalar, ``version`` included) untouched.

    The bit-exact contract (tests/test_hnsw.py): ``hash_pytree(relink(S))
    == hash_pytree(fresh_build(S))`` — the jitted scan over the fast insert
    path must land on exactly the graph the reference per-row build lands
    on. Consequences of the canonical order: tombstoned waypoints vanish,
    the new entry is ``repair_entry``'s choice, and no node's level is
    capped (the first re-inserted node carries the maximal raw level), so a
    re-linked graph is also a *better* graph than the churned one."""
    blank = _blank_graph(state)
    order = relink_order(state)
    cap = state.capacity

    def body(carry, slot):
        def ins(c):
            nbrs, lvls, ent = c
            st = dataclasses.replace(
                blank, hnsw_neighbors=nbrs, hnsw_levels=lvls, hnsw_entry=ent)
            out = hnsw_insert(st, slot, ef_construction=ef_construction,
                              fast=True)
            return out.hnsw_neighbors, out.hnsw_levels, out.hnsw_entry

        return jax.lax.cond(slot < cap, ins, lambda c: c, carry), None

    carry0 = (blank.hnsw_neighbors, blank.hnsw_levels, blank.hnsw_entry)
    (nbrs, lvls, ent), _ = jax.lax.scan(body, carry0, order)
    return dataclasses.replace(
        state, hnsw_neighbors=nbrs, hnsw_levels=lvls, hnsw_entry=ent)


def fresh_build(state: MemoryState, *, ef_construction: int = 32
                ) -> MemoryState:
    """The definitional re-link reference: the same canonical order, one
    reference-path ``hnsw_insert`` per live row on the host. ``relink``
    must match it bit-for-bit — this is the oracle the contract test
    runs, never the production path."""
    out = _blank_graph(state)
    order = np.asarray(relink_order(state))
    for slot in order:
        if int(slot) >= state.capacity:
            break  # dead-slot sentinels are all at the tail
        out = hnsw_insert(out, jnp.int32(int(slot)),
                          ef_construction=ef_construction)
    return out


# --------------------------------------------------------------------------- #
# query
# --------------------------------------------------------------------------- #


def hnsw_search(state: MemoryState, q_raw: jax.Array, k: int, *, ef: int = 64
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ANN search: returns (ids[k] int64, dists[k] wide int64, slots[k]).

    Missing results are (-1, INF, -1). Deterministic for a fixed state.
    """
    max_levels = state.hnsw_max_levels
    entry = state.hnsw_entry
    have_graph = entry >= 0
    entry_safe = jnp.clip(entry, 0, state.capacity - 1)
    entry_level = jnp.where(have_graph, state.hnsw_levels[entry_safe], 0)

    def descend(lvl_rev, cur):
        lvl = jnp.int32(max_levels - 1 - lvl_rev)
        do = (lvl <= entry_level) & (lvl > 0) & have_graph
        return jnp.where(do, greedy_step_level(state, q_raw, lvl, cur), cur).astype(jnp.int32)

    cur = jax.lax.fori_loop(0, max_levels, descend, entry_safe.astype(jnp.int32))
    # Level-0 beam traverses tombstones (dead_ok) so a churned graph stays
    # fully reachable; dead rows are then dropped from the *answer*, not the
    # frontier. On a tombstone-free state this is bit-identical to the
    # live-only beam (every beamed slot is valid), so insert-only goldens
    # are untouched.
    d, s = search_layer(state, q_raw, cur, jnp.int32(0), ef, dead_ok=True)
    safe = jnp.clip(s, 0, state.capacity - 1)
    live = (d < INF) & state.valid[safe]
    d = jnp.where(live, d, INF)
    s = jnp.where(live, s, jnp.int32(2 ** 31 - 1))
    d, s = jax.lax.sort((d, s), num_keys=2)
    d, s = d[:k], s[:k]
    ok = (d < INF) & have_graph
    slots = jnp.where(ok, s, jnp.int32(-1))
    ids = jnp.where(ok, state.ids[jnp.clip(s, 0, state.capacity - 1)], jnp.int64(-1))
    dists = jnp.where(ok, d, INF)
    return ids, dists, slots
