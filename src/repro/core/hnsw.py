"""Deterministic HNSW (paper §7), adapted from pointer-chasing to TPU form.

The paper removes the three stochastic ingredients of classic HNSW:
  1. *Fixed ordering* — batches are applied in sorted id order (see
     ``commands.canonicalize_batch``); the command log fixes the order.
  2. *Data-dependent level assignment* — instead of an RNG draw, a node's
     level is a pure function of its external id (trailing-zero count of a
     SplitMix64 avalanche), giving the same geometric(1/2) level profile with
     zero state.
  3. *Fixed entry point* — the first inserted node is the entry forever.
     (Consequence: node levels are capped at the entry's level; higher levels
     would be unreachable from the fixed entry. Recorded deviation: classic
     HNSW promotes the entry, the paper pins it.)

TPU adaptation (DESIGN.md §2): the adjacency is a dense
``[levels, capacity, degree]`` int32 array; search is a ``lax.while_loop``
beam over gathered neighbor rows; all distance comparisons use *wide* integer
L2 scores with (distance, slot) lexicographic tie-breaks, so every decision
is a pure integer comparison — bit-identical everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.state import MemoryState

# large sentinel distance: safely above any real wide score, well below int64 max
INF = jnp.int64(1) << 62


# --------------------------------------------------------------------------- #
# level assignment: deterministic, data-dependent (paper §7.2)
# --------------------------------------------------------------------------- #


def splitmix64(x: jax.Array) -> jax.Array:
    """SplitMix64 avalanche — the stable 'randomness' source. uint64 wraps."""
    z = x.astype(jnp.uint64) + jnp.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    return z ^ (z >> jnp.uint64(31))


def level_of_id(ext_id: jax.Array, max_levels: int) -> jax.Array:
    """Geometric(1/2) level from the id's hash: count trailing ones.

    P(level ≥ k) = 2^-k exactly, like HNSW's mL=1/ln(2) draw, but replayable.
    """
    h = splitmix64(ext_id)
    # trailing ones of h == trailing zeros of ~h
    tz = jnp.int32(0)

    def body(i, carry):
        tz, done = carry
        bit = (h >> jnp.uint64(i)) & jnp.uint64(1)
        take = jnp.logical_and(jnp.logical_not(done), bit == 1)
        tz = jnp.where(take, tz + 1, tz)
        done = jnp.logical_or(done, bit == 0)
        return tz, done

    tz, _ = jax.lax.fori_loop(0, max_levels - 1, body, (tz, jnp.bool_(False)))
    return jnp.minimum(tz, max_levels - 1).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# distances
# --------------------------------------------------------------------------- #


def _wide_l2(state: MemoryState, q_raw: jax.Array, slots: jax.Array) -> jax.Array:
    """Exact wide squared-L2 from query to the given slots; invalid → INF."""
    rows = state.vectors[slots].astype(jnp.int64)  # [n, dim]
    d = rows - q_raw.astype(jnp.int64)[None, :]
    dist = jnp.sum(d * d, axis=-1)
    ok = (slots >= 0) & state.valid[jnp.clip(slots, 0, state.capacity - 1)]
    return jnp.where(ok, dist, INF)


def _lex_less(d_a, s_a, d_b, s_b):
    """(distance, slot) lexicographic less-than — the deterministic tie-break."""
    return (d_a < d_b) | ((d_a == d_b) & (s_a < s_b))


def _sort_by_dist(d: jax.Array, s: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort candidate arrays by (distance, slot): a single integer key sort.

    Key packs distance (< 2^62) and slot into a sortable composite via
    stable two-key lax.sort.
    """
    d_sorted, s_sorted = jax.lax.sort((d, s), num_keys=2)
    return d_sorted, s_sorted


def _sort_dedup(d: jax.Array, s: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort by (distance, slot) and blank duplicate slots.

    A duplicated slot has an identical (d, s) pair, so duplicates are
    adjacent post-sort; the second copy is replaced by the (INF, pad)
    sentinel and a re-sort pushes it to the tail. Pure integer ops.
    """
    pad = jnp.int32(2**31 - 1)
    d, s = jax.lax.sort((d, s), num_keys=2)
    dup = jnp.zeros_like(s, dtype=jnp.bool_).at[1:].set(
        (s[1:] == s[:-1]) & (s[1:] != pad))
    d = jnp.where(dup, INF, d)
    s = jnp.where(dup, pad, s)
    return jax.lax.sort((d, s), num_keys=2)


# --------------------------------------------------------------------------- #
# greedy descent (beam = 1) for upper levels
# --------------------------------------------------------------------------- #


def greedy_step_level(state: MemoryState, q_raw: jax.Array, level: jax.Array,
                      start_slot: jax.Array,
                      neighbors_full: jax.Array | None = None,
                      static_level: int | None = None) -> jax.Array:
    """Walk to the locally-nearest node at ``level`` starting from start_slot."""

    def cond(carry):
        cur, cur_d, moved, it = carry
        return moved & (it < jnp.int32(state.capacity))

    def body(carry):
        cur, cur_d, _, it = carry
        nbrs = (neighbors_full[static_level, cur]
                if neighbors_full is not None
                else jax.lax.dynamic_index_in_dim(
                    state.hnsw_neighbors, level, axis=0, keepdims=False
                )[cur])  # [degree]
        nd = _wide_l2(state, q_raw, nbrs)
        best = jnp.argmin(nd)  # ties → lowest index; nbr lists are sorted by (d,slot)
        best_d = nd[best]
        best_s = nbrs[best]
        better = _lex_less(best_d, best_s, cur_d, cur)
        nxt = jnp.where(better, best_s, cur)
        nxt_d = jnp.where(better, best_d, cur_d)
        return nxt.astype(jnp.int32), nxt_d, better, it + 1

    d0 = _wide_l2(state, q_raw, start_slot[None])[0]
    cur, _, _, _ = jax.lax.while_loop(
        cond, body, (start_slot.astype(jnp.int32), d0, jnp.bool_(True), jnp.int32(0))
    )
    return cur


# --------------------------------------------------------------------------- #
# beam search at one level
# --------------------------------------------------------------------------- #


def search_layer(
    state: MemoryState,
    q_raw: jax.Array,
    entry_slot: jax.Array,
    level: jax.Array,
    ef: int,
    max_iters: int | None = None,
    fast: bool = False,
    neighbors_l: jax.Array | None = None,
    neighbors_full: jax.Array | None = None,
    static_level: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """ef-beam search at ``level``; returns (dists[ef], slots[ef]) sorted.

    Carries fixed-size arrays + a capacity-sized expansion mask. Every merge
    is a (distance, slot) sort — deterministic including ties.

    ``fast=True`` (the bulk-ingest construction path) computes the identical
    beam with less work per expansion: the merge is a single sort — the beam
    and the fresh-masked neighbor row are disjoint by construction (``seen``
    excludes every slot ever beamed; graph rows never repeat a slot), so the
    dedup pass of ``_sort_dedup`` can never fire — expansions yielding no
    fresh neighbors skip the merge entirely (merging an all-INF row is the
    identity on a sorted beam), and expansion state rides in an ef-sized
    flag vector permuted alongside the beam instead of a capacity-sized
    scatter mask.
    """
    capacity = state.capacity
    degree = state.hnsw_degree
    if max_iters is None:
        max_iters = 2 * ef + 8

    d0 = jnp.full((ef,), INF, dtype=jnp.int64)
    s0 = jnp.full((ef,), jnp.int32(2**31 - 1), dtype=jnp.int32)
    d0 = d0.at[0].set(_wide_l2(state, q_raw, entry_slot[None])[0])
    s0 = s0.at[0].set(entry_slot.astype(jnp.int32))
    seen0 = jnp.zeros((capacity,), jnp.bool_).at[entry_slot].set(True)

    if neighbors_full is not None:
        # bulk path: row gathers go straight into the full [levels, capacity,
        # degree] array at a static level — no per-call slice materialization
        def row_of(cur):
            return neighbors_full[static_level, cur]
    else:
        if neighbors_l is None:
            neighbors_l = jax.lax.dynamic_index_in_dim(
                state.hnsw_neighbors, level, axis=0, keepdims=False
            )  # [capacity, degree]
        _nl = neighbors_l

        def row_of(cur):
            return _nl[cur]

    if fast:
        exp0 = jnp.zeros((ef,), jnp.bool_)

        def fcond(carry):
            d, s, exp, seen, it = carry
            return jnp.any((~exp) & (d < INF)) & (it < max_iters)

        def fbody(carry):
            d, s, exp, seen, it = carry
            unexp = (~exp) & (d < INF)
            pick = jnp.argmax(unexp)  # beam sorted ⇒ first True is nearest
            cur = jnp.clip(s[pick], 0, capacity - 1)
            exp = exp.at[pick].set(True)
            nbrs = row_of(cur)  # [degree]
            nbr_safe = jnp.clip(nbrs, 0, capacity - 1)
            fresh = (nbrs >= 0) & (~seen[nbr_safe])

            def merge(ops):
                d, s, exp, seen = ops
                nd = _wide_l2(state, q_raw, nbrs)
                nd = jnp.where(fresh, nd, INF)
                ns = jnp.where(fresh, nbr_safe, jnp.int32(2**31 - 1))
                # -1 entries route to index `capacity` and are dropped: the
                # slow path's clip-to-0 scatter writes conflicting values at
                # slot 0 (its dedup pass absorbs the fallout); here the beam
                # must stay duplicate-free, so mark only real neighbors
                tgt = jnp.where(nbrs >= 0, nbr_safe, jnp.int32(capacity))
                seen = seen.at[tgt].set(True, mode="drop")
                md = jnp.concatenate([d, nd])
                ms = jnp.concatenate([s, ns])
                mf = jnp.concatenate([exp, jnp.zeros((degree,), jnp.bool_)])
                md, ms, mf = jax.lax.sort((md, ms, mf), num_keys=2)
                return md[:ef], ms[:ef], mf[:ef], seen

            d, s, exp, seen = jax.lax.cond(
                jnp.any(fresh), merge, lambda o: o, (d, s, exp, seen))
            return d, s, exp, seen, it + 1

        d, s, _, _, _ = jax.lax.while_loop(
            fcond, fbody, (d0, s0, exp0, seen0, jnp.int32(0)))
        return d, s

    expanded0 = jnp.zeros((capacity,), jnp.bool_)

    def cond(carry):
        d, s, seen, expanded, it = carry
        safe = jnp.clip(s, 0, capacity - 1)
        unexp = (~expanded[safe]) & (d < INF)
        return jnp.any(unexp) & (it < max_iters)

    def body(carry):
        d, s, seen, expanded, it = carry
        safe = jnp.clip(s, 0, capacity - 1)
        unexp = (~expanded[safe]) & (d < INF)
        # nearest unexpanded candidate (arrays are kept sorted, so argmax of
        # the first True is the nearest)
        pick = jnp.argmax(unexp)  # first True in sorted order
        cur = safe[pick]
        expanded = expanded.at[cur].set(True)
        nbrs = row_of(cur)  # [degree]
        nbr_safe = jnp.clip(nbrs, 0, capacity - 1)
        fresh = (nbrs >= 0) & (~seen[nbr_safe])
        nd = _wide_l2(state, q_raw, nbrs)
        nd = jnp.where(fresh, nd, INF)
        ns = jnp.where(fresh, nbr_safe, jnp.int32(2**31 - 1))
        seen = seen.at[nbr_safe].set(seen[nbr_safe] | (nbrs >= 0))
        # merge + keep ef best (deduped: rows may repeat a neighbor)
        md = jnp.concatenate([d, nd])
        ms = jnp.concatenate([s, ns])
        md, ms = _sort_dedup(md, ms)
        return md[:ef], ms[:ef], seen, expanded, it + 1

    d, s, _, _, _ = jax.lax.while_loop(cond, body, (d0, s0, seen0, expanded0, jnp.int32(0)))
    return d, s


# --------------------------------------------------------------------------- #
# insert
# --------------------------------------------------------------------------- #


def _add_bidirectional_edges(
    state_neighbors: jax.Array,  # [capacity, degree] at one level
    vectors: jax.Array,          # [capacity, dim] raw
    valid: jax.Array,
    new_slot: jax.Array,
    cand_d: jax.Array,           # [ef] sorted candidate distances to new node
    cand_s: jax.Array,           # [ef]
    m: int,
    active: jax.Array,           # bool: is this level active for the new node
) -> jax.Array:
    """Connect new_slot ↔ its M nearest candidates, pruning to degree by
    (distance-to-owner, slot). Pure integer ordering ⇒ deterministic."""
    capacity, degree = state_neighbors.shape
    pad = jnp.int32(2**31 - 1)

    # forward edges: M best candidates (already sorted by (d, slot)), -1 padded
    idx = jnp.arange(degree)
    src = jnp.clip(idx, 0, cand_s.shape[0] - 1)
    fwd_slots = jnp.where(
        (idx < m) & (cand_d[src] < INF), cand_s[src], jnp.int32(-1)
    ).astype(jnp.int32)
    fwd = jnp.where(active, fwd_slots, state_neighbors[new_slot])
    state_neighbors = state_neighbors.at[new_slot].set(fwd)

    # reverse edges: for each of the M candidates, insert new_slot and prune
    new_vec = vectors[new_slot].astype(jnp.int64)

    def rev_one(i, nbrs_arr):
        c = cand_s[i]
        is_real = active & (cand_d[i] < INF) & (i < m) & (c != new_slot)

        def do(nbrs_arr):
            owner_vec = vectors[c].astype(jnp.int64)
            cur = nbrs_arr[c]  # [degree]
            cur_safe = jnp.clip(cur, 0, capacity - 1)
            cur_vecs = vectors[cur_safe].astype(jnp.int64)
            dd = jnp.sum((cur_vecs - owner_vec[None, :]) ** 2, axis=-1)
            dd = jnp.where(cur >= 0, dd, INF)
            d_new = jnp.sum((new_vec - owner_vec) ** 2)
            alld = jnp.concatenate([dd, d_new[None]])
            alls = jnp.concatenate(
                [jnp.where(cur >= 0, cur, pad), new_slot[None].astype(jnp.int32)]
            )
            alld, alls = _sort_dedup(alld, alls)
            kept = jnp.where(alld[:degree] < INF, alls[:degree], jnp.int32(-1))
            return nbrs_arr.at[c].set(kept)

        return jax.lax.cond(is_real, do, lambda a: a, nbrs_arr)

    state_neighbors = jax.lax.fori_loop(0, cand_s.shape[0], rev_one, state_neighbors)
    return state_neighbors


def _add_edges_fast(neighbors: jax.Array, lvl: int, vectors: jax.Array,
                    new_slot: jax.Array, cand_d: jax.Array, cand_s: jax.Array,
                    m: int) -> jax.Array:
    """Bulk-path edge update on the full [levels, capacity, degree] array.

    Equivalent to ``_add_bidirectional_edges`` at one (static) level with
    ``active=True``, but with no per-level slice round-trip: the forward row
    and the m pruned reverse rows go in as direct (level, row) scatters, and
    the per-candidate loop is one batched prune — candidates are distinct
    rows (the fast-path beam is duplicate-free), so the sequential loop's
    iterations are independent."""
    _, capacity, degree = neighbors.shape
    pad = jnp.int32(2**31 - 1)

    idx = jnp.arange(degree)
    src = jnp.clip(idx, 0, cand_s.shape[0] - 1)
    fwd = jnp.where(
        (idx < m) & (cand_d[src] < INF), cand_s[src], jnp.int32(-1)
    ).astype(jnp.int32)
    neighbors = neighbors.at[lvl, new_slot].set(fwd)

    new_vec = vectors[new_slot].astype(jnp.int64)
    mm = min(m, cand_s.shape[0])
    c = cand_s[:mm]                  # [mm]
    is_real = (cand_d[:mm] < INF) & (c != new_slot)
    c_safe = jnp.clip(c, 0, capacity - 1)
    owner_vecs = vectors[c_safe].astype(jnp.int64)     # [mm, dim]
    cur = neighbors[lvl, c_safe]                       # [mm, degree]
    cur_safe = jnp.clip(cur, 0, capacity - 1)
    cur_vecs = vectors[cur_safe].astype(jnp.int64)     # [mm, degree, dim]
    dd = jnp.sum((cur_vecs - owner_vecs[:, None, :]) ** 2, axis=-1)
    dd = jnp.where(cur >= 0, dd, INF)
    d_new = jnp.sum((new_vec[None, :] - owner_vecs) ** 2, axis=-1)
    alld = jnp.concatenate([dd, d_new[:, None]], axis=1)
    alls = jnp.concatenate(
        [jnp.where(cur >= 0, cur, pad),
         jnp.broadcast_to(new_slot.astype(jnp.int32), (mm,))[:, None]],
        axis=1)
    alld, alls = jax.lax.sort((alld, alls), num_keys=2, dimension=1)
    kept = jnp.where(alld[:, :degree] < INF, alls[:, :degree], jnp.int32(-1))
    rows = jnp.where(is_real, c_safe, jnp.int32(capacity))
    return neighbors.at[lvl, rows].set(kept, mode="drop")


def hnsw_insert(state: MemoryState, new_slot: jax.Array, *, ef_construction: int = 32,
                m: int | None = None, fast: bool = False) -> MemoryState:
    """Incrementally insert the (already stored) row at ``new_slot``.

    Fully deterministic: level from id hash, entry fixed at first node,
    all selections tie-broken by slot id.

    ``fast=True`` selects the bulk-ingest variant used by
    ``machine.bulk_apply``: per-level work is gated behind ``lax.cond`` so
    inactive levels skip their beam search at runtime, and the reverse-edge
    loop visits only the M candidates that can actually connect. Both are
    pure control-flow changes — every value the default path would *use* is
    computed identically, so the resulting state is bit-identical
    (tests/test_bulk_apply.py proves this on randomized logs).
    """
    if m is None:
        m = state.hnsw_degree // 2
    if fast and m > ef_construction:
        # with more connectable candidates than beam slots, the default
        # path's forward-edge writer clip-repeats the last candidate,
        # producing duplicate row entries its dedup-sorts absorb — the
        # fast path's duplicate-free-beam invariant does not hold there,
        # so take the reference implementation (both args are static)
        fast = False
    max_levels = state.hnsw_max_levels
    q_raw = state.vectors[new_slot]
    ext_id = state.ids[new_slot]

    is_first = state.hnsw_entry < 0
    raw_level = level_of_id(ext_id, max_levels)
    entry = jnp.where(is_first, new_slot.astype(jnp.int32), state.hnsw_entry)
    entry_level = jnp.where(
        is_first, raw_level, state.hnsw_levels[jnp.clip(entry, 0, state.capacity - 1)]
    )
    # paper: entry fixed to first node ⇒ cap level so all nodes stay reachable
    node_level = jnp.minimum(raw_level, entry_level)

    state = dataclasses.replace(
        state,
        hnsw_levels=state.hnsw_levels.at[new_slot].set(node_level),
        hnsw_entry=entry.astype(jnp.int32),
    )

    if fast:
        # Unrolled static-level variant for bulk ingest. Identical values,
        # cheaper control flow: every lax.cond carries one [capacity, degree]
        # level slice instead of the whole [levels, capacity, degree] array,
        # inactive levels skip their beam search at runtime, and the
        # reverse-edge loop is batched over the m connectable candidates.
        def build(neighbors: jax.Array) -> jax.Array:
            # phase 1: greedy descent, entry's top level → node_level+1
            cur = entry.astype(jnp.int32)
            for lvl in range(max_levels - 1, 0, -1):
                do = (jnp.int32(lvl) <= entry_level) & (jnp.int32(lvl) > node_level)
                cur = jax.lax.cond(
                    do,
                    lambda c, lvl=lvl: greedy_step_level(
                        state, q_raw, jnp.int32(lvl), c,
                        neighbors_full=neighbors, static_level=lvl),
                    lambda c: c, cur)

            # phase 2: beam search + connect at levels node_level..0
            for lvl in range(max_levels - 1, -1, -1):
                active = jnp.int32(lvl) <= node_level

                def do_level(args, lvl=lvl):
                    nbrs, c = args
                    d, s = search_layer(state, q_raw, c, jnp.int32(lvl),
                                        ef_construction, fast=True,
                                        neighbors_full=nbrs,
                                        static_level=lvl)
                    # exclude self; the beam is duplicate-free, so a plain
                    # sort pushes the blanked entry back to the tail
                    d = jnp.where(s == new_slot, INF, d)
                    s = jnp.where(s == new_slot, jnp.int32(2**31 - 1), s)
                    d, s = jax.lax.sort((d, s), num_keys=2)
                    nbrs = _add_edges_fast(
                        nbrs, lvl, state.vectors, new_slot.astype(jnp.int32),
                        d, s, m)
                    nxt = jnp.where(d[0] < INF, s[0], c).astype(jnp.int32)
                    return nbrs, nxt

                neighbors, cur = jax.lax.cond(
                    active, do_level, lambda a: a, (neighbors, cur))
            return neighbors

        neighbors = jax.lax.cond(
            jnp.logical_not(is_first), build, lambda n: n,
            state.hnsw_neighbors)
        return dataclasses.replace(state, hnsw_neighbors=neighbors)

    def not_first_insert(state: MemoryState) -> MemoryState:
        # phase 1: greedy descent from the entry's top level to node_level+1
        def descend(lvl_rev, cur):
            lvl = jnp.int32(max_levels - 1 - lvl_rev)
            do = (lvl <= entry_level) & (lvl > node_level)
            return jnp.where(
                do, greedy_step_level(state, q_raw, lvl, cur), cur
            ).astype(jnp.int32)

        cur = jax.lax.fori_loop(0, max_levels, descend, entry.astype(jnp.int32))

        # phase 2: beam search + connect at levels node_level..0
        neighbors = state.hnsw_neighbors

        def connect(lvl_rev, carry):
            neighbors, cur = carry
            lvl = jnp.int32(max_levels - 1 - lvl_rev)
            active = lvl <= node_level
            # search against a state view with current neighbor arrays
            st = dataclasses.replace(state, hnsw_neighbors=neighbors)
            d, s = search_layer(st, q_raw, cur, lvl, ef_construction)
            # exclude self from candidates
            d = jnp.where(s == new_slot, INF, d)
            s = jnp.where(s == new_slot, jnp.int32(2**31 - 1), s)
            d, s = _sort_dedup(d, s)
            lvl_nbrs = jax.lax.dynamic_index_in_dim(neighbors, lvl, 0, keepdims=False)
            lvl_nbrs = _add_bidirectional_edges(
                lvl_nbrs, state.vectors, state.valid, new_slot.astype(jnp.int32),
                d, s, m, active
            )
            neighbors = jax.lax.dynamic_update_index_in_dim(neighbors, lvl_nbrs, lvl, 0)
            # next level starts from the best found here (when this level ran)
            nxt = jnp.where(active & (d[0] < INF), s[0], cur).astype(jnp.int32)
            return neighbors, nxt

        neighbors, _ = jax.lax.fori_loop(0, max_levels, connect, (neighbors, cur))
        return dataclasses.replace(state, hnsw_neighbors=neighbors)

    return jax.lax.cond(jnp.logical_not(is_first), not_first_insert, lambda s: s, state)


# --------------------------------------------------------------------------- #
# query
# --------------------------------------------------------------------------- #


def hnsw_search(state: MemoryState, q_raw: jax.Array, k: int, *, ef: int = 64
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ANN search: returns (ids[k] int64, dists[k] wide int64, slots[k]).

    Missing results are (-1, INF, -1). Deterministic for a fixed state.
    """
    max_levels = state.hnsw_max_levels
    entry = state.hnsw_entry
    have_graph = entry >= 0
    entry_safe = jnp.clip(entry, 0, state.capacity - 1)
    entry_level = jnp.where(have_graph, state.hnsw_levels[entry_safe], 0)

    def descend(lvl_rev, cur):
        lvl = jnp.int32(max_levels - 1 - lvl_rev)
        do = (lvl <= entry_level) & (lvl > 0) & have_graph
        return jnp.where(do, greedy_step_level(state, q_raw, lvl, cur), cur).astype(jnp.int32)

    cur = jax.lax.fori_loop(0, max_levels, descend, entry_safe.astype(jnp.int32))
    d, s = search_layer(state, q_raw, cur, jnp.int32(0), ef)
    d, s = d[:k], s[:k]
    ok = (d < INF) & have_graph
    slots = jnp.where(ok, s, jnp.int32(-1))
    ids = jnp.where(ok, state.ids[jnp.clip(s, 0, state.capacity - 1)], jnp.int64(-1))
    dists = jnp.where(ok, d, INF)
    return ids, dists, slots
