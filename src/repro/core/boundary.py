"""The determinism boundary (paper §5, §5.3).

Valori "does not attempt to make neural inference deterministic; instead, it
defines a strict boundary at which non-deterministic model outputs are
normalized into a deterministic memory state." This module is that boundary:
every float tensor entering the memory substrate passes through
``normalize_embedding`` exactly once, after which all state is integer.

Pipeline (all deterministic given the *quantized* inputs):
  float vector → [optional f32 pre-round] → Q-encode (saturating, round-half-
  away-from-zero) → optional exact integer L2 normalization.

The pre-round step optionally truncates float mantissas before quantization.
Divergent platforms produce floats differing in the last few ulps (paper
Table 1 shows ≤ ~2^-18 relative divergence); rounding to a grid coarser than
the cross-platform divergence collapses both platforms' values onto the same
fixed-point integer, which is why the boundary absorbs upstream float noise
rather than merely hiding it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fp
from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract


def normalize_embedding(
    x: jax.Array,
    contract: PrecisionContract = DEFAULT_CONTRACT,
    unit_norm: bool = True,
) -> jax.Array:
    """Float embedding(s) → deterministic fixed-point raw vectors.

    Args:
      x: float array [..., dim]; typically model hidden states in [-1, 1]ish.
      contract: the precision contract in force for this memory.
      unit_norm: L2-normalize *after* quantization using exact integer math,
        so normalization cannot re-introduce float nondeterminism.
    """
    raw = fp.encode(x, contract)
    if unit_norm:
        raw = fp.qnorm(raw, axis=-1, contract=contract)
    return raw


def admit_query(q: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT,
                unit_norm: bool = True) -> jax.Array:
    """Queries cross the same boundary as stored vectors (symmetry matters:
    the paper's replay guarantee covers the query path too)."""
    return normalize_embedding(q, contract, unit_norm)
