"""Valori deterministic memory substrate — the paper's primary contribution.

Public surface:
  contracts   — Q-format precision contracts (paper §6)
  fixedpoint  — exact integer arithmetic (paper §5.1)
  boundary    — the float→fixed determinism boundary (paper §5.3)
  state       — MemoryState arena pytree (paper §5.2)
  commands    — integer-encoded replayable command log (paper §3.1)
  machine     — the pure transition function F + replay (paper §3.1) and
                the hash-identical vectorized bulk_apply (DESIGN.md §3)
  hashing     — platform-invariant tree hashes (paper §8.1)
  snapshot    — serialize/restore with hash verification (paper §8.1):
                v1 blobs + v2 chunked content-addressed store (DESIGN.md §5)
  wal         — segmented, hash-chained write-ahead command log with
                replay-equivalent compaction (DESIGN.md §5)
  durability  — DurableStore: snapshots + WAL + restore_at time travel,
                crash recovery, retention (DESIGN.md §5)
  search      — exact deterministic k-NN (wide integer scores)
  hnsw        — deterministic HNSW (paper §7), TPU-adapted
  query       — batched deterministic query engine: vmapped HNSW, planner,
                shard fan-out (DESIGN.md §4)
  distributed — pod-scale sharded memory over shard_map (DESIGN.md §2)
  compat      — version-bridging shims over moved JAX APIs
"""
from repro.core import (boundary, commands, contracts, distributed, durability,
                        fixedpoint, hashing, hnsw, machine, query, search,
                        snapshot, state, wal)
from repro.core.contracts import (CONTRACTS, DEFAULT_CONTRACT, Q8_8, Q16_16,
                                  Q32_32, PrecisionContract, get_contract)
from repro.core.state import MemoryState, init_state

__all__ = [
    "boundary", "commands", "contracts", "distributed", "durability",
    "fixedpoint", "hashing", "hnsw", "machine", "query", "search", "snapshot",
    "state", "wal",
    "CONTRACTS", "DEFAULT_CONTRACT", "Q8_8", "Q16_16", "Q32_32",
    "PrecisionContract", "get_contract", "MemoryState", "init_state",
]
