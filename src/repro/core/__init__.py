"""Valori deterministic memory substrate — the paper's primary contribution.

Public surface:
  contracts   — Q-format precision contracts (paper §6)
  fixedpoint  — exact integer arithmetic (paper §5.1)
  boundary    — the float→fixed determinism boundary (paper §5.3)
  state       — MemoryState arena pytree (paper §5.2)
  commands    — integer-encoded replayable command log (paper §3.1)
  machine     — the pure transition function F + replay (paper §3.1) and
                the hash-identical vectorized bulk_apply (DESIGN.md §3)
  hashing     — platform-invariant tree hashes (paper §8.1)
  snapshot    — serialize/restore with hash verification (paper §8.1):
                v1 blobs + v2 chunked content-addressed store (DESIGN.md §5)
  wal         — segmented, hash-chained write-ahead command log with
                replay-equivalent compaction (DESIGN.md §5), group commit
                and scheduled compaction policies (DESIGN.md §6)
  durability  — DurableStore: snapshots + WAL + restore_at time travel,
                crash recovery, retention (DESIGN.md §5)
  shard_wal   — ShardedDurableStore: per-shard WALs reconciled to one
                global cursor, durable distributed ingest (DESIGN.md §6)
  search      — exact deterministic k-NN (wide integer scores) and the
                compressed coarse tier's scan + exact re-rank
  codes       — deterministic int8 code table over Q16.16 rows: pure
                function of the live rows, incrementally maintained,
                chunk-snapshot-able (DESIGN.md §10)
  hnsw        — deterministic HNSW (paper §7), TPU-adapted
  query       — batched deterministic query engine: vmapped HNSW, planner,
                shard fan-out (DESIGN.md §4)
  distributed — pod-scale sharded memory over shard_map (DESIGN.md §2)
  compat      — version-bridging shims over moved JAX APIs

Most-used entry points (each docstring states the contract it promises):
  replay / bulk_apply      — Apply(S_0, {C_i}); bulk form is hash-identical
  DurableStore, restore_at — durable history; restore_at(t) ≡ replay(log[:t])
  GroupCommitPolicy, GroupCommitWriter — one fsync per group of commands
  CompactionPolicy         — dead-ratio-scheduled WAL compaction
  ShardedDurableStore      — per-shard WALs, one reconciled global cursor
  plan_query               — deterministic exact-vs-HNSW route from host ints
"""
from repro.core import (boundary, codes, commands, contracts, distributed,
                        durability, fixedpoint, hashing, hnsw, machine, query,
                        search, shard_wal, snapshot, state, wal)
from repro.core.contracts import (CONTRACTS, DEFAULT_CONTRACT, Q8_8, Q16_16,
                                  Q32_32, PrecisionContract, get_contract)
from repro.core.durability import DurableStore, SideTable, restore_at
from repro.core.hashing import content_hash
from repro.core.machine import apply_command, bulk_apply, replay
from repro.core.query import plan_query, retrieval_hash, sharded_host_query
from repro.core.shard_wal import ShardedDurableStore
from repro.core.state import MemoryState, init_state
from repro.core.wal import (CompactionPolicy, GroupCommitPolicy,
                            GroupCommitWriter, WriteAheadLog)

__all__ = [
    "boundary", "codes", "commands", "contracts", "distributed",
    "durability", "fixedpoint", "hashing", "hnsw", "machine", "query",
    "search", "shard_wal", "snapshot", "state", "wal",
    "CONTRACTS", "DEFAULT_CONTRACT", "Q8_8", "Q16_16", "Q32_32",
    "PrecisionContract", "get_contract", "MemoryState", "init_state",
    "apply_command", "bulk_apply", "replay", "content_hash",
    "DurableStore", "SideTable", "restore_at", "plan_query",
    "retrieval_hash", "sharded_host_query",
    "ShardedDurableStore", "WriteAheadLog",
    "CompactionPolicy", "GroupCommitPolicy", "GroupCommitWriter",
]
