"""Snapshot / restore with hash verification (paper §5.2, §8.1).

A snapshot is the canonical little-endian serialization of every MemoryState
leaf plus a manifest holding the FNV tree hash. Restoring on any machine and
re-hashing must reproduce the manifest hash exactly — the paper's
"Snapshot Transfer" experiment (H_A ≡ H_B) as an executable artifact.

Two on-disk formats coexist (DESIGN.md §5):

v1 — one opaque blob (all little-endian):
  magic 'VLRI' | version u32 | contract name (len u32 + utf8)
  | leaf count u32 | per leaf: path (len+utf8), dtype str (len+utf8),
    ndim u32, dims u64..., payload bytes
  | trailer: fnv hash u64 (hash_pytree of the state)

v2 — chunked + content-addressed: each leaf's canonical bytes are split
into fixed-size chunks keyed by their FNV-1a hash and stored once in a
``ChunkStore``; the snapshot itself is only a small *manifest*:
  magic 'VLR2' | version u32 | contract name | t u64 (applied-command
  cursor, == state.version) | chunk_size u32 | leaf count u32
  | per leaf: path, dtype, ndim u32, dims u64..., nbytes u64,
    n_chunks u32, chunk keys u64...
  | trailer: fnv tree hash u64

Because chunks are keyed by content, a second snapshot after N mutations
re-uses every clean chunk and writes only the dirty ones — incremental
snapshots fall out of content addressing, no dirty-tracking needed. The v1
reader is kept verbatim for old blobs; ``restore_any`` dispatches on the
magic.
"""
from __future__ import annotations

import io
import os
import pathlib
import struct
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.contracts import get_contract
from repro.core.state import MemoryState

MAGIC = b"VLRI"
MAGIC_V2 = b"VLR2"
FORMAT_VERSION = 1
FORMAT_VERSION_V2 = 2
DEFAULT_CHUNK_SIZE = 8192

_U64 = (1 << 64) - 1


def _write_str(buf: io.BytesIO, s: str) -> None:
    b = s.encode()
    buf.write(struct.pack("<I", len(b)))
    buf.write(b)


def _read_str(buf: io.BytesIO) -> str:
    (n,) = struct.unpack("<I", buf.read(4))
    return buf.read(n).decode()


def _canonical_leaf_bytes(leaf) -> Tuple[np.ndarray, bytes]:
    arr = np.asarray(leaf)
    return arr, arr.astype(arr.dtype.newbyteorder("<"), copy=False).tobytes()


# --------------------------------------------------------------------------- #
# v1: single opaque blob (kept byte-stable — golden fixture enforced)
# --------------------------------------------------------------------------- #


def snapshot_bytes(state: MemoryState) -> bytes:
    """Serialize a state. The embedded hash covers the *state tree*, so any
    bit flip in any leaf is detected at restore time."""
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", FORMAT_VERSION))
    _write_str(buf, state.contract_name)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    buf.write(struct.pack("<I", len(leaves)))
    for path, leaf in leaves:
        arr, payload = _canonical_leaf_bytes(leaf)
        _write_str(buf, jax.tree_util.keystr(path))
        _write_str(buf, str(arr.dtype))
        buf.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            buf.write(struct.pack("<Q", d))
        buf.write(payload)

    h = hashing.hash_pytree(state)
    buf.write(struct.pack("<Q", h))
    return buf.getvalue()


def _state_from_leaves(leaves: Dict[str, np.ndarray],
                       contract_name: str) -> MemoryState:
    def leaf_for(field: str):
        return jnp.asarray(leaves[f".{field}"])

    return MemoryState(
        vectors=leaf_for("vectors"),
        ids=leaf_for("ids"),
        valid=leaf_for("valid"),
        links=leaf_for("links"),
        meta=leaf_for("meta"),
        hnsw_neighbors=leaf_for("hnsw_neighbors"),
        hnsw_levels=leaf_for("hnsw_levels"),
        hnsw_entry=leaf_for("hnsw_entry"),
        cursor=leaf_for("cursor"),
        count=leaf_for("count"),
        version=leaf_for("version"),
        contract_name=contract_name,
    )


def restore_bytes(data: bytes) -> Tuple[MemoryState, int]:
    """Restore a v1 state; verifies the manifest hash. Returns (state, hash)."""
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC:
        raise ValueError("not a Valori snapshot")
    (ver,) = struct.unpack("<I", buf.read(4))
    if ver != FORMAT_VERSION:
        raise ValueError(f"unsupported snapshot version {ver}")
    contract_name = _read_str(buf)
    get_contract(contract_name)  # validates

    (n_leaves,) = struct.unpack("<I", buf.read(4))
    leaves = {}
    for _ in range(n_leaves):
        path = _read_str(buf)
        dtype = np.dtype(_read_str(buf))
        (ndim,) = struct.unpack("<I", buf.read(4))
        shape = tuple(struct.unpack("<Q", buf.read(8))[0] for _ in range(ndim))
        count = int(np.prod(shape)) if shape else 1
        payload = buf.read(count * dtype.itemsize)
        arr = np.frombuffer(payload, dtype=dtype.newbyteorder("<")).astype(dtype)
        leaves[path] = arr.reshape(shape)

    (stored_hash,) = struct.unpack("<Q", buf.read(8))
    state = _state_from_leaves(leaves, contract_name)
    actual = hashing.hash_pytree(state)
    if actual != stored_hash:
        raise ValueError(
            f"snapshot hash mismatch: stored {stored_hash:#x}, got {actual:#x}"
        )
    return state, actual


def save(path: str, state: MemoryState) -> int:
    data = snapshot_bytes(state)
    with open(path, "wb") as f:
        f.write(data)
    return hashing.hash_pytree(state)


def load(path: str) -> Tuple[MemoryState, int]:
    with open(path, "rb") as f:
        return restore_bytes(f.read())


# --------------------------------------------------------------------------- #
# v2: content-addressed chunk store + manifest
# --------------------------------------------------------------------------- #


def chunk_key(data: bytes) -> int:
    """Content key of a chunk: the vectorized word digest (length-salted,
    so a chunk and its zero-padded extension stay distinct)."""
    return hashing.digest_bytes(data)


class ChunkStore:
    """Content-addressed blob store: one file per chunk, named by key.

    ``put`` is idempotent — re-putting bytes already present writes nothing,
    which is what makes repeated snapshots incremental. ``get`` re-hashes
    and refuses a corrupt chunk, so every restored byte is verified twice
    (per chunk here, whole-tree in the manifest hash).
    """

    def __init__(self, directory: str | os.PathLike):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        # write-side stats, reset per snapshot by the callers that care
        self.puts = 0
        self.writes = 0
        self.bytes_written = 0

    def _path(self, key: int) -> pathlib.Path:
        return self.dir / f"{key:016x}.chk"

    def put(self, data: bytes) -> Tuple[int, bool]:
        key = chunk_key(data)
        self.puts += 1
        path = self._path(key)
        if path.exists():
            return key, False
        tmp = path.with_suffix(".tmp")
        with open(tmp, "wb") as f:  # fsync before publish: a manifest must
            f.write(data)           # never reference a chunk that could be
            f.flush()               # torn by the crash the manifest survives
            os.fsync(f.fileno())
        tmp.rename(path)
        self.writes += 1
        self.bytes_written += len(data)
        return key, True

    def get(self, key: int) -> bytes:
        data = self._path(key).read_bytes()
        if chunk_key(data) != key:
            raise ValueError(f"chunk {key:016x} corrupt (content hash mismatch)")
        return data

    def __contains__(self, key: int) -> bool:
        return self._path(key).exists()

    def keys(self) -> List[int]:
        return sorted(int(p.stem, 16) for p in self.dir.glob("*.chk"))

    def delete(self, key: int) -> None:
        self._path(key).unlink(missing_ok=True)

    def reset_stats(self) -> None:
        self.puts = self.writes = self.bytes_written = 0


def snapshot_v2(state: MemoryState, store: ChunkStore, *,
                chunk_size: int = DEFAULT_CHUNK_SIZE
                ) -> Tuple[bytes, Dict[str, int]]:
    """Write the state's chunks into ``store`` and return (manifest bytes,
    stats). Chunks already present are not rewritten — a snapshot taken
    after N mutations costs only the dirty chunks."""
    store.reset_stats()
    buf = io.BytesIO()
    buf.write(MAGIC_V2)
    buf.write(struct.pack("<I", FORMAT_VERSION_V2))
    _write_str(buf, state.contract_name)
    buf.write(struct.pack("<Q", int(state.version) & _U64))
    buf.write(struct.pack("<I", chunk_size))

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    buf.write(struct.pack("<I", len(leaves)))
    total = 0
    for path, leaf in leaves:
        arr, payload = _canonical_leaf_bytes(leaf)
        total += len(payload)
        _write_str(buf, jax.tree_util.keystr(path))
        _write_str(buf, str(arr.dtype))
        buf.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            buf.write(struct.pack("<Q", d))
        keys = []
        for off in range(0, max(len(payload), 1), chunk_size):
            key, _ = store.put(payload[off:off + chunk_size])
            keys.append(key)
        buf.write(struct.pack("<Q", len(payload)))
        buf.write(struct.pack("<I", len(keys)))
        for key in keys:
            buf.write(struct.pack("<Q", key))

    h = hashing.hash_pytree(state)
    buf.write(struct.pack("<Q", h))
    stats = {"chunks": store.puts, "chunks_written": store.writes,
             "bytes_written": store.bytes_written, "bytes_total": total,
             "manifest_bytes": buf.tell()}
    return buf.getvalue(), stats


def restore_v2(data: bytes, store: ChunkStore) -> Tuple[MemoryState, int]:
    """Restore a v2 manifest against its chunk store; verifies every chunk's
    content hash and the whole-tree hash. Returns (state, hash)."""
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC_V2:
        raise ValueError("not a v2 Valori snapshot manifest")
    (ver,) = struct.unpack("<I", buf.read(4))
    if ver != FORMAT_VERSION_V2:
        raise ValueError(f"unsupported snapshot version {ver}")
    contract_name = _read_str(buf)
    get_contract(contract_name)
    (t,) = struct.unpack("<Q", buf.read(8))
    (chunk_size,) = struct.unpack("<I", buf.read(4))
    del chunk_size  # recorded for tooling; chunk lengths are self-describing

    (n_leaves,) = struct.unpack("<I", buf.read(4))
    leaves = {}
    for _ in range(n_leaves):
        path = _read_str(buf)
        dtype = np.dtype(_read_str(buf))
        (ndim,) = struct.unpack("<I", buf.read(4))
        shape = tuple(struct.unpack("<Q", buf.read(8))[0] for _ in range(ndim))
        (nbytes,) = struct.unpack("<Q", buf.read(8))
        (n_chunks,) = struct.unpack("<I", buf.read(4))
        parts = []
        for _ in range(n_chunks):
            (key,) = struct.unpack("<Q", buf.read(8))
            parts.append(store.get(key))
        payload = b"".join(parts)
        if len(payload) != nbytes:
            raise ValueError(
                f"leaf {path}: reassembled {len(payload)} bytes, "
                f"manifest says {nbytes}")
        arr = np.frombuffer(payload, dtype=dtype.newbyteorder("<")).astype(dtype)
        leaves[path] = arr.reshape(shape)

    (stored_hash,) = struct.unpack("<Q", buf.read(8))
    state = _state_from_leaves(leaves, contract_name)
    actual = hashing.hash_pytree(state)
    if actual != stored_hash:
        raise ValueError(
            f"snapshot hash mismatch: stored {stored_hash:#x}, got {actual:#x}"
        )
    if (int(state.version) & _U64) != t:
        raise ValueError(
            f"manifest cursor t={t} disagrees with state.version="
            f"{int(state.version)}")
    return state, actual


def manifest_cursor(data: bytes) -> int:
    """Applied-command cursor ``t`` of a v2 manifest, without touching the
    chunk store — a format-inspection helper for tooling/audit scripts
    (DurableStore itself keys snapshots by cursor-named files)."""
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC_V2:
        raise ValueError("not a v2 Valori snapshot manifest")
    buf.read(4)
    _read_str(buf)
    (t,) = struct.unpack("<Q", buf.read(8))
    return t


def manifest_chunk_keys(data: bytes) -> List[int]:
    """All chunk keys a v2 manifest references (for retention sweeps)."""
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC_V2:
        raise ValueError("not a v2 Valori snapshot manifest")
    buf.read(4)
    _read_str(buf)
    buf.read(12)
    (n_leaves,) = struct.unpack("<I", buf.read(4))
    keys = []
    for _ in range(n_leaves):
        _read_str(buf)
        _read_str(buf)
        (ndim,) = struct.unpack("<I", buf.read(4))
        buf.read(8 * ndim + 8)
        (n_chunks,) = struct.unpack("<I", buf.read(4))
        for _ in range(n_chunks):
            (key,) = struct.unpack("<Q", buf.read(8))
            keys.append(key)
    return keys


def restore_any(data: bytes, store: Optional[ChunkStore] = None
                ) -> Tuple[MemoryState, int]:
    """Restore either snapshot format; v2 needs its chunk store."""
    if data[:4] == MAGIC:
        return restore_bytes(data)
    if data[:4] == MAGIC_V2:
        if store is None:
            raise ValueError("v2 snapshot needs its ChunkStore")
        return restore_v2(data, store)
    raise ValueError("not a Valori snapshot")
