"""Snapshot / restore with hash verification (paper §5.2, §8.1).

A snapshot is the canonical little-endian serialization of every MemoryState
leaf plus a manifest holding the FNV tree hash. Restoring on any machine and
re-hashing must reproduce the manifest hash exactly — the paper's
"Snapshot Transfer" experiment (H_A ≡ H_B) as an executable artifact.

Format (all little-endian):
  magic 'VLRI' | version u32 | contract name (len u32 + utf8)
  | leaf count u32 | per leaf: path (len+utf8), dtype str (len+utf8),
    ndim u32, dims u64..., payload bytes
  | trailer: fnv hash u64 (hash_pytree of the state)
"""
from __future__ import annotations

import io
import struct
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.contracts import get_contract
from repro.core.state import MemoryState

MAGIC = b"VLRI"
FORMAT_VERSION = 1


def _write_str(buf: io.BytesIO, s: str) -> None:
    b = s.encode()
    buf.write(struct.pack("<I", len(b)))
    buf.write(b)


def _read_str(buf: io.BytesIO) -> str:
    (n,) = struct.unpack("<I", buf.read(4))
    return buf.read(n).decode()


def snapshot_bytes(state: MemoryState) -> bytes:
    """Serialize a state. The embedded hash covers the *state tree*, so any
    bit flip in any leaf is detected at restore time."""
    buf = io.BytesIO()
    buf.write(MAGIC)
    buf.write(struct.pack("<I", FORMAT_VERSION))
    _write_str(buf, state.contract_name)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    buf.write(struct.pack("<I", len(leaves)))
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        _write_str(buf, jax.tree_util.keystr(path))
        _write_str(buf, str(arr.dtype))
        buf.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            buf.write(struct.pack("<Q", d))
        canonical = arr.astype(arr.dtype.newbyteorder("<"), copy=False)
        buf.write(canonical.tobytes())

    h = hashing.hash_pytree(state)
    buf.write(struct.pack("<Q", h))
    return buf.getvalue()


def restore_bytes(data: bytes) -> Tuple[MemoryState, int]:
    """Restore a state; verifies the manifest hash. Returns (state, hash)."""
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC:
        raise ValueError("not a Valori snapshot")
    (ver,) = struct.unpack("<I", buf.read(4))
    if ver != FORMAT_VERSION:
        raise ValueError(f"unsupported snapshot version {ver}")
    contract_name = _read_str(buf)
    get_contract(contract_name)  # validates

    (n_leaves,) = struct.unpack("<I", buf.read(4))
    leaves = {}
    for _ in range(n_leaves):
        path = _read_str(buf)
        dtype = np.dtype(_read_str(buf))
        (ndim,) = struct.unpack("<I", buf.read(4))
        shape = tuple(struct.unpack("<Q", buf.read(8))[0] for _ in range(ndim))
        count = int(np.prod(shape)) if shape else 1
        payload = buf.read(count * dtype.itemsize)
        arr = np.frombuffer(payload, dtype=dtype.newbyteorder("<")).astype(dtype)
        leaves[path] = arr.reshape(shape)

    (stored_hash,) = struct.unpack("<Q", buf.read(8))

    def leaf_for(field: str):
        return jnp.asarray(leaves[f".{field}"])

    state = MemoryState(
        vectors=leaf_for("vectors"),
        ids=leaf_for("ids"),
        valid=leaf_for("valid"),
        links=leaf_for("links"),
        meta=leaf_for("meta"),
        hnsw_neighbors=leaf_for("hnsw_neighbors"),
        hnsw_levels=leaf_for("hnsw_levels"),
        hnsw_entry=leaf_for("hnsw_entry"),
        cursor=leaf_for("cursor"),
        count=leaf_for("count"),
        version=leaf_for("version"),
        contract_name=contract_name,
    )
    actual = hashing.hash_pytree(state)
    if actual != stored_hash:
        raise ValueError(
            f"snapshot hash mismatch: stored {stored_hash:#x}, got {actual:#x}"
        )
    return state, actual


def save(path: str, state: MemoryState) -> int:
    data = snapshot_bytes(state)
    with open(path, "wb") as f:
        f.write(data)
    return hashing.hash_pytree(state)


def load(path: str) -> Tuple[MemoryState, int]:
    with open(path, "rb") as f:
        return restore_bytes(f.read())
