"""Exact deterministic k-NN over the fixed-point arena.

The throughput-oriented counterpart of hnsw.py (DESIGN.md §2): scoring is a
blocked integer matmul (delegated to the Pallas qgemm kernel when enabled,
pure jnp otherwise) and selection is a (score, id) lexicographic top-k, so
results — including tie order — are bit-identical everywhere.

Scores are *wide* (unshifted Q(2f)) integers: exact, monotone in the true
metric, never rounded before ranking.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.state import MemoryState

INF = jnp.int64(1) << 62

METRIC_L2 = "l2"
METRIC_DOT = "dot"


def score_block(queries_raw: jax.Array, db_raw: jax.Array, metric: str = METRIC_L2,
                use_kernel: bool = False) -> jax.Array:
    """Wide integer scores [nq, nd]; lower = better for both metrics
    (dot scores are negated so selection logic is uniform)."""
    if use_kernel:
        from repro.kernels.qgemm import ops as qgemm_ops
        wide_dot = qgemm_ops.qgemm(queries_raw, db_raw)
    else:
        wide_dot = jnp.einsum(
            "qd,nd->qn",
            queries_raw.astype(jnp.int64),
            db_raw.astype(jnp.int64),
        )
    if metric == METRIC_DOT:
        return -wide_dot
    if metric == METRIC_L2:
        qq = jnp.sum(queries_raw.astype(jnp.int64) ** 2, axis=-1)  # [nq]
        nn = jnp.sum(db_raw.astype(jnp.int64) ** 2, axis=-1)  # [nd]
        return qq[:, None] - 2 * wide_dot + nn[None, :]
    raise ValueError(f"unknown metric {metric!r}")


def topk_by_score(scores: jax.Array, ids: jax.Array, k: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """Deterministic top-k smallest scores with (score, id) tie-break.

    scores [nq, n] int64, ids [n] int64 → (scores [nq,k], ids [nq,k]).
    """
    nq, n = scores.shape
    ids_b = jnp.broadcast_to(ids[None, :], (nq, n))
    s_sorted, i_sorted = jax.lax.sort((scores, ids_b), num_keys=2, dimension=1)
    return s_sorted[:, :k], i_sorted[:, :k]


def _topk_by_score_kernel(scores: jax.Array, ids: jax.Array, k: int
                          ) -> Tuple[jax.Array, jax.Array]:
    """qtopk-backed top-k, bit-identical to :func:`topk_by_score`.

    The kernel tie-breaks on int32 keys, but ids are int64. Rank each id
    among the sorted id column instead: id → rank is strictly monotone for
    the unique real ids, so (score, rank) order equals (score, id) order;
    masked rows all share id 2^62 and score INF, and every INF result is
    normalized to (-1, INF) downstream, so their internal tie order is
    unobservable.
    """
    from repro.kernels.qtopk import ops as qtopk_ops
    n = ids.shape[0]
    order = jnp.argsort(ids)  # stable integer sort
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    sorted_ids = ids[order]
    s, r = qtopk_ops.qtopk(scores, ranks, k)
    return s, sorted_ids[jnp.clip(r, 0, n - 1)]


@partial(jax.jit, static_argnames=("k", "metric", "use_kernel"))
def exact_search(state: MemoryState, queries_raw: jax.Array, k: int,
                 *, metric: str = METRIC_L2, use_kernel: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """k-NN over all live rows. Returns (ids [nq,k] int64, scores [nq,k]).

    Missing results (fewer than k live rows) are (-1, INF).
    ``use_kernel=True`` scores through Pallas qgemm and selects through
    Pallas qtopk — bit-identical to the pure-jnp path
    (tests/test_query_engine.py::test_kernel_parity).
    """
    scores = score_block(queries_raw, state.vectors, metric, use_kernel)
    scores = jnp.where(state.valid[None, :], scores, INF)
    # tombstoned ids are -1; give them +inf-ish id so they sort last among ties
    ids = jnp.where(state.valid, state.ids, jnp.int64(1) << 62)
    if use_kernel:
        s, i = _topk_by_score_kernel(scores, ids, k)
    else:
        s, i = topk_by_score(scores, ids, k)
    found = s < INF
    return jnp.where(found, i, jnp.int64(-1)), jnp.where(found, s, INF)


def merge_candidates(scores: jax.Array, ids: jax.Array, k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Top-k of a [..., m] candidate pool by (score, id) — the one combine
    every fan-in path shares (pairwise merge, shard all-gather). A pure
    integer two-key sort, so the result is invariant to any permutation of
    the pool — the order-invariance the distributed paths lean on."""
    # re-mask tombstones so (-1) padding never wins ties
    i_key = jnp.where(scores < INF, ids, jnp.int64(1) << 62)
    s_sorted, i_sorted = jax.lax.sort(
        (scores, i_key), num_keys=2, dimension=scores.ndim - 1)
    s_out = s_sorted[..., :k]
    i_out = i_sorted[..., :k]
    return s_out, jnp.where(s_out < INF, i_out, jnp.int64(-1))


@partial(jax.jit, static_argnames=("k", "ef_coarse", "metric", "use_kernel"))
def coarse_search(state: MemoryState, table, queries_raw: jax.Array, k: int,
                  *, ef_coarse: int, metric: str = METRIC_L2,
                  use_kernel: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Compressed-tier k-NN: int8 coarse scan, exact Q16.16 re-rank.

    Two stages (DESIGN.md §10):

    1. *Coarse scan*: approximate integer scores over the int8 code table
       (``kernels/qcoarse`` when ``use_kernel``, its jnp oracle otherwise —
       bit-identical either way), candidates = the ``ef_coarse`` best by
       (approx score, slot).
    2. *Re-rank*: the survivors re-scored with the exact wide Q16.16
       ``score_block`` arithmetic and combined by ``merge_candidates`` —
       the same (score, id) tie-break every other read path uses.

    The served scores are therefore exact: quantization error can only
    cost *recall* (a true neighbor missing from the candidate set), never
    score fidelity. Coverage implies bit-exactness: whenever the candidate
    set contains every live row — by construction when
    ``ef_coarse >= live_count`` — the result equals ``exact_search``'s
    bit-for-bit, which is the conformance suite's coarse-route contract.

    Returns (ids [nq, k] int64, scores [nq, k] int64); missing results
    are (-1, INF), exactly like ``exact_search``.
    """
    from repro.core import codes as codes_lib    # lazy: codes is leaf-level
    from repro.kernels.qcoarse import ops as qcoarse_ops

    n = state.vectors.shape[0]
    ef = min(ef_coarse, n)
    if ef < k:
        raise ValueError(
            f"coarse route needs ef_coarse >= k (got ef_coarse={ef_coarse}, "
            f"k={k}, capacity={n}): a candidate set of {ef} cannot "
            f"yield {k} results")

    w = codes_lib.query_weights(queries_raw, table, metric)
    s = qcoarse_ops.qcoarse(w, table.codes, use_pallas=use_kernel)
    if metric == METRIC_L2:
        approx = table.norms[None, :] - 2 * s
    else:
        approx = -s
    approx = jnp.where(state.valid[None, :], approx, INF)

    # candidate selection by (approx score, slot): slots are unique, so the
    # set is deterministic; the *served* tie order is fixed later by the
    # exact (score, id) merge, the same combine every fan-in path shares
    slots = jnp.arange(n, dtype=jnp.int64)
    if use_kernel:
        s_c, slot_c = _topk_by_score_kernel(approx, slots, ef)
    else:
        s_c, slot_c = topk_by_score(approx, slots, ef)
    slot_i = slot_c.astype(jnp.int32)                       # [nq, ef]

    # exact re-rank: the same wide integer arithmetic as score_block over
    # the full arena, gathered per query (integer sums are order-invariant,
    # so the values are bit-identical to the full scan's)
    rows = state.vectors[slot_i]                            # [nq, ef, d]
    exact = jax.vmap(
        lambda q, db: score_block(q[None, :], db, metric)[0]
    )(queries_raw, rows)                                    # [nq, ef]
    live = state.valid[slot_i] & (s_c < INF)
    exact = jnp.where(live, exact, INF)
    cand_ids = jnp.where(live, state.ids[slot_i], jnp.int64(1) << 62)
    s_out, i_out = merge_candidates(exact, cand_ids, k)
    return i_out, s_out


def merge_topk(scores_a: jax.Array, ids_a: jax.Array,
               scores_b: jax.Array, ids_b: jax.Array, k: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Merge two sorted top-k lists into one — the deterministic combine step
    used by the sharded memory. Associative, commutative, and permutation-
    invariant (tests/test_query_engine.py proves all three), which is what
    makes shard fan-in order a non-event."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    return merge_candidates(s, i, k)
