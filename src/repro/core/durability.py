"""Durable store: snapshots + WAL + time travel (DESIGN.md §5).

Ties the two durability primitives together into an operational recovery
and audit path:

  * every applied command is appended to a segmented, hash-chained
    ``WriteAheadLog`` (wal.py);
  * checkpoints are v2 content-addressed snapshots (snapshot.py) whose
    manifest carries the applied-command cursor ``t`` (== state.version,
    the monotone logical clock every command advances);
  * ``restore_at(store, t)`` materializes the state *as of command t*:
    nearest snapshot ≤ t, then ``machine.bulk_apply`` of the WAL tail —
    bit-identical (hash-equal) to ``machine.replay(genesis, log[:t])`` at
    every offset, because bulk_apply is replay-equivalent by contract and
    snapshot restore is hash-verified;
  * ``recover()`` is crash recovery: the WAL open truncates any torn tail
    to the longest valid record prefix, and the state is rebuilt at
    ``max(newest snapshot t, durable WAL prefix)``;
  * ``retain(keep)`` ages out (snapshot, WAL-segment) pairs together: old
    manifests are deleted, WAL segments wholly below the oldest retained
    snapshot are dropped, and chunks no surviving manifest references are
    swept. The time-travel window shrinks accordingly — never the ability
    to recover the present;
  * ``append_many`` is the group-commit sink (one fsync per group,
    DESIGN.md §6), a configured ``wal.CompactionPolicy`` schedules
    dead-ratio-driven compaction automatically on append, and
    ``rollback_to(t)`` drops durable-but-unacked suffixes — the primitive
    ``shard_wal.ShardedDurableStore`` reconciles shards with.

Layout of a store directory:
  store.json                    dim / contract / chunk_size / segment_records
  chunks/<key:016x>.chk         content-addressed chunk store (shared)
  snapshots/t_<t:020d>.vsn2     v2 manifests, named by cursor
  wal/seg_<base_t:020d>.wal     hash-chained command segments
"""
from __future__ import annotations

import json
import os
import pathlib
import struct
import threading
from typing import Dict, List, Optional, Tuple

# a torn manifest can fail in the struct layer (struct.error), on a garbage
# contract name (KeyError), or on a short/unicode-broken string read before
# any semantic hash check runs; all of it means "this snapshot is unusable,
# fall back to an older one"
_RESTORE_ERRORS = (ValueError, OSError, KeyError, struct.error)

from repro.core import hashing, machine, snapshot, wal
from repro.core.commands import CommandLog
from repro.core.contracts import get_contract
from repro.core.state import MemoryState


class DurableStore:
    """One directory holding a memory's full durable history.

    Invariant: at every retained offset ``t``, ``restore_at(t)`` is
    hash-identical to ``machine.replay(genesis, log[:t])``; after any
    crash, ``recover()`` rebuilds the latest durable point and refuses
    (never approximates) lost history."""

    def __init__(self, directory: str | os.PathLike,
                 genesis: Optional[MemoryState] = None, *,
                 chunk_size: int = snapshot.DEFAULT_CHUNK_SIZE,
                 segment_records: int = 1024,
                 compaction: Optional[wal.CompactionPolicy] = None,
                 chunks: Optional[snapshot.ChunkStore] = None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        meta_path = self.dir / "store.json"

        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            dim = meta["dim"]
            contract = get_contract(meta["contract"])
            chunk_size = meta["chunk_size"]
            segment_records = meta["segment_records"]
        else:
            if genesis is None:
                raise ValueError(
                    f"{self.dir} is not a DurableStore and no genesis state "
                    "was given to create one")
            dim = genesis.dim
            contract = genesis.contract
            meta = {"dim": dim, "contract": contract.name,
                    "chunk_size": chunk_size,
                    "segment_records": segment_records}
            tmp = meta_path.with_suffix(".tmp")
            with open(tmp, "w") as f:  # tmp+fsync+rename: a crash leaves a
                f.write(json.dumps(meta))  # stale .tmp, never a torn
                f.flush()                  # store.json that bricks reopen
                os.fsync(f.fileno())
            tmp.rename(meta_path)

        self.chunk_size = chunk_size
        # serializes WAL mutations (append / retain / compact) so a
        # background checkpoint+retention thread can never unlink or rewrite
        # a segment a foreground append is extending
        self._lock = threading.RLock()
        # a shared ChunkStore (sharded stores dedup chunks across shards)
        # is swept by its owner, never by this store's retain()
        self._owns_chunks = chunks is None
        self.chunks = chunks if chunks is not None \
            else snapshot.ChunkStore(self.dir / "chunks")
        self.compaction = compaction
        self._genesis_cache: Optional[MemoryState] = None
        self.wal = wal.WriteAheadLog(self.dir / "wal", dim, contract,
                                     segment_records=segment_records)
        self._snap_dir = self.dir / "snapshots"
        self._snap_dir.mkdir(exist_ok=True)

        if genesis is not None and not self.snapshots():
            if int(genesis.version) != 0:
                raise ValueError("genesis state must be at t=0 "
                                 f"(got version {int(genesis.version)})")
            self._write_snapshot(genesis)  # makes restore_at total over t

    # ------------------------------------------------------------------ #
    # snapshots
    # ------------------------------------------------------------------ #

    def _snap_path(self, t: int) -> pathlib.Path:
        return self._snap_dir / f"t_{t:020d}.vsn2"

    def snapshots(self) -> List[int]:
        """Cursors of all retained snapshots, ascending."""
        return sorted(int(p.stem.split("_")[1])
                      for p in self._snap_dir.glob("t_*.vsn2"))

    def _write_snapshot(self, state: MemoryState) -> Dict[str, int]:
        manifest, stats = snapshot.snapshot_v2(state, self.chunks,
                                               chunk_size=self.chunk_size)
        t = int(state.version)
        tmp = self._snap_path(t).with_suffix(".tmp")
        with open(tmp, "wb") as f:  # chunks are fsynced by put(); sync the
            f.write(manifest)       # manifest too before publishing it
            f.flush()
            os.fsync(f.fileno())
        tmp.rename(self._snap_path(t))
        return stats

    def checkpoint(self, state: MemoryState) -> Dict[str, int]:
        """Snapshot ``state`` at its cursor. The cursor must not run ahead
        of the durable log — a snapshot of commands the WAL never saw could
        not be audited back to genesis."""
        t = int(state.version)
        with self._lock:
            wal_t = self.wal.t
        if t > wal_t:
            raise ValueError(
                f"state cursor t={t} ahead of durable WAL t={wal_t}; "
                "append the commands before checkpointing")
        # the write itself runs outside the lock so appends keep flowing;
        # checkpoint and retain are serialized by their callers (one
        # background worker at a time — engine.wait_durable / manager.wait)
        stats = self._write_snapshot(state)
        stats["t"] = t
        return stats

    # ------------------------------------------------------------------ #
    # the command stream
    # ------------------------------------------------------------------ #

    def append(self, log: CommandLog) -> int:
        """Durably append commands (one fsync per touched segment); returns
        the new WAL cursor. Runs scheduled compaction when a
        ``CompactionPolicy`` was configured and is due."""
        with self._lock:
            t = self.wal.append(log)
            self._maybe_compact()
            return t

    def append_many(self, logs) -> int:
        """Group commit: durably append several logs under one fsync per
        touched segment (``wal.WriteAheadLog.append_many``); returns the new
        WAL cursor. This is the sink ``wal.GroupCommitWriter`` drives."""
        with self._lock:
            t = self.wal.append_many(logs)
            self._maybe_compact()
            return t

    def _maybe_compact(self) -> None:
        if self.compaction is None:
            return

        def genesis():
            # lazily restored (costs only when a check actually runs); an
            # unavailable t=0 snapshot legitimately skips the check, but
            # ONLY that — a failure inside compaction itself (corrupt
            # segment, disk full) must propagate, not vanish per append
            try:
                return self._genesis()
            except _RESTORE_ERRORS:
                return None

        self.wal.maybe_compact(genesis, self.compaction)

    def _genesis(self) -> MemoryState:
        """The t=0 state (cached; immutable once restored)."""
        if self._genesis_cache is None:
            state, _ = self.restore_at(0)
            self._genesis_cache = state
        return self._genesis_cache

    @property
    def t(self) -> int:
        """Durable logical time: commands safely on disk."""
        return self.wal.t

    # ------------------------------------------------------------------ #
    # time travel + recovery
    # ------------------------------------------------------------------ #

    def restore_at(self, t: int, *, ef_construction: int = 32
                   ) -> Tuple[MemoryState, int]:
        """The state as of command ``t`` — hash-identical to replaying
        ``log[:t]`` from genesis. Returns (state, hash).

        Snapshots that fail verification (torn chunks, missing files) are
        skipped: the next-older snapshot plus a longer WAL tail rebuilds
        the same bits, so one bad snapshot never loses history the WAL
        still covers."""
        with self._lock:
            snaps = [s for s in self.snapshots() if s <= t]
            if not snaps:
                raise ValueError(
                    f"no snapshot at or below t={t} (oldest retained: "
                    f"{self.snapshots()[:1]}); retention dropped that history")
            last_err: Optional[Exception] = None
            for base_t in reversed(snaps):
                try:
                    state, _ = snapshot.restore_v2(
                        self._snap_path(base_t).read_bytes(), self.chunks)
                except _RESTORE_ERRORS as e:
                    last_err = e  # broken snapshot: fall back one older
                    continue
                if t > base_t:
                    tail = self.wal.read_range(base_t, t)
                    state = machine.bulk_apply(
                        state, tail, ef_construction=ef_construction)
                return state, hashing.hash_pytree(state)
            raise ValueError(
                f"every snapshot at or below t={t} failed to restore"
            ) from last_err

    def recover(self, *, ef_construction: int = 32
                ) -> Tuple[MemoryState, int, int]:
        """Crash recovery: the state at the last durable prefix. The WAL
        open already truncated any torn tail; the newest snapshot may run
        ahead of a torn WAL (its chunks were durable first) — recover to
        whichever durable point is latest, falling back to earlier points
        if a snapshot is itself broken. When the recovered cursor is ahead
        of the WAL, the WAL cursor is advanced past the lost region (an
        explicit, refusable gap — never fabricated history), so new
        appends and checkpoints stay consistent. Returns (state, hash, t)."""
        with self._lock:
            candidates = sorted({self.wal.t, *self.snapshots()}, reverse=True)
            last_err: Optional[Exception] = None
            for t in candidates:
                try:
                    state, h = self.restore_at(
                        t, ef_construction=ef_construction)
                except _RESTORE_ERRORS as e:
                    last_err = e
                    continue
                if t > self.wal.t:
                    self.wal.reset_to(t)
                return state, h, t
            raise ValueError("no recoverable state in the store") from last_err

    def rollback_to(self, t: int) -> None:
        """Drop every durable artifact above logical time ``t``: snapshots
        with a newer cursor are deleted and the WAL is truncated to ``t``
        (``wal.WriteAheadLog.truncate_to``). Used by the sharded store to
        discard a shard's durable-but-never-globally-acked suffix so all
        shards rejoin lockstep at one reconciled global cursor. Refuses a
        ``t`` inside a lost gap — that history cannot be re-entered."""
        with self._lock:
            self.wal.truncate_to(t)  # raises before any snapshot is lost
            for s in self.snapshots():
                if s > t:
                    self._snap_path(s).unlink()

    # ------------------------------------------------------------------ #
    # retention + compaction
    # ------------------------------------------------------------------ #

    def referenced_chunk_keys(self) -> set:
        """Chunk keys referenced by any retained snapshot manifest — the
        live set a chunk-store sweep must preserve."""
        with self._lock:
            referenced = set()
            for t in self.snapshots():
                referenced.update(snapshot.manifest_chunk_keys(
                    self._snap_path(t).read_bytes()))
            return referenced

    def retain(self, keep: int) -> Dict[str, int]:
        """Keep the newest ``keep`` snapshots; drop older manifests, WAL
        segments wholly below the oldest retained snapshot, and chunks no
        surviving manifest references. When the chunk store is shared
        (sharded stores), the chunk sweep is the owner's job — other
        shards' manifests may reference keys this store no longer does."""
        if keep < 1:
            raise ValueError("must retain at least one snapshot")
        with self._lock:
            snaps = self.snapshots()
            dropped = snaps[:-keep] if len(snaps) > keep else []
            for t in dropped:
                self._snap_path(t).unlink()
            kept = self.snapshots()
            segs_dropped = self.wal.drop_below(kept[0]) if kept else 0

            chunks_dropped = 0
            if self._owns_chunks:
                referenced = self.referenced_chunk_keys()
                for key in self.chunks.keys():
                    if key not in referenced:
                        self.chunks.delete(key)
                        chunks_dropped += 1
            return {"snapshots_dropped": len(dropped),
                    "wal_segments_dropped": segs_dropped,
                    "chunks_dropped": chunks_dropped,
                    # lets a coordinator prune merged records for remote
                    # shards without listing their snapshot directories
                    "oldest_snapshot": kept[0] if kept else 0}

    def compact_wal(self, genesis: MemoryState) -> Dict[str, int]:
        """Fold dead commands in the WAL (wal.compact_log contract)."""
        with self._lock:
            return self.wal.compact(genesis)


def restore_at(store: DurableStore, t: int, *, ef_construction: int = 32
               ) -> Tuple[MemoryState, int]:
    """Module-level alias: the state as of command ``t`` (see
    ``DurableStore.restore_at``)."""
    return store.restore_at(t, ef_construction=ef_construction)


# --------------------------------------------------------------------------- #
# durable side tables: serving caches that survive a crash (DESIGN.md §7)
# --------------------------------------------------------------------------- #

_SIDE_MAGIC = b"VSDT"
_SIDE_FORMAT = 1


class SideTable:
    """Append-only durable ``key -> bytes`` table for serving-layer caches
    (the engine's doc token prefixes). Deliberately NOT part of the
    replayable state: nothing here is hashed into the memory, recovery of
    the substrate never depends on it, and a lost suffix merely refills
    lazily — but a restart no longer starts cold (the ROADMAP follow-up
    this closes).

    Format: a small fsynced header, then self-validating records
    ``u64 key | u32 len | payload | u64 digest(key|len|payload)``
    (``hashing.digest_bytes``). Later records for a key win, so an update
    is just another append. On open, the file is scanned and truncated to
    its longest valid record prefix — the WAL's torn-tail rule, applied to
    a cache. ``put`` buffers through the OS; ``sync()`` makes the table
    durable (the engine calls it at its flush/checkpoint barriers).

    The table is also shippable (DESIGN.md §9): records are kept in append
    order with a chained prefix digest (``digest_at``), so a replica can
    mirror the table record-by-record (``records_from`` on the primary,
    ``append_record`` on the replica) and verify the whole prefix against
    one advertised digest — the TAIL_ACK discipline applied to the cache."""

    def __init__(self, path: str | os.PathLike):
        self.path = pathlib.Path(path)
        self.entries: Dict[int, bytes] = {}
        self._records: list = []   # raw record bytes, append order
        self._chain: list = [0]    # _chain[i] = chained digest of records[:i]
        self._closed = False
        self._dirty = False
        # put/sync race when a timer-flush thread drives sync (the engine's
        # pre_flush hook) while the foreground thread is still putting: an
        # unsynchronized dirty flag could be cleared for a record that was
        # never fsynced, letting command durability outrun the cache's
        self._mu = threading.RLock()
        if self.path.exists():
            self._load_and_truncate()
        else:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(self.path.suffix + ".tmp")
            with open(tmp, "wb") as f:  # tmp+fsync+rename: never a torn header
                f.write(_SIDE_MAGIC + struct.pack("<I", _SIDE_FORMAT))
                f.flush()
                os.fsync(f.fileno())
            tmp.rename(self.path)
        self._f = open(self.path, "ab")

    def _load_and_truncate(self) -> None:
        data = self.path.read_bytes()
        if data[:4] != _SIDE_MAGIC:
            raise ValueError(f"{self.path.name}: not a side table")
        (fmt,) = struct.unpack_from("<I", data, 4)
        if fmt != _SIDE_FORMAT:
            raise ValueError(f"{self.path.name}: unsupported format {fmt}")
        off = 8
        valid = off
        while off + 12 <= len(data):
            key, n = struct.unpack_from("<QI", data, off)
            end = off + 12 + n + 8
            if end > len(data):
                break  # torn tail: short record
            (stored,) = struct.unpack_from("<Q", data, off + 12 + n)
            if stored != hashing.digest_bytes(data[off:off + 12 + n]):
                break  # torn/corrupt record: keep the valid prefix
            self.entries[key] = data[off + 12:off + 12 + n]
            self._records.append(data[off:end])
            self._chain.append(hashing.digest_bytes(
                struct.pack("<Q", self._chain[-1]) + data[off:end]))
            off = valid = end
        if valid < len(data):
            with open(self.path, "r+b") as f:
                f.truncate(valid)
                f.flush()
                os.fsync(f.fileno())

    def put(self, key: int, payload: bytes) -> None:
        """Record (buffered — durable after the next ``sync()``)."""
        body = struct.pack("<QI", key, len(payload)) + payload
        raw = body + struct.pack("<Q", hashing.digest_bytes(body))
        with self._mu:
            self._f.write(raw)
            self.entries[key] = payload
            self._records.append(raw)
            self._chain.append(hashing.digest_bytes(
                struct.pack("<Q", self._chain[-1]) + raw))
            self._dirty = True

    @property
    def record_count(self) -> int:
        with self._mu:
            return len(self._records)

    def digest_at(self, count: int) -> int:
        """Chained digest over the first ``count`` records — the verify
        target a mirroring replica must reproduce (0 records -> 0)."""
        with self._mu:
            if not 0 <= count < len(self._chain):
                raise ValueError(
                    f"digest_at({count}): table has {len(self._records)} "
                    "records")
            return self._chain[count]

    def records_from(self, index: int):
        """Raw self-validating record bytes [index, record_count) — what
        SIDE_TAIL ships."""
        with self._mu:
            if not 0 <= index <= len(self._records):
                raise ValueError(
                    f"records_from({index}): table has {len(self._records)} "
                    "records")
            return list(self._records[index:])

    def append_record(self, raw: bytes) -> None:
        """Mirror one shipped record: re-verify its embedded digest, then
        append it byte-identically (buffered; durable after ``sync()``).
        A mirrored table is therefore a byte prefix of its source."""
        if len(raw) < 20:
            raise ValueError("side-table record truncated")
        key, n = struct.unpack_from("<QI", raw, 0)
        if len(raw) != 12 + n + 8:
            raise ValueError("side-table record length mismatch")
        (stored,) = struct.unpack_from("<Q", raw, 12 + n)
        if stored != hashing.digest_bytes(raw[:12 + n]):
            raise ValueError("side-table record digest mismatch")
        with self._mu:
            self._f.write(raw)
            self.entries[key] = raw[12:12 + n]
            self._records.append(raw)
            self._chain.append(hashing.digest_bytes(
                struct.pack("<Q", self._chain[-1]) + raw))
            self._dirty = True

    def sync(self) -> None:
        """Make every ``put`` so far durable (no-op when clean)."""
        with self._mu:
            if not self._dirty:
                return
            self._f.flush()
            os.fsync(self._f.fileno())
            self._dirty = False

    def close(self) -> None:
        """Idempotent: flush once, then become a no-op (engines and hosts
        are closed repeatedly by benches and kill tests)."""
        with self._mu:
            if self._closed:
                return
            self.sync()
            self._f.close()
            self._closed = True
