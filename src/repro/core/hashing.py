"""Deterministic state hashing (paper §8.1 "Snapshot Transfer" / §9 consensus).

Two-level scheme, identical on host (numpy) and device (jit):

  1. per-leaf digest: the leaf's canonical little-endian words are mixed with
     an order-sensitive multiply-xor (uint64, wraparound exact in both numpy
     and JAX) and folded with XOR — parallel/vectorizable but order-sensitive,
     so permuted contents hash differently;
  2. the per-leaf digests (xor'd with an FNV-1a hash of the leaf's tree path,
     dtype and shape) enter a sequential FNV-1a chain in sorted-path order.

Integer ops only ⇒ the hash is bit-identical across platforms, in/out of jit,
and under any sharding — which is exactly what the paper's snapshot-transfer
experiment (x86 → ARM, H_A ≡ H_B) requires. ``hash_pytree`` (host) and
``hash_state_device`` (jittable) return the same value for the same tree; the
test suite asserts this equivalence.
"""
from __future__ import annotations

import struct
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MIX_GOLDEN = 0x9E3779B97F4A7C15
MIX_PRIME = 0xC2B2AE3D27D4EB4F
_U64 = (1 << 64) - 1


# --------------------------------------------------------------------------- #
# canonical word view
# --------------------------------------------------------------------------- #


def _host_words(leaf: Any) -> np.ndarray:
    """Canonical uint64-word sequence of an array's little-endian bytes.

    Words are itemsize-granular (one word per element; 8-byte elements split
    into lo,hi), matching the device bitcast decomposition exactly.
    """
    arr = np.asarray(leaf)
    if arr.dtype == np.bool_:
        arr = arr.astype(np.uint8)
    b = arr.tobytes()  # C order
    itemsize = arr.dtype.itemsize
    if itemsize == 8:
        w = np.frombuffer(b, dtype="<u8")
        lo = w & np.uint64(0xFFFFFFFF)
        hi = w >> np.uint64(32)
        return np.stack([lo, hi], axis=-1).reshape(-1)
    if itemsize == 4:
        return np.frombuffer(b, dtype="<u4").astype(np.uint64)
    if itemsize == 2:
        return np.frombuffer(b, dtype="<u2").astype(np.uint64)
    if itemsize == 1:
        return np.frombuffer(b, dtype="u1").astype(np.uint64)
    raise TypeError(f"unhashable dtype {arr.dtype}")


def _device_words(leaf: jax.Array) -> jax.Array:
    leaf = jnp.asarray(leaf)
    if leaf.dtype == jnp.bool_:
        leaf = leaf.astype(jnp.uint8)
    flat = leaf.reshape(-1)
    itemsize = flat.dtype.itemsize
    if itemsize == 8:
        w = jax.lax.bitcast_convert_type(flat, jnp.uint64)
        lo = w & jnp.uint64(0xFFFFFFFF)
        hi = w >> jnp.uint64(32)
        return jnp.stack([lo, hi], axis=-1).reshape(-1)
    if itemsize == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32).astype(jnp.uint64)
    if itemsize == 2:
        return jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint64)
    if itemsize == 1:
        return jax.lax.bitcast_convert_type(flat, jnp.uint8).astype(jnp.uint64)
    raise TypeError(f"unhashable dtype {leaf.dtype}")


# --------------------------------------------------------------------------- #
# level 1: order-sensitive parallel fold
# --------------------------------------------------------------------------- #


def _mix_fold_host(words: np.ndarray) -> int:
    if words.size == 0:
        return 0
    with np.errstate(over="ignore"):
        idx = np.arange(words.shape[0], dtype=np.uint64)
        mixed = (words ^ (idx * np.uint64(MIX_GOLDEN))) * np.uint64(MIX_PRIME)
        return int(np.bitwise_xor.reduce(mixed))


def _mix_fold_device(words: jax.Array) -> jax.Array:
    if words.shape[0] == 0:
        return jnp.uint64(0)
    idx = jnp.arange(words.shape[0], dtype=jnp.uint64)
    mixed = (words ^ (idx * jnp.uint64(MIX_GOLDEN))) * jnp.uint64(MIX_PRIME)
    return jax.lax.reduce(mixed, jnp.uint64(0), jax.lax.bitwise_xor, dimensions=[0])


# --------------------------------------------------------------------------- #
# level 2: FNV-1a chain over (path ^ digest) entries
# --------------------------------------------------------------------------- #


def _fnv1a_bytes(data: bytes, h: int = FNV_OFFSET) -> int:
    for ch in data:
        h = ((h ^ ch) * FNV_PRIME) & _U64
    return h


def _leaf_meta_hash(path, leaf) -> int:
    """Static per-leaf salt: tree path + dtype + shape (host-computable even
    for tracers, since metadata is static under jit)."""
    h = _fnv1a_bytes(jax.tree_util.keystr(path).encode())
    dt = jnp.asarray(leaf).dtype if hasattr(leaf, "dtype") else np.asarray(leaf).dtype
    h = _fnv1a_bytes(str(dt).encode(), h)
    for s in np.shape(leaf):
        h = ((h ^ (s & _U64)) * FNV_PRIME) & _U64
    return h


def _fnv_chain_host(entries) -> int:
    h = FNV_OFFSET
    for e in entries:
        h = ((h ^ (int(e) & _U64)) * FNV_PRIME) & _U64
    return h


def digest_bytes(data: bytes) -> int:
    """Order-sensitive 64-bit digest of a raw byte string: zero-pad to
    8-byte words, vectorized mix-fold, salted with an FNV hash of the
    length (so a chunk and its zero-extension differ). Platform-invariant
    like everything here, but ~100x faster than byte-wise FNV on bulk
    payloads — this is what the durability layer (chunk keys, WAL record
    chains) hashes with."""
    pad = (-len(data)) % 8
    words = np.frombuffer(data + b"\0" * pad, dtype="<u8").astype(np.uint64)
    return _mix_fold_host(words) ^ _fnv1a_bytes(struct.pack("<Q", len(data)))


def content_hash(state: Any) -> int:
    """Layout-invariant 64-bit hash of a memory's *live content*.

    Hashes the live rows sorted by external id — ``(ids, vectors, meta)``
    triples — and nothing else, so the value is invariant to slot layout,
    arena capacity, shard count and merge order: a flat single-kernel
    state and the merged sharded-layout state built from the same command
    log agree on it (the cross-layout conformance artifact, DESIGN.md §7).
    It deliberately excludes what is layout-dependent by construction:
    slot indices, the HNSW graph, ``links`` rows (slot-local adjacency),
    free-list cursors and the padded ``version`` clock. ``hash_pytree``
    remains the within-layout artifact durability verifies; this is the
    across-layout one the serve engine's ``memory_hash()`` reports."""
    ids = np.asarray(state.ids)
    valid = np.asarray(state.valid)
    live = np.flatnonzero(valid)
    # ids are unique among live rows (machine invariant), so the sort is a
    # total, deterministic order
    order = live[np.argsort(ids[live], kind="stable")]
    return hash_pytree((ids[order],
                        np.asarray(state.vectors)[order],
                        np.asarray(state.meta)[order]))


def hash_pytree(tree: Any) -> int:
    """Deterministic 64-bit hash of a pytree of arrays, on host."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    entries = []
    for path, leaf in leaves:
        digest = _mix_fold_host(_host_words(leaf))
        entries.append(digest ^ _leaf_meta_hash(path, leaf))
    return _fnv_chain_host(entries)


def hash_state_device(tree: Any) -> jax.Array:
    """Jittable tree hash; bit-identical to ``hash_pytree`` on the same tree."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    entries = []
    for path, leaf in leaves:
        digest = _mix_fold_device(_device_words(leaf))
        entries.append(digest ^ jnp.uint64(_leaf_meta_hash(path, leaf)))
    prime = jnp.uint64(FNV_PRIME)

    h = jnp.uint64(FNV_OFFSET)
    if entries:
        def step(h, e):
            return (h ^ e) * prime, None
        h, _ = jax.lax.scan(step, h, jnp.stack(entries))
    return h
