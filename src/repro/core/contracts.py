"""Precision contracts (paper §6): numeric precision as a configurable memory contract.

A contract fixes the Q-format used inside the deterministic domain. Determinism
is preserved for *any* contract because all in-kernel arithmetic is integer
arithmetic (associative, exact); the contract only trades range/resolution
against storage and bandwidth.

The storage dtype is the narrowest signed integer that holds
``int_bits + frac_bits`` (plus sign); accumulation always happens in a wider
integer type (``acc_dtype``) so dot products over large dimensions cannot
overflow before the final renormalization — mirroring the paper's "i64 (or
wider) intermediates" rule (§5.1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PrecisionContract:
    """A Q(int_bits).(frac_bits) fixed-point memory contract."""

    name: str
    int_bits: int   # integer bits excluding the sign bit
    frac_bits: int

    # ------------------------------------------------------------------ #
    @property
    def total_bits(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def storage_dtype(self):
        bits = self.total_bits
        if bits <= 8:
            return jnp.int8
        if bits <= 16:
            return jnp.int16
        if bits <= 32:
            return jnp.int32
        if bits <= 64:
            return jnp.int64
        raise ValueError(f"contract {self.name} needs {bits} bits > 64")

    @property
    def acc_dtype(self):
        """Accumulator type for sums of products (always 2x storage width)."""
        bits = self.total_bits
        if bits <= 16:
            return jnp.int32
        return jnp.int64

    @property
    def one(self) -> int:
        """Fixed-point representation of 1.0."""
        return 1 << self.frac_bits

    @property
    def max_raw(self) -> int:
        return (1 << (self.int_bits + self.frac_bits)) - 1

    @property
    def min_raw(self) -> int:
        return -(1 << (self.int_bits + self.frac_bits))

    @property
    def max_value(self) -> float:
        return self.max_raw / self.one

    @property
    def min_value(self) -> float:
        return self.min_raw / self.one

    @property
    def resolution(self) -> float:
        return 1.0 / self.one

    # numpy equivalents (for host-side serialization) ------------------- #
    @property
    def np_storage_dtype(self):
        return np.dtype(jnp.dtype(self.storage_dtype).name)

    def describe(self) -> str:
        return (
            f"{self.name}: range [{self.min_value}, {self.max_value}], "
            f"resolution {self.resolution:.2e}, storage {jnp.dtype(self.storage_dtype).name}, "
            f"accum {jnp.dtype(self.acc_dtype).name}"
        )


# The paper's contract ladder (Table 2). Q64.64/Q128 exceed 64-bit storage and
# are listed as future work in the paper; we expose the ones realizable with
# native integer dtypes and keep the ladder extensible.
Q8_8 = PrecisionContract("Q8.8", int_bits=7, frac_bits=8)
Q16_16 = PrecisionContract("Q16.16", int_bits=15, frac_bits=16)
Q32_32 = PrecisionContract("Q32.32", int_bits=31, frac_bits=32)
# narrow wire format used by the gradient-compression path
Q2_13 = PrecisionContract("Q2.13", int_bits=2, frac_bits=13)

CONTRACTS: Dict[str, PrecisionContract] = {
    c.name: c for c in (Q8_8, Q16_16, Q32_32, Q2_13)
}

DEFAULT_CONTRACT = Q16_16


def get_contract(name: str) -> PrecisionContract:
    try:
        return CONTRACTS[name]
    except KeyError as e:
        raise KeyError(
            f"unknown precision contract {name!r}; have {sorted(CONTRACTS)}"
        ) from e
