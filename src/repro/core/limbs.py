"""Exact wide-integer (128-bit) arithmetic from 32-bit limbs.

Realizes the paper's Table 2 "future" contracts: Q32.32 products need 128-bit
accumulation, which neither JAX nor TPU offer natively. We represent signed
128-bit values as four uint32 limbs (little-endian) and build
add/mul/accumulate from single-width ops with explicit carries — every step
is a native integer instruction, so the § 5.1 determinism argument extends
unchanged to the wide domain.

Used by fixedpoint.qdot_q32 (exact Q32.32 dot products) and validated against
Python bigints in tests/test_limbs.py. Throughput is ~10 int ops per MAC —
the paper's anticipated cost of the "enterprise" contract.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_MASK32 = jnp.uint64(0xFFFFFFFF)

# A wide value is a tuple of 4 uint32 arrays (lo → hi limbs), two's complement.
Wide = Tuple[jax.Array, jax.Array, jax.Array, jax.Array]


def from_int64(x: jax.Array) -> Wide:
    """Sign-extend int64 → 4-limb two's complement."""
    u = x.astype(jnp.uint64)
    lo = (u & _MASK32).astype(jnp.uint32)
    hi = ((u >> jnp.uint64(32)) & _MASK32).astype(jnp.uint32)
    sign = jnp.where(x < 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return (lo, hi, sign, sign)


def zeros_like_wide(x: jax.Array) -> Wide:
    z = jnp.zeros(x.shape, jnp.uint32)
    return (z, z, z, z)


def wide_add(a: Wide, b: Wide) -> Wide:
    """Limbwise add with carry propagation (mod 2^128, two's complement)."""
    out = []
    carry = jnp.zeros(a[0].shape, jnp.uint64)
    for i in range(4):
        s = a[i].astype(jnp.uint64) + b[i].astype(jnp.uint64) + carry
        out.append((s & _MASK32).astype(jnp.uint32))
        carry = s >> jnp.uint64(32)
    return tuple(out)


def wide_neg(a: Wide) -> Wide:
    inv = tuple((~x) for x in a)
    one = (jnp.ones(a[0].shape, jnp.uint32), jnp.zeros(a[0].shape, jnp.uint32),
           jnp.zeros(a[0].shape, jnp.uint32), jnp.zeros(a[0].shape, jnp.uint32))
    return wide_add(inv, one)


def mul_i64_i64(a: jax.Array, b: jax.Array) -> Wide:
    """Exact signed 64×64 → 128-bit product via 32-bit limb partials.

    |a|,|b| split into (lo, hi) uint32 limbs; four 32×32→64 partial products
    are accumulated with carries; the sign is applied by two's complement.
    """
    sign = (a < 0) ^ (b < 0)
    ua = jnp.abs(a).astype(jnp.uint64)
    ub = jnp.abs(b).astype(jnp.uint64)
    a0 = ua & _MASK32
    a1 = ua >> jnp.uint64(32)
    b0 = ub & _MASK32
    b1 = ub >> jnp.uint64(32)

    p00 = a0 * b0                     # ≤ 2^64-ish, exact in uint64
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1

    # accumulate into limbs l0..l3 with carries
    l0 = p00 & _MASK32
    t1 = (p00 >> jnp.uint64(32)) + (p01 & _MASK32) + (p10 & _MASK32)
    l1 = t1 & _MASK32
    t2 = (t1 >> jnp.uint64(32)) + (p01 >> jnp.uint64(32)) \
        + (p10 >> jnp.uint64(32)) + (p11 & _MASK32)
    l2 = t2 & _MASK32
    l3 = (t2 >> jnp.uint64(32)) + (p11 >> jnp.uint64(32))
    mag = (l0.astype(jnp.uint32), l1.astype(jnp.uint32),
           l2.astype(jnp.uint32), (l3 & _MASK32).astype(jnp.uint32))
    neg = wide_neg(mag)
    return tuple(jnp.where(sign, n, m) for n, m in zip(neg, mag))


def wide_sum(w: Wide, axis: int = -1) -> Wide:
    """Order-invariant exact sum along an axis: per-limb uint64 partial sums
    with deferred carry propagation (each limb sum ≤ 2^32 · n < 2^64 for
    n < 2^32 elements)."""
    sums = [jnp.sum(x.astype(jnp.uint64), axis=axis) for x in w]
    out = []
    carry = jnp.zeros(sums[0].shape, jnp.uint64)
    for s in sums:
        t = s + carry
        out.append((t & _MASK32).astype(jnp.uint32))
        carry = t >> jnp.uint64(32)
    return tuple(out)


def to_float(w: Wide) -> jax.Array:
    """Approximate float64 view (for diagnostics; exactness lives in limbs)."""
    sign_bit = (w[3] >> jnp.uint32(31)) & jnp.uint32(1)
    # two's complement magnitude
    neg = wide_neg(w)
    limbs = [jnp.where(sign_bit == 1, n, p) for n, p in zip(neg, w)]
    val = jnp.zeros(w[0].shape, jnp.float64)
    for i, x in enumerate(limbs):
        val = val + x.astype(jnp.float64) * (2.0 ** (32 * i))
    return jnp.where(sign_bit == 1, -val, val)


def to_python_int(w) -> int:
    """Host-side exact conversion (scalar) for tests."""
    import numpy as np
    limbs = [int(np.asarray(x)) for x in w]
    u = sum(l << (32 * i) for i, l in enumerate(limbs))
    if u >= 1 << 127:
        u -= 1 << 128
    return u


# --------------------------------------------------------------------------- #
# Q32.32 operations built on limbs
# --------------------------------------------------------------------------- #


def qdot_q32_wide(a: jax.Array, b: jax.Array, axis: int = -1) -> Wide:
    """Exact Q32.32 dot product accumulated in 128 bits (Q(64) scale).

    a, b: int64 raw Q32.32 arrays. The result is the exact Σ aᵢ·bᵢ — wide,
    unshifted — monotone for ranking, order-invariant by construction.
    """
    prods = mul_i64_i64(a, b)
    return wide_sum(prods, axis=axis)


def q32_dot_to_q32(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    """Q32.32 dot renormalized back to Q32.32 (int64), saturating.

    Shift right by 32 = drop limb 0; saturate to int64 if the true value
    exceeds 64 bits (|limb3| must be pure sign extension of limb2's msb).
    """
    w = qdot_q32_wide(a, b, axis)
    l0, l1, l2, l3 = w
    val = (l1.astype(jnp.uint64)
           | (l2.astype(jnp.uint64) << jnp.uint64(32))).astype(jnp.int64)
    # overflow detection: l3 (and l2's sign) must match val's sign extension
    sign = (l2 >> jnp.uint32(31)) & jnp.uint32(1)
    expect_l3 = jnp.where(sign == 1, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    ok = l3 == expect_l3
    maxv = jnp.int64(2**63 - 1)
    minv = jnp.int64(-(2**63))
    pos_overflow = (l3 >> jnp.uint32(31)) == 0
    return jnp.where(ok, val, jnp.where(pos_overflow, maxv, minv))
