"""Version-bridging shims over the handful of JAX APIs that moved.

The repo targets the modern surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``); the pinned
toolchain may ship an older JAX where those live elsewhere or do not exist.
Everything here resolves the best available implementation at import time
with guarded ``getattr`` — no behavior change on new JAX.
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax

# --------------------------------------------------------------------------- #
# shard_map: jax.shard_map (new) → jax.experimental.shard_map (old)
# --------------------------------------------------------------------------- #

_new_shard_map = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              **kwargs):
    """``jax.shard_map`` with the new signature, on any JAX.

    Old JAX calls it ``jax.experimental.shard_map.shard_map`` and spells
    ``check_vma`` as ``check_rep``; the new API's ``axis_names`` (axes that
    are manual inside the body) maps to the old API's complementary
    ``auto`` set — dropping it would silently manualize every mesh axis.
    """
    if _new_shard_map is not None:
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma,
                              **kwargs)
    from jax.experimental.shard_map import shard_map as _old
    axis_names = kwargs.pop("axis_names", None)
    if kwargs:  # loud, not silent: dropped options would skew by version
        raise TypeError(f"compat.shard_map: unsupported on this JAX: "
                        f"{sorted(kwargs)}")
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma, auto=auto)


# --------------------------------------------------------------------------- #
# mesh construction / ambient mesh context
# --------------------------------------------------------------------------- #


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(shape))
    return jax.make_mesh(tuple(shape), tuple(axis_names))


@contextlib.contextmanager
def use_mesh(mesh):
    """``jax.set_mesh`` context on new JAX; ``jax.sharding.use_mesh`` on the
    mid-range versions that have it; ``with mesh:`` (thread-resource mesh)
    on old JAX. Either way :func:`get_abstract_mesh` sees it."""
    setter = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def pallas_tpu_compiler_params():
    """``pltpu.CompilerParams`` (new name) or ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def cost_analysis(compiled):
    """``compiled.cost_analysis()`` as a dict on every JAX version (older
    releases return a one-element list of dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        return ca[0] if ca else {}
    return ca or {}


def get_abstract_mesh():
    """The ambient mesh (or None): ``jax.sharding.get_abstract_mesh`` when it
    exists, else the thread-resources physical mesh set by ``with mesh:``."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        m = getter()
        return m if (m is not None and m.axis_names) else None
    try:
        from jax.interpreters.pxla import thread_resources
        m = thread_resources.env.physical_mesh
        return m if (m is not None and m.axis_names) else None
    except Exception:  # pragma: no cover — very old/new layouts
        return None
