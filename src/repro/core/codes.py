"""Deterministic compressed vector tier: int8 codes over Q16.16 rows.

The exact arena stores one int32 Q16.16 raw value per (row, dim). At scale
that costs twice: bytes held AND bytes streamed per exact-route scan. This
module adds a compressed tier in the MonaVec direction (PAPERS.md) without
giving up the substrate's core property: every byte of it is a *pure integer
function of the live rows*, so the code table is replay-invariant state, not
a cache — the same live content produces the same codes on every platform,
every layout, every replay.

Per-dimension integer scalar quantization (DESIGN.md §10):

    offset_j = ((lo_j + hi_j) >> 1 >> e_j) << e_j      (multiple of scale_j)
    scale_j  = 2^e_j,  e_j = smallest e with 127 * 2^e >= dev_j
    code_ij  = clip(round_nearest((raw_ij - offset_j) / scale_j), -127, 127)

with lo/hi the per-dim min/max over live rows and dev_j the max deviation
from the midpoint. Everything is shifts, integer compares and the
round-to-nearest integer division from ``core/fixedpoint.py`` — bit-exact
everywhere. Dead rows encode as all-zero codes with zero norms, so the
table's bytes are themselves layout-hashable.

Why powers of two: params only change when a per-dim extreme moves far
enough to cross a power-of-two bucket, so ``refresh`` (the incremental
maintenance rule ``bulk_apply`` callers use) almost always re-encodes only
the touched rows; when params do drift it falls back to a full rebuild that
is bit-identical to ``build`` by construction (tests/test_codes.py proves
``refresh == build`` over randomized six-opcode logs).

Coarse scoring (kernels/qcoarse) ranks by an int32-weighted dot against the
codes; re-ranking the survivors with the exact wide Q16.16 scores restores
bit-exactness whenever the candidate set covers the exact top-k — in
particular, ``ef_coarse >= live_count`` makes the served answer equal
``exact_search``'s hash regardless of quantization error (the
coverage-implies-bit-exact contract the conformance suite pins).

Range analysis: boundary-normalized rows satisfy |raw| <= 2^16, so
dev <= 2^17, e <= 11, scale <= 2^11, and a query weight
|w_j| = |(q_j - offset_j) * scale_j| <= 2^28 = ``W_BOUND`` — the bound the
qcoarse kernel's int32 limb planes rely on (see kernels/qcoarse/kernel.py).
"""
from __future__ import annotations

import dataclasses
import io
import struct
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fp
from repro.core import hashing
from repro.core.state import MemoryState

# smallest e with 127 * 2^e >= dev, searched over e in [0, MAX_EXP)
MAX_EXP = 16
# |query weight| bound for boundary-normalized inputs (kernel exactness)
W_BOUND = 1 << 28

METRIC_L2 = "l2"
METRIC_DOT = "dot"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CodeTable:
    """The compressed tier. Invariant: ``table == build(state)`` — a pure
    function of the live rows, maintained incrementally by ``refresh``."""
    codes: jax.Array    # [capacity, dim] int8; dead rows all-zero
    offset: jax.Array   # [dim] int32, a multiple of scale
    scale: jax.Array    # [dim] int32, a power of two >= 1
    norms: jax.Array    # [capacity] int64: sum_j (codes*scale)^2; dead rows 0


# --------------------------------------------------------------------------- #
# params + encoding: integer-only, pure in the live rows
# --------------------------------------------------------------------------- #


def code_params(vectors: jax.Array, valid: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Per-dim (offset int32, scale int32) from the live rows only.

    Pure in the live *multiset*: any layout/permutation of the same live
    content produces the same params (min/max are order-invariant), which
    is what keeps sharded and flat coarse tiers comparable.
    """
    v = vectors.astype(jnp.int32)
    live = valid[:, None]
    big = jnp.int32(2**31 - 1)
    lo = jnp.min(jnp.where(live, v, big), axis=0)
    hi = jnp.max(jnp.where(live, v, -big), axis=0)
    has = jnp.any(valid)
    lo = jnp.where(has, lo, jnp.int32(0))
    hi = jnp.where(has, hi, jnp.int32(0))
    # midpoint in int64: lo+hi can overflow int32 at the contract extremes
    mid = ((lo.astype(jnp.int64) + hi.astype(jnp.int64)) >> 1).astype(jnp.int32)
    dev = jnp.maximum(hi - mid, mid - lo)                  # >= 0
    need = (dev + 126) // 127                              # ceil(dev / 127)
    powers = jnp.left_shift(jnp.int32(1), jnp.arange(MAX_EXP, dtype=jnp.int32))
    e = jnp.sum((powers[None, :] < need[:, None]).astype(jnp.int32), axis=1)
    scale = jnp.left_shift(jnp.int32(1), e).astype(jnp.int32)
    # bucket the offset to a multiple of scale: extremes must shift the
    # midpoint by >= scale before params change at all — the stability
    # that makes refresh() incremental in practice
    offset = jnp.left_shift(jnp.right_shift(mid, e), e).astype(jnp.int32)
    return offset, scale


def encode_rows(vectors: jax.Array, valid: jax.Array,
                offset: jax.Array, scale: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """(codes int8 [n, dim], norms int64 [n]) for rows under fixed params.

    Element-local: code_ij depends only on (raw_ij, valid_i, offset_j,
    scale_j) — the fact that makes row-sliced refresh bit-equal to a full
    rebuild. Rounding is the round-half-away-from-zero integer division
    every fixed-point op in this repo uses.
    """
    v = vectors.astype(jnp.int64)
    delta = v - offset.astype(jnp.int64)[None, :]
    c = fp._int_div_round_to_nearest(delta, scale.astype(jnp.int64)[None, :])
    c = jnp.clip(c, -127, 127)
    c = jnp.where(valid[:, None], c, 0).astype(jnp.int8)
    deq = c.astype(jnp.int64) * scale.astype(jnp.int64)[None, :]
    norms = jnp.where(valid, jnp.sum(deq * deq, axis=-1), jnp.int64(0))
    return c, norms


@jax.jit
def build(state: MemoryState) -> CodeTable:
    """The reference constructor: the whole table from the live rows."""
    offset, scale = code_params(state.vectors, state.valid)
    c, norms = encode_rows(state.vectors, state.valid, offset, scale)
    return CodeTable(codes=c, offset=offset, scale=scale, norms=norms)


def refresh(table: CodeTable, state: MemoryState,
            touched_slots: np.ndarray) -> CodeTable:
    """Incremental maintenance: bit-identical to ``build(state)`` given
    ``touched_slots`` covers every slot whose (vector, valid) changed.

    Params are recomputed (cheap: one masked min/max) and compared; while
    they hold steady — the common case, thanks to power-of-two bucketing —
    only the touched rows re-encode. A param drift (a new per-dim extreme
    crossed a bucket) re-encodes everything, which is exactly ``build``.
    """
    offset, scale = code_params(state.vectors, state.valid)
    if (np.any(np.asarray(offset) != np.asarray(table.offset))
            or np.any(np.asarray(scale) != np.asarray(table.scale))):
        return build(state)
    t = np.asarray(touched_slots, np.int32)
    if t.size == 0:
        return table
    ti = jnp.asarray(t)
    c_sub, n_sub = encode_rows(state.vectors[ti], state.valid[ti],
                               table.offset, table.scale)
    return CodeTable(codes=table.codes.at[ti].set(c_sub),
                     offset=table.offset, scale=table.scale,
                     norms=table.norms.at[ti].set(n_sub))


def diff_slots(prev: MemoryState, cur: MemoryState) -> np.ndarray:
    """Slots whose (vector, valid) changed between two states — the touched
    set a generic log application must refresh. Host-side; used by
    ``apply_with_codes`` so arbitrary six-opcode logs maintain the table."""
    pv = np.asarray(prev.vectors)
    cv = np.asarray(cur.vectors)
    changed = np.any(pv != cv, axis=-1)
    changed |= np.asarray(prev.valid) != np.asarray(cur.valid)
    return np.nonzero(changed)[0].astype(np.int32)


def apply_with_codes(state: MemoryState, table: CodeTable, log,
                     *, ef_construction: int = 32
                     ) -> Tuple[MemoryState, CodeTable]:
    """``machine.bulk_apply`` plus table maintenance in one step — the
    write-path pairing that keeps ``table == build(state)`` an invariant
    across INSERT/DELETE/upsert (tests/test_codes.py replays randomized
    logs through this and checks the invariant bit-for-bit)."""
    from repro.core import machine  # lazy: machine must not depend on us
    new_state = machine.bulk_apply(state, log, ef_construction=ef_construction)
    return new_state, refresh(table, new_state, diff_slots(state, new_state))


# --------------------------------------------------------------------------- #
# query-side weights for the coarse scan
# --------------------------------------------------------------------------- #


def query_weights(queries_raw: jax.Array, table: CodeTable, metric: str
                  ) -> jax.Array:
    """int32 weights w [nq, dim] such that ranking by the integer dot
    ``S_i = sum_j w_j * codes_ij`` (plus the stored row norms for L2)
    orders rows by their metric against the *dequantized* vectors:

      l2 : ||q - (offset + c*scale)||^2 = const - 2*S_i + norms_i,
           w_j = (q_j - offset_j) * scale_j
      dot: -<q, offset + c*scale>      = const - S_i,
           w_j = q_j * scale_j

    Computed in int64 then clipped to +-W_BOUND so the qcoarse limb planes
    stay int32-exact (boundary-normalized inputs never reach the clip).
    """
    q = queries_raw.astype(jnp.int64)
    s = table.scale.astype(jnp.int64)[None, :]
    if metric == METRIC_L2:
        w = (q - table.offset.astype(jnp.int64)[None, :]) * s
    elif metric == METRIC_DOT:
        w = q * s
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.clip(w, -W_BOUND, W_BOUND).astype(jnp.int32)


def table_hash(table: CodeTable) -> int:
    """Platform-invariant hash of the table — must equal the hash of
    ``build(state)`` on every holder of the same state (audit artifact)."""
    return hashing.hash_pytree(table)


# --------------------------------------------------------------------------- #
# durability: the table rides the chunked v2 snapshot format
# --------------------------------------------------------------------------- #

MAGIC_CODES = b"VLRQ"
_FORMAT_VERSION = 1
_U64 = (1 << 64) - 1
# fixed leaf order + dtypes: the manifest is self-describing but the
# restore refuses anything that isn't exactly a CodeTable
_LEAVES = (("codes", np.int8), ("offset", np.int32),
           ("scale", np.int32), ("norms", np.int64))


def snapshot_table_v2(table: CodeTable, cursor: int, store, *,
                      chunk_size: int = 8192) -> Tuple[bytes, dict]:
    """Write the table's chunks into a ``snapshot.ChunkStore`` and return
    (manifest bytes, stats) — the same content-addressed manifest shape as
    ``snapshot.snapshot_v2``, so repeated checkpoints of a slowly-changing
    table cost only the dirty chunks (param-stable refreshes dirty only
    the touched rows' chunks)."""
    from repro.core import snapshot as snap
    store.reset_stats()
    buf = io.BytesIO()
    buf.write(MAGIC_CODES)
    buf.write(struct.pack("<I", _FORMAT_VERSION))
    buf.write(struct.pack("<Q", int(cursor) & _U64))
    buf.write(struct.pack("<I", chunk_size))
    buf.write(struct.pack("<I", len(_LEAVES)))
    total = 0
    for name, dtype in _LEAVES:
        arr = np.asarray(getattr(table, name), dtype=dtype)
        payload = arr.astype(arr.dtype.newbyteorder("<"), copy=False).tobytes()
        total += len(payload)
        snap._write_str(buf, name)
        buf.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            buf.write(struct.pack("<Q", d))
        keys = []
        for off in range(0, max(len(payload), 1), chunk_size):
            key, _ = store.put(payload[off:off + chunk_size])
            keys.append(key)
        buf.write(struct.pack("<Q", len(payload)))
        buf.write(struct.pack("<I", len(keys)))
        for key in keys:
            buf.write(struct.pack("<Q", key))
    buf.write(struct.pack("<Q", table_hash(table)))
    stats = {"chunks": store.puts, "chunks_written": store.writes,
             "bytes_written": store.bytes_written, "bytes_total": total,
             "manifest_bytes": buf.tell()}
    return buf.getvalue(), stats


def restore_table_v2(data: bytes, store) -> Tuple[CodeTable, int]:
    """Reassemble a table manifest against its chunk store; every chunk's
    content hash and the whole-table hash are verified. Returns
    (table, cursor)."""
    from repro.core import snapshot as snap
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC_CODES:
        raise ValueError("not a Valori code-table manifest")
    (ver,) = struct.unpack("<I", buf.read(4))
    if ver != _FORMAT_VERSION:
        raise ValueError(f"unsupported code-table format {ver}")
    (cursor,) = struct.unpack("<Q", buf.read(8))
    buf.read(4)  # chunk_size: recorded for tooling; lengths self-describe
    (n_leaves,) = struct.unpack("<I", buf.read(4))
    if n_leaves != len(_LEAVES):
        raise ValueError(f"code-table manifest has {n_leaves} leaves")
    arrays = {}
    for name, dtype in _LEAVES:
        got = snap._read_str(buf)
        if got != name:
            raise ValueError(f"leaf {got!r} where {name!r} expected")
        (ndim,) = struct.unpack("<I", buf.read(4))
        shape = tuple(struct.unpack("<Q", buf.read(8))[0]
                      for _ in range(ndim))
        (nbytes,) = struct.unpack("<Q", buf.read(8))
        (n_chunks,) = struct.unpack("<I", buf.read(4))
        parts = [store.get(struct.unpack("<Q", buf.read(8))[0])
                 for _ in range(n_chunks)]
        payload = b"".join(parts)
        if len(payload) != nbytes:
            raise ValueError(f"leaf {name}: got {len(payload)} bytes, "
                             f"manifest says {nbytes}")
        arr = np.frombuffer(payload, dtype=np.dtype(dtype).newbyteorder("<"))
        arrays[name] = jnp.asarray(arr.astype(dtype).reshape(shape))
    (stored_hash,) = struct.unpack("<Q", buf.read(8))
    table = CodeTable(**arrays)
    actual = table_hash(table)
    if actual != stored_hash:
        raise ValueError(f"code-table hash mismatch: stored "
                         f"{stored_hash:#x}, got {actual:#x}")
    return table, cursor


def table_manifest_cursor(data: bytes) -> int:
    if data[:4] != MAGIC_CODES:
        raise ValueError("not a Valori code-table manifest")
    (cursor,) = struct.unpack("<Q", data[8:16])
    return cursor


def table_manifest_chunk_keys(data: bytes) -> list:
    """All chunk keys a code-table manifest references (retention sweeps)."""
    from repro.core import snapshot as snap
    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC_CODES:
        raise ValueError("not a Valori code-table manifest")
    buf.read(16)  # version, cursor, chunk_size
    (n_leaves,) = struct.unpack("<I", buf.read(4))
    keys = []
    for _ in range(n_leaves):
        snap._read_str(buf)
        (ndim,) = struct.unpack("<I", buf.read(4))
        buf.read(8 * ndim + 8)
        (n_chunks,) = struct.unpack("<I", buf.read(4))
        for _ in range(n_chunks):
            (key,) = struct.unpack("<Q", buf.read(8))
            keys.append(key)
    return keys
