"""Integer-encoded command log (paper §3.1, §5.2).

Commands are the ONLY way memory state changes; the log is the replayable
audit trail. Encoding is a struct-of-arrays pytree so a whole log can be
applied with one ``lax.scan`` and serialized alongside snapshots.

Opcodes:
  NOP=0, INSERT=1, DELETE=2, LINK=3, UNLINK=4, SET_META=5

Fields per record:
  opcode int32; arg0 int64 (id / src id); arg1 int64 (dst id / meta slot);
  arg2 int64 (meta value); vec storage[dim] (INSERT payload, zeros otherwise).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract

NOP, INSERT, DELETE, LINK, UNLINK, SET_META = range(6)
NUM_OPCODES = 6

OPCODE_NAMES = ["NOP", "INSERT", "DELETE", "LINK", "UNLINK", "SET_META"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CommandLog:
    opcode: jax.Array  # [n] int32
    arg0: jax.Array    # [n] int64
    arg1: jax.Array    # [n] int64
    arg2: jax.Array    # [n] int64
    vec: jax.Array     # [n, dim] contract storage dtype

    def __len__(self) -> int:
        return self.opcode.shape[0]

    @property
    def dim(self) -> int:
        return self.vec.shape[1]

    def record(self, i) -> "CommandLog":
        """Single record (still a CommandLog of length semantics removed)."""
        return CommandLog(
            opcode=self.opcode[i], arg0=self.arg0[i], arg1=self.arg1[i],
            arg2=self.arg2[i], vec=self.vec[i],
        )

    def concat(self, other: "CommandLog") -> "CommandLog":
        return CommandLog(
            opcode=jnp.concatenate([self.opcode, other.opcode]),
            arg0=jnp.concatenate([self.arg0, other.arg0]),
            arg1=jnp.concatenate([self.arg1, other.arg1]),
            arg2=jnp.concatenate([self.arg2, other.arg2]),
            vec=jnp.concatenate([self.vec, other.vec]),
        )

    def slice(self, start: int, stop: int) -> "CommandLog":
        return CommandLog(
            opcode=self.opcode[start:stop], arg0=self.arg0[start:stop],
            arg1=self.arg1[start:stop], arg2=self.arg2[start:stop],
            vec=self.vec[start:stop],
        )


def empty_log(dim: int, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    return CommandLog(
        opcode=jnp.zeros((0,), jnp.int32),
        arg0=jnp.zeros((0,), jnp.int64),
        arg1=jnp.zeros((0,), jnp.int64),
        arg2=jnp.zeros((0,), jnp.int64),
        vec=jnp.zeros((0, dim), contract.storage_dtype),
    )


def _mk(opcode, dim, contract, a0=0, a1=0, a2=0, vec=None) -> CommandLog:
    v = jnp.zeros((1, dim), contract.storage_dtype) if vec is None else vec[None]
    return CommandLog(
        opcode=jnp.asarray([opcode], jnp.int32),
        arg0=jnp.asarray([a0], jnp.int64),
        arg1=jnp.asarray([a1], jnp.int64),
        arg2=jnp.asarray([a2], jnp.int64),
        vec=v.astype(contract.storage_dtype),
    )


def insert_cmd(ext_id, raw_vec, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    """raw_vec must already be fixed-point (post-boundary)."""
    return _mk(INSERT, raw_vec.shape[-1], contract, a0=ext_id, vec=raw_vec)


def delete_cmd(ext_id, dim, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    return _mk(DELETE, dim, contract, a0=ext_id)


def link_cmd(src_id, dst_id, dim, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    return _mk(LINK, dim, contract, a0=src_id, a1=dst_id)


def unlink_cmd(src_id, dst_id, dim, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    return _mk(UNLINK, dim, contract, a0=src_id, a1=dst_id)


def set_meta_cmd(ext_id, slot, value, dim,
                 contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    return _mk(SET_META, dim, contract, a0=ext_id, a1=slot, a2=value)


def insert_batch(ext_ids: jax.Array, raw_vecs: jax.Array,
                 contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    """Batch of INSERTs in *canonical (sorted-by-id) order* — paper §7.1:
    'items are processed in a verified, sorted order (usually by ID) to
    prevent race conditions or insertion-order dependencies'."""
    order = jnp.argsort(ext_ids)
    ext_ids = ext_ids[order]
    raw_vecs = raw_vecs[order]
    n, dim = raw_vecs.shape
    return CommandLog(
        opcode=jnp.full((n,), INSERT, jnp.int32),
        arg0=ext_ids.astype(jnp.int64),
        arg1=jnp.zeros((n,), jnp.int64),
        arg2=jnp.zeros((n,), jnp.int64),
        vec=raw_vecs.astype(contract.storage_dtype),
    )


def delete_batch(ext_ids: jax.Array, dim: int,
                 contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    """Batch of DELETEs in canonical (sorted-by-id) order — the churn twin
    of ``insert_batch``. ``dim`` fixes the (all-zero) vec payload shape so
    the batch concatenates with insert batches in one audit log."""
    ext_ids = ext_ids[jnp.argsort(ext_ids)]
    n = ext_ids.shape[0]
    return CommandLog(
        opcode=jnp.full((n,), DELETE, jnp.int32),
        arg0=ext_ids.astype(jnp.int64),
        arg1=jnp.zeros((n,), jnp.int64),
        arg2=jnp.zeros((n,), jnp.int64),
        vec=jnp.zeros((n, dim), contract.storage_dtype),
    )


def canonicalize_batch(log: CommandLog) -> CommandLog:
    """Sort a batch of same-opcode commands by (arg0, arg1) — the paper's
    'verified, sorted order'. Only safe for order-free batches (pure inserts
    or pure links); mixed logs define their own order by construction."""
    key = log.arg0 * jnp.int64(1 << 20) + jnp.clip(log.arg1, 0, (1 << 20) - 1)
    order = jnp.argsort(key)
    return jax.tree.map(lambda a: a[order], log)


# ---------------------------------------------------------------------------#
# host-side serialization (audit trail files)
# ---------------------------------------------------------------------------#


def log_to_bytes(log: CommandLog) -> bytes:
    """Canonical little-endian serialization of a command log."""
    parts = []
    header = np.asarray(
        [len(log), log.dim, np.asarray(log.vec).dtype.itemsize], dtype="<i8"
    )
    parts.append(header.tobytes())
    for name in ("opcode", "arg0", "arg1", "arg2", "vec"):
        arr = np.asarray(getattr(log, name))
        parts.append(arr.astype(arr.dtype.newbyteorder("<")).tobytes())
    return b"".join(parts)


def log_from_bytes(data: bytes, contract: PrecisionContract = DEFAULT_CONTRACT) -> CommandLog:
    n, dim, isz = np.frombuffer(data[:24], dtype="<i8")
    n, dim, isz = int(n), int(dim), int(isz)
    off = 24
    def take(dtype, count):
        nonlocal off
        nbytes = np.dtype(dtype).itemsize * count
        arr = np.frombuffer(data[off:off + nbytes], dtype=dtype)
        off += nbytes
        return arr
    opcode = take("<i4", n)
    arg0 = take("<i8", n)
    arg1 = take("<i8", n)
    arg2 = take("<i8", n)
    vdt = {1: "<i1", 2: "<i2", 4: "<i4", 8: "<i8"}[isz]
    vec = take(vdt, n * dim).reshape(n, dim)
    return CommandLog(
        opcode=jnp.asarray(opcode, jnp.int32),
        arg0=jnp.asarray(arg0, jnp.int64),
        arg1=jnp.asarray(arg1, jnp.int64),
        arg2=jnp.asarray(arg2, jnp.int64),
        vec=jnp.asarray(vec, contract.storage_dtype),
    )
