"""Sharded deterministic memory (DESIGN.md §2 — the paper's claim at pod scale).

The Rust kernel is single-node. At pod scale the arena is sharded row-wise
across the ``model`` mesh axis; queries are sharded across ``data``. The key
observation carried over from the paper: every cross-device combine here is
an *integer* collective (all-gather of wide scores + ids, then a sort-merge),
and integer collectives are exact and order-invariant — so the distributed
memory inherits bit-determinism from the arithmetic, not from scheduling.

Command routing is deterministic too: a command for external id ``i`` belongs
to shard ``splitmix64(i) mod n_shards``; each shard replays its own sub-log.
tests/test_distributed.py verifies that a multi-device shard_map run returns
search results bit-identical to the single-device kernel.

Layout: the distributed state reuses the MemoryState dataclass, with
* row arrays laid out shard-major: global row = shard * cap_per_shard + local;
* per-shard scalars (cursor/count/version/hnsw_entry) carried as [n_shards]
  arrays (each shard is its own little Valori kernel with its own clock).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import machine, search
from repro.core.commands import NOP, CommandLog
from repro.core.hnsw import splitmix64
from repro.core.state import MemoryState, init_state
from repro.core import compat

INF = search.INF


# --------------------------------------------------------------------------- #
# deterministic command routing
# --------------------------------------------------------------------------- #


def shard_of_id(ext_id, n_shards: int):
    """Shard owner of an external id — pure integer hash, platform-invariant."""
    return (splitmix64(jnp.asarray(ext_id, jnp.int64).astype(jnp.uint64))
            % jnp.uint64(n_shards)).astype(jnp.int32)


def route_commands(log: CommandLog, n_shards: int) -> CommandLog:
    """Split a global log into per-shard logs, NOP-padded to equal length:
    fields gain a leading [n_shards] axis. Relative order within a shard is
    preserved, so per-shard replay equals filtering the global replay."""
    opcode = np.asarray(log.opcode)
    arg0 = np.asarray(log.arg0)
    n = len(opcode)
    owners = np.asarray(shard_of_id(jnp.asarray(arg0), n_shards))

    per_shard_idx = [[] for _ in range(n_shards)]
    for i in range(n):
        per_shard_idx[int(owners[i])].append(i)
    max_len = max([len(ix) for ix in per_shard_idx] + [1])

    def pad_take(arr: np.ndarray, idx) -> np.ndarray:
        taken = arr[idx] if len(idx) else arr[:0]
        pad_shape = (max_len - len(idx),) + arr.shape[1:]
        return np.concatenate([taken, np.zeros(pad_shape, arr.dtype)], axis=0)

    fields = {}
    for name in ("opcode", "arg0", "arg1", "arg2", "vec"):
        arr = np.asarray(getattr(log, name))
        fields[name] = jnp.asarray(np.stack([pad_take(arr, ix) for ix in per_shard_idx]))
    lengths = jnp.asarray([len(ix) for ix in per_shard_idx])
    fields["opcode"] = jnp.where(
        jnp.arange(max_len)[None, :] < lengths[:, None], fields["opcode"], NOP
    ).astype(jnp.int32)
    return CommandLog(**fields)


# --------------------------------------------------------------------------- #
# sharded state construction + specs
# --------------------------------------------------------------------------- #


def init_sharded_host(n_shards: int, capacity_per_shard: int, dim: int,
                      **kwargs) -> MemoryState:
    """Empty sharded-layout state (shard-major rows, [n_shards] per-shard
    scalars) as plain host/default-device arrays — no mesh required. This
    is the genesis a ``shard_wal.ShardedDurableStore`` slices per shard;
    ``init_sharded_state`` lays the same state out over a mesh."""
    proto = init_state(capacity_per_shard, dim, **kwargs)

    def rep(x):  # per-shard scalar → [n_shards]
        return jnp.broadcast_to(x[None], (n_shards,) + x.shape)

    return dataclasses.replace(
        proto,
        vectors=jnp.tile(proto.vectors, (n_shards, 1)),
        ids=jnp.tile(proto.ids, (n_shards,)),
        valid=jnp.tile(proto.valid, (n_shards,)),
        links=jnp.tile(proto.links, (n_shards, 1)),
        meta=jnp.tile(proto.meta, (n_shards, 1)),
        hnsw_neighbors=jnp.tile(proto.hnsw_neighbors, (1, n_shards, 1)),
        hnsw_levels=jnp.tile(proto.hnsw_levels, (n_shards,)),
        hnsw_entry=rep(proto.hnsw_entry),
        cursor=rep(proto.cursor),
        count=rep(proto.count),
        version=rep(proto.version),
    )


def init_sharded_state(mesh: Mesh, axis: str, capacity_per_shard: int, dim: int,
                       **kwargs) -> MemoryState:
    state = init_sharded_host(mesh.shape[axis], capacity_per_shard, dim,
                              **kwargs)
    specs = state_specs(axis, state.contract_name)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs
    )


def state_specs(axis: str, contract_name: str) -> MemoryState:
    """PartitionSpecs for the sharded MemoryState layout described above."""
    return MemoryState(
        vectors=P(axis, None),
        ids=P(axis),
        valid=P(axis),
        links=P(axis, None),
        meta=P(axis, None),
        hnsw_neighbors=P(None, axis, None),
        hnsw_levels=P(axis),
        hnsw_entry=P(axis),
        cursor=P(axis),
        count=P(axis),
        version=P(axis),
        contract_name=contract_name,
    )


def _log_specs(axis: str) -> CommandLog:
    return CommandLog(
        opcode=P(axis, None), arg0=P(axis, None), arg1=P(axis, None),
        arg2=P(axis, None), vec=P(axis, None, None),
    )


def _to_local(state: MemoryState) -> MemoryState:
    """Inside shard_map: strip the local leading shard dim from scalars."""
    return dataclasses.replace(
        state,
        hnsw_entry=state.hnsw_entry[0], cursor=state.cursor[0],
        count=state.count[0], version=state.version[0],
    )


def _to_shardview(state: MemoryState) -> MemoryState:
    return dataclasses.replace(
        state,
        hnsw_entry=state.hnsw_entry[None], cursor=state.cursor[None],
        count=state.count[None], version=state.version[None],
    )


# --------------------------------------------------------------------------- #
# sharded replay + search via shard_map
# --------------------------------------------------------------------------- #


def distributed_replay(mesh: Mesh, axis: str, state: MemoryState,
                       routed_log: CommandLog, *, ef_construction: int = 32
                       ) -> MemoryState:
    """Replay per-shard logs on their shards (no cross-shard traffic: ids are
    hash-routed, so shards never contend)."""
    specs = state_specs(axis, state.contract_name)

    @partial(compat.shard_map, mesh=mesh, in_specs=(specs, _log_specs(axis)),
             out_specs=specs, check_vma=False)
    def _replay(local_state: MemoryState, local_log: CommandLog) -> MemoryState:
        local_log = jax.tree.map(lambda a: a[0], local_log)  # drop shard dim
        out = machine.replay(_to_local(local_state), local_log,
                             ef_construction=ef_construction)
        return _to_shardview(out)

    return _replay(state, routed_log)


def shard_live_counts(state: MemoryState, n_shards: int) -> np.ndarray:
    """Per-shard live-row counts of a sharded-layout state, derived from the
    ``valid`` mask (cross-checkable against the per-shard ``count`` scalars)
    — the shard-balance diagnostic for the serve engine's sequential id
    allocation, and a planner-facing host fact."""
    return np.asarray(state.valid).reshape(n_shards, -1).sum(axis=1)


def shard_slice(state: MemoryState, s: int, n_shards: int) -> MemoryState:
    """Shard ``s`` of a shard-major sharded-layout state as a plain
    single-kernel MemoryState (host-side view; inverse of ``merge_shards``)."""
    cap = state.capacity // n_shards
    lo, hi = s * cap, (s + 1) * cap
    return dataclasses.replace(
        state,
        vectors=state.vectors[lo:hi], ids=state.ids[lo:hi],
        valid=state.valid[lo:hi], links=state.links[lo:hi],
        meta=state.meta[lo:hi],
        hnsw_neighbors=state.hnsw_neighbors[:, lo:hi],
        hnsw_levels=state.hnsw_levels[lo:hi],
        hnsw_entry=state.hnsw_entry[s], cursor=state.cursor[s],
        count=state.count[s], version=state.version[s],
    )


def merge_shards(shards) -> MemoryState:
    """Reassemble per-shard kernel states into the sharded layout (row
    arrays concatenated shard-major, per-shard scalars stacked)."""
    def cat(field):
        return jnp.concatenate([getattr(sh, field) for sh in shards], axis=0)

    def stack_scalar(field):
        return jnp.stack([getattr(sh, field) for sh in shards])

    return dataclasses.replace(
        shards[0],
        vectors=cat("vectors"), ids=cat("ids"), valid=cat("valid"),
        links=cat("links"), meta=cat("meta"),
        hnsw_neighbors=jnp.concatenate(
            [sh.hnsw_neighbors for sh in shards], axis=1),
        hnsw_levels=cat("hnsw_levels"),
        hnsw_entry=stack_scalar("hnsw_entry"), cursor=stack_scalar("cursor"),
        count=stack_scalar("count"), version=stack_scalar("version"),
    )


def distributed_bulk_apply(mesh: Mesh, axis: str, state: MemoryState,
                           routed_log: CommandLog, *, ef_construction: int = 32
                           ) -> MemoryState:
    """Apply routed per-shard logs through ``machine.bulk_apply``.

    Each shard is its own little Valori kernel, so bulk-apply runs per shard
    on its local slice — the segmentation driver is host-side, which is
    exactly where the routing table already lives. The result is
    hash-identical to ``distributed_replay`` on the same routed log, shard
    by shard (the per-shard equivalence is machine.bulk_apply's contract);
    the NOP padding ``route_commands`` adds folds into a version bump.

    Trade-off vs ``distributed_replay``: shards are processed sequentially
    on the host and the result is materialized unsharded (≈1 extra arena
    copy on the default device) before the final re-shard — the ingest win
    is per-shard vectorization, not cross-shard parallelism. For arenas too
    big to stage on one host, use ``distributed_replay``; for the mesh-free
    layout, ``shard_wal.apply_routed_device`` now runs the whole routed
    apply as one vmapped device scan with no per-shard host loop
    (DESIGN.md §11).
    """
    n_shards = mesh.shape[axis]

    shards = []
    for s in range(n_shards):
        local = shard_slice(state, s, n_shards)
        local_log = jax.tree.map(lambda a, s=s: a[s], routed_log)
        shards.append(machine.bulk_apply(local, local_log,
                                         ef_construction=ef_construction))

    out = merge_shards(shards)
    specs = state_specs(axis, state.contract_name)
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), out, specs)


def distributed_hnsw_search(mesh: Mesh, axis: str, state: MemoryState,
                            queries_raw: jax.Array, k: int, *, ef: int = 64,
                            query_axis: str | None = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """ANN across shards: each shard runs its deterministic HNSW graph
    (vmapped beam search), candidates merge with the same exact integer sort
    as the flat path — the IVF-style latency configuration of the paper's
    index at pod scale. Per-shard graphs are built incrementally by
    distributed_replay, so replaying the same routed log on any mesh gives
    identical graphs and hence identical results."""
    specs = state_specs(axis, state.contract_name)
    qspec = P(query_axis, None)
    out_spec = P(query_axis, None)

    from repro.core import query as query_lib  # lazy: query imports us lazily

    @partial(compat.shard_map, mesh=mesh, in_specs=(specs, qspec),
             out_specs=(out_spec, out_spec), check_vma=False)
    def _search(local_state: MemoryState, q: jax.Array):
        local = _to_local(local_state)
        ids, dists, _ = query_lib.batched_hnsw_search(local, q, k, ef=ef)
        all_ids = jax.lax.all_gather(ids, axis)       # [n_shards, nq, k]
        all_d = jax.lax.all_gather(dists, axis)
        nq = q.shape[0]
        flat_ids = jnp.moveaxis(all_ids, 0, 1).reshape(nq, -1)
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(nq, -1)
        d_out, i_out = search.merge_candidates(flat_d, flat_ids, k)
        return i_out, d_out

    return _search(state, queries_raw)


# --------------------------------------------------------------------------- #
# per-shard snapshots under one merged manifest (DESIGN.md §5)
# --------------------------------------------------------------------------- #

SHARDED_MAGIC = b"VLRS"
SHARDED_FORMAT = 1


def snapshot_sharded(state: MemoryState, n_shards: int, store, *,
                     chunk_size: int | None = None) -> bytes:
    """Write one v2 snapshot per shard into ``store`` (a
    ``snapshot.ChunkStore``) and return a merged manifest whose combined
    hash is the hash of the whole sharded-layout state — the same value a
    single host computes over the assembled arenas, so a pod and a
    single-kernel holder of identical content agree on one number.

    Shards share the chunk store: identical chunks (e.g. untouched empty
    arena regions) are stored once across all shards."""
    import struct

    from repro.core import hashing as hashing_lib
    from repro.core import snapshot as snapshot_lib

    chunk_size = chunk_size or snapshot_lib.DEFAULT_CHUNK_SIZE
    parts = []
    for s in range(n_shards):
        manifest, _ = snapshot_lib.snapshot_v2(
            shard_slice(state, s, n_shards), store, chunk_size=chunk_size)
        parts.append(manifest)
    combined = hashing_lib.hash_pytree(state)
    out = [SHARDED_MAGIC, struct.pack("<II", SHARDED_FORMAT, n_shards),
           struct.pack("<Q", combined)]
    for m in parts:
        out.append(struct.pack("<Q", len(m)))
        out.append(m)
    return b"".join(out)


def restore_sharded(data: bytes, store) -> Tuple[MemoryState, int]:
    """Restore a merged manifest: per-shard v2 restores, reassembled with
    ``merge_shards``; verifies the combined hash. Returns (state, hash)."""
    import struct

    from repro.core import hashing as hashing_lib
    from repro.core import snapshot as snapshot_lib

    if data[:4] != SHARDED_MAGIC:
        raise ValueError("not a sharded Valori snapshot manifest")
    fmt, n_shards = struct.unpack_from("<II", data, 4)
    if fmt != SHARDED_FORMAT:
        raise ValueError(f"unsupported sharded manifest format {fmt}")
    (stored,) = struct.unpack_from("<Q", data, 12)
    off = 20
    shards = []
    for _ in range(n_shards):
        (n,) = struct.unpack_from("<Q", data, off)
        off += 8
        shard, _ = snapshot_lib.restore_v2(data[off:off + n], store)
        off += n
        shards.append(shard)
    state = merge_shards(shards)
    actual = hashing_lib.hash_pytree(state)
    if actual != stored:
        raise ValueError(
            f"sharded snapshot combined-hash mismatch: stored {stored:#x}, "
            f"got {actual:#x}")
    return state, actual


def distributed_search(mesh: Mesh, axis: str, state: MemoryState,
                       queries_raw: jax.Array, k: int, *,
                       metric: str = search.METRIC_L2, use_kernel: bool = False,
                       query_axis: str | None = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Exact k-NN across all shards: local top-k, all-gather, sort-merge.

    Integer-only combine ⇒ results (ids, scores, tie order) are independent
    of shard count and identical to the single-kernel answer.
    """
    specs = state_specs(axis, state.contract_name)
    qspec = P(query_axis, None)
    out_spec = P(query_axis, None)

    @partial(compat.shard_map, mesh=mesh, in_specs=(specs, qspec),
             out_specs=(out_spec, out_spec), check_vma=False)
    def _search(local_state: MemoryState, q: jax.Array):
        ids, scores = search.exact_search(
            _to_local(local_state), q, k, metric=metric, use_kernel=use_kernel
        )
        all_ids = jax.lax.all_gather(ids, axis)       # [n_shards, nq, k]
        all_scores = jax.lax.all_gather(scores, axis)
        nq = q.shape[0]
        flat_ids = jnp.moveaxis(all_ids, 0, 1).reshape(nq, -1)
        flat_scores = jnp.moveaxis(all_scores, 0, 1).reshape(nq, -1)
        s_out, i_out = search.merge_candidates(flat_scores, flat_ids, k)
        return i_out, s_out

    return _search(state, queries_raw)
