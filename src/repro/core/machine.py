"""The pure state-machine transition function F (paper §3.1, §5.2).

``S_{t+1} = F(S_t, C_t)``: a single jittable function dispatching on opcode
via ``lax.switch``. ``replay`` folds a whole command log with ``lax.scan`` —
the paper's replayability guarantee is literally this scan. Every branch
returns a full next-state so the switch is shape-stable.

Semantics (all deterministic, total — invalid commands are no-ops that still
advance logical time, so a log replays identically even past rejections):

* INSERT(id, vec): upsert. Existing id → overwrite row in place (graph edges
  and HNSW links for that slot are rebuilt from the new vector lazily via the
  next index touch; vector content is what distance math reads). New id →
  lowest free slot, claimed *clean*: the row's meta words and user links are
  reset, so a fresh id never inherits a tombstoned predecessor's metadata —
  slot-reuse order is layout-dependent, and leaked meta would break the
  cross-layout ``content_hash`` contract (DESIGN.md §7). HNSW incremental
  insert runs for new rows.
* DELETE(id): clear valid bit (tombstone). Slot becomes reusable; HNSW keeps
  the tombstoned node's edges so it stays a traversal waypoint (the query
  beam traverses tombstones via ``dead_ok`` and drops them from the answer,
  never the frontier), and when the delete kills the current entry point,
  ``hnsw.ensure_live_entry`` promotes the deterministic replacement — the
  live node with the greatest raw (id-derived) level, lowest id first
  (DESIGN.md §11) — so every layout repairs to the same entry.
* LINK(a, b) / UNLINK(a, b): typed user edges in ``links`` (first free /
  matching entry). Distinct from HNSW adjacency.
* SET_META(id, slot, value): write a metadata word.
"""
from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw
from repro.core.commands import (DELETE, INSERT, LINK, NOP, NUM_OPCODES,
                                 SET_META, UNLINK, CommandLog)
from repro.core.state import MemoryState, slot_of_id


def _bump(state: MemoryState) -> MemoryState:
    return dataclasses.replace(state, version=state.version + 1)


# --------------------------------------------------------------------------- #
# opcode handlers — each: (state, rec) -> state
# --------------------------------------------------------------------------- #


def _op_nop(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    return state


def _op_insert(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    ext_id = rec.arg0
    existing = slot_of_id(state, ext_id)
    has_existing = existing >= 0
    free_mask = ~state.valid
    any_free = jnp.any(free_mask)
    free_slot = jnp.argmax(free_mask).astype(jnp.int32)  # lowest free slot
    slot = jnp.where(has_existing, existing, free_slot)
    can_write = has_existing | any_free  # full arena rejects new ids

    def write(state: MemoryState) -> MemoryState:
        vectors = state.vectors.at[slot].set(rec.vec)
        ids = state.ids.at[slot].set(ext_id)
        valid = state.valid.at[slot].set(True)
        count = state.count + jnp.where(has_existing, 0, 1).astype(jnp.int32)
        cursor = jnp.maximum(state.cursor, slot + 1)
        # a fresh id claims a CLEAN row: meta/links left by a tombstoned
        # predecessor must not leak (slot reuse is layout-dependent; leaked
        # meta breaks the cross-layout content_hash). Upserts keep theirs.
        meta = state.meta.at[slot].set(
            jnp.where(has_existing, state.meta[slot], 0))
        links = state.links.at[slot].set(
            jnp.where(has_existing, state.links[slot], -1))
        new_state = dataclasses.replace(
            state, vectors=vectors, ids=ids, valid=valid,
            count=count, cursor=cursor, meta=meta, links=links,
        )
        # fresh rows enter the HNSW graph; overwrites keep their links
        return jax.lax.cond(
            has_existing,
            lambda s: s,
            lambda s: hnsw.hnsw_insert(s, slot, ef_construction=ef_construction),
            new_state,
        )

    return jax.lax.cond(can_write, write, lambda s: s, state)


def _op_delete(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    slot = slot_of_id(state, rec.arg0)
    found = slot >= 0
    safe = jnp.clip(slot, 0, state.capacity - 1)
    valid = state.valid.at[safe].set(jnp.where(found, False, state.valid[safe]))
    ids = state.ids.at[safe].set(jnp.where(found, jnp.int64(-1), state.ids[safe]))
    count = state.count - jnp.where(found, 1, 0).astype(jnp.int32)
    return hnsw.ensure_live_entry(
        dataclasses.replace(state, valid=valid, ids=ids, count=count))


def _op_link(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    a = slot_of_id(state, rec.arg0)
    b = slot_of_id(state, rec.arg1)
    ok = (a >= 0) & (b >= 0)
    sa = jnp.clip(a, 0, state.capacity - 1)
    row = state.links[sa]  # [max_links]
    already = jnp.any(row == b)
    free = row < 0
    has_free = jnp.any(free)
    pos = jnp.argmax(free)
    do = ok & has_free & ~already
    new_row = jnp.where(
        do, row.at[pos].set(b.astype(jnp.int32)), row
    )
    return dataclasses.replace(state, links=state.links.at[sa].set(new_row))


def _op_unlink(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    a = slot_of_id(state, rec.arg0)
    b = slot_of_id(state, rec.arg1)
    ok = (a >= 0) & (b >= 0)
    sa = jnp.clip(a, 0, state.capacity - 1)
    row = state.links[sa]
    new_row = jnp.where(ok & (row == b), jnp.int32(-1), row)
    return dataclasses.replace(state, links=state.links.at[sa].set(new_row))


def _op_set_meta(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    slot = slot_of_id(state, rec.arg0)
    ok = slot >= 0
    safe = jnp.clip(slot, 0, state.capacity - 1)
    mslot = jnp.clip(rec.arg1, 0, state.meta.shape[1] - 1).astype(jnp.int32)
    cur = state.meta[safe, mslot]
    val = jnp.where(ok, rec.arg2, cur)
    return dataclasses.replace(state, meta=state.meta.at[safe, mslot].set(val))


_HANDLERS = [_op_nop, _op_insert, _op_delete, _op_link, _op_unlink, _op_set_meta]


# --------------------------------------------------------------------------- #
# F and replay
# --------------------------------------------------------------------------- #


def apply_command(state: MemoryState, rec: CommandLog,
                  *, ef_construction: int = 32) -> MemoryState:
    """S_{t+1} = F(S_t, C_t). Total function; always advances ``version``."""
    op = jnp.clip(rec.opcode, 0, NUM_OPCODES - 1)
    branches = [partial(h, ef_construction=ef_construction) for h in _HANDLERS]
    state = jax.lax.switch(op, branches, state, rec)
    return _bump(state)


@partial(jax.jit, static_argnames=("ef_construction",))
def replay(state: MemoryState, log: CommandLog,
           *, ef_construction: int = 32) -> MemoryState:
    """Apply a whole log: the paper's Apply(S_0, {C_i}). One lax.scan.

    Invariant: a pure function of (state, log) — the same inputs produce a
    bit-identical final state (same ``hashing.hash_pytree``) on any
    platform, in any chunking (``apply_chunked``), and under
    ``bulk_apply``'s batched form. This is the replayability guarantee
    every durability and audit contract reduces to."""

    def step(s, rec):
        return apply_command(s, rec, ef_construction=ef_construction), None

    final, _ = jax.lax.scan(step, state, log)
    return final


def apply_chunked(state: MemoryState, log: CommandLog, chunk: int,
                  *, ef_construction: int = 32) -> MemoryState:
    """Replay in host-driven chunks (used by tests to prove that batch
    boundaries cannot affect the final state)."""
    n = len(log)
    for start in range(0, n, chunk):
        state = replay(state, log.slice(start, min(start + chunk, n)),
                       ef_construction=ef_construction)
    return state


# --------------------------------------------------------------------------- #
# bulk apply: the vectorized ingestion fast path (DESIGN.md §3)
# --------------------------------------------------------------------------- #
#
# ``bulk_apply(S, log) == replay(S, log)`` bit-for-bit (hash-identical), but
# applies the log in batched segments instead of one lax.scan step per
# command. The host segments the log by opcode; each segment runs a batched
# kernel:
#
#   * clean INSERT runs (fresh, distinct ids): slots are allocated with ONE
#     prefix-scan over the free mask (the i-th fresh insert takes the i-th
#     lowest free slot — exactly the sequential "lowest free slot, in log
#     order" semantics), vectors/ids/valid are written with one batched
#     scatter, and only the HNSW graph construction remains a loop — over
#     fresh rows only, with inactive levels cond-skipped (hnsw_insert
#     ``fast=True``).
#   * DELETE / SET_META runs: slot resolution is one vmapped probe against
#     the segment-entry state plus a host-computed first/last-occurrence
#     mask, then one batched scatter.
#   * everything else (NOP-padded sequential segments: LINK/UNLINK order
#     within a row is semantic, and hazardous INSERTs — upserts or
#     duplicate ids — genuinely depend on interleaving): a plain scan of F,
#     which is the definitional semantics.
#
# Why pre-scattering whole INSERT runs cannot change the HNSW graph: every
# slot the construction searches, scores, or links is reachable only through
# the entry point and neighbor arrays, which mention exactly the rows already
# inserted. Rows scattered early but not yet graph-inserted have no incident
# edges, so no search can observe them — the graph build sees precisely the
# prefix state sequential replay would have shown it.


def _pad_log(log: CommandLog, target: int) -> CommandLog:
    """NOP-pad a sub-log to ``target`` records (pow2 buckets keep the number
    of distinct jit shapes logarithmic)."""
    n = len(log)
    if n == target:
        return log
    pad = target - n
    return CommandLog(
        opcode=jnp.concatenate([log.opcode, jnp.zeros((pad,), jnp.int32)]),
        arg0=jnp.concatenate([log.arg0, jnp.zeros((pad,), jnp.int64)]),
        arg1=jnp.concatenate([log.arg1, jnp.zeros((pad,), jnp.int64)]),
        arg2=jnp.concatenate([log.arg2, jnp.zeros((pad,), jnp.int64)]),
        vec=jnp.concatenate(
            [log.vec, jnp.zeros((pad, log.dim), log.vec.dtype)]),
    )


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


@partial(jax.jit, static_argnames=("ef_construction",))
def _apply_insert_segment(state: MemoryState, log: CommandLog,
                          n_real: jax.Array, *, ef_construction: int
                          ) -> MemoryState:
    """Clean INSERT run: all ids fresh and distinct (host-verified).

    Slot allocation is one prefix scan: command i takes the i-th lowest free
    slot; commands past the free-slot supply are rejected, exactly like the
    sequential path."""
    m = len(log)
    cap = state.capacity
    free_mask = ~state.valid
    num_free = jnp.sum(free_mask).astype(jnp.int32)
    free_idx = jnp.nonzero(free_mask, size=m, fill_value=cap)[0].astype(jnp.int32)
    idx = jnp.arange(m, dtype=jnp.int32)
    present = idx < n_real                 # NOP padding guard
    accepted = present & (idx < num_free)  # full arena rejects the tail
    slots = jnp.where(accepted, free_idx, jnp.int32(cap))  # cap ⇒ dropped

    # no unique_indices promise: the rejected/padded tail repeats the `cap`
    # sentinel, and a false uniqueness promise is undefined behavior even
    # though those writes are dropped
    vectors = state.vectors.at[slots].set(
        log.vec, mode="drop", indices_are_sorted=True)
    ids = state.ids.at[slots].set(
        log.arg0, mode="drop", indices_are_sorted=True)
    valid = state.valid.at[slots].set(
        True, mode="drop", indices_are_sorted=True)
    # every id in a clean run is fresh: claimed rows start with zero meta
    # and no user links (see _op_insert — tombstone leftovers must not leak)
    meta = state.meta.at[slots].set(
        jnp.zeros((m, state.meta.shape[1]), state.meta.dtype),
        mode="drop", indices_are_sorted=True)
    links = state.links.at[slots].set(
        jnp.full((m, state.links.shape[1]), -1, state.links.dtype),
        mode="drop", indices_are_sorted=True)
    count = state.count + jnp.sum(accepted).astype(jnp.int32)
    cursor = jnp.maximum(
        state.cursor, jnp.max(jnp.where(accepted, slots + 1, 0)))
    state = dataclasses.replace(
        state, vectors=vectors, ids=ids, valid=valid, count=count,
        cursor=cursor, meta=meta, links=links,
        version=state.version + n_real,
    )

    # graph construction stays ordered over the fresh rows only; rejected and
    # padded entries carry slot == cap and skip at runtime. The scan carries
    # just the graph arrays — vectors/ids/valid are loop invariants, so they
    # stay out of the carried (and cond-copied) state.
    def body(carry, slot):
        def insert(c):
            nbrs, lvls, ent = c
            st = dataclasses.replace(
                state, hnsw_neighbors=nbrs, hnsw_levels=lvls, hnsw_entry=ent)
            out = hnsw.hnsw_insert(
                st, slot, ef_construction=ef_construction, fast=True)
            return out.hnsw_neighbors, out.hnsw_levels, out.hnsw_entry

        return jax.lax.cond(slot < cap, insert, lambda c: c, carry), None

    carry0 = (state.hnsw_neighbors, state.hnsw_levels, state.hnsw_entry)
    (nbrs, lvls, ent), _ = jax.lax.scan(body, carry0, slots)
    return dataclasses.replace(
        state, hnsw_neighbors=nbrs, hnsw_levels=lvls, hnsw_entry=ent)


def _probe_slots(state: MemoryState, arg0: jax.Array):
    """Batched ``slot_of_id``: (found[n], slots[n]) against one state — the
    shared slot-resolution core of the delete and meta kernels."""
    match = (state.ids[None, :] == arg0[:, None]) & state.valid[None, :]
    return jnp.any(match, axis=1), jnp.argmax(match, axis=1).astype(jnp.int32)


@jax.jit
def _apply_delete_segment(state: MemoryState, arg0: jax.Array,
                          first_occ: jax.Array, n_real: jax.Array
                          ) -> MemoryState:
    """DELETE run: one vmapped id→slot probe + one batched tombstone scatter.
    ``first_occ`` (host-computed) keeps only the first delete of each id —
    later duplicates are sequential no-ops."""
    cap = state.capacity
    idx = jnp.arange(arg0.shape[0])
    found, slots = _probe_slots(state, arg0)
    do = found & first_occ & (idx < n_real)
    tgt = jnp.where(do, slots, cap)
    valid = state.valid.at[tgt].set(False, mode="drop")
    ids = state.ids.at[tgt].set(jnp.int64(-1), mode="drop")
    count = state.count - jnp.sum(do).astype(jnp.int32)
    # One entry repair at batch end == per-command repair under replay: in a
    # pure-DELETE run, each sequential repair picks the max-(raw level, -id)
    # node over a superset of the batch's final live set, and the final
    # repair keys on that final set alone — so the last choice is the same
    # either way (and both land on -1 when nothing survives).
    return hnsw.ensure_live_entry(dataclasses.replace(
        state, valid=valid, ids=ids, count=count,
        version=state.version + n_real))


@jax.jit
def _apply_meta_segment(state: MemoryState, arg0: jax.Array, arg1: jax.Array,
                        arg2: jax.Array, last_occ: jax.Array,
                        n_real: jax.Array) -> MemoryState:
    """SET_META run: one probe + one scatter. ``last_occ`` (host-computed on
    the clipped (id, meta-slot) key) realizes last-write-wins."""
    cap = state.capacity
    idx = jnp.arange(arg0.shape[0])
    found, slots = _probe_slots(state, arg0)
    mslot = jnp.clip(arg1, 0, state.meta.shape[1] - 1).astype(jnp.int32)
    do = found & last_occ & (idx < n_real)
    row = jnp.where(do, slots, cap)
    meta = state.meta.at[row, mslot].set(arg2, mode="drop")
    return dataclasses.replace(
        state, meta=meta, version=state.version + n_real)


@partial(jax.jit, static_argnames=("ef_construction",))
def _apply_seq_segment(state: MemoryState, log: CommandLog, n_real: jax.Array,
                       *, ef_construction: int) -> MemoryState:
    """Order-sensitive remainder (LINK/UNLINK runs, hazardous INSERTs): the
    definitional scan of F, minus the per-command version bump — NOP padding
    must not advance logical time."""
    def step(s, rec):
        op = jnp.clip(rec.opcode, 0, NUM_OPCODES - 1)
        branches = [partial(h, ef_construction=ef_construction)
                    for h in _HANDLERS]
        return jax.lax.switch(op, branches, s, rec), None

    out, _ = jax.lax.scan(step, state, log)
    return dataclasses.replace(out, version=state.version + n_real)


_BATCH_CHUNK = 512  # caps the [run, capacity] probe matrix in delete/meta


class _HostAllocator:
    """Exact host mirror of F's slot allocator, driven during segmentation.

    Tracks the live id→slot map, the free-slot min-heap (lowest-slot-first,
    like the device argmax over the free mask) and per-slot graph virginity.
    A slot that ever held a graph node keeps its stale inbound HNSW edges
    after deletion (soft delete), so pre-scattering a whole INSERT run would
    make the reused row visible to earlier searches in the run — sequential
    replay would still see it invalid. Fresh inserts landing on such slots
    are therefore hazards and take the sequential path."""

    def __init__(self, state: MemoryState):
        ids_h = np.asarray(state.ids)
        valid_h = np.asarray(state.valid)
        levels_h = np.asarray(state.hnsw_levels)
        self.id2slot = {int(i): int(s)
                        for s, i in enumerate(ids_h) if valid_h[s]}
        self.free = [int(s) for s in np.nonzero(~valid_h)[0]]  # already sorted
        self.virgin = (levels_h < 0)

    def next_slot_virgin(self) -> bool:
        return (not self.free) or bool(self.virgin[self.free[0]])

    def insert(self, ext_id: int) -> None:
        if ext_id in self.id2slot:     # upsert: no allocation
            return
        if self.free:
            slot = heapq.heappop(self.free)
            self.id2slot[ext_id] = slot
            self.virgin[slot] = False
        # else: arena full, rejected

    def delete(self, ext_id: int) -> None:
        slot = self.id2slot.pop(ext_id, None)
        if slot is not None:
            heapq.heappush(self.free, slot)


def _segment_log(opcode, arg0, alloc: _HostAllocator):
    """Host-side pass: split the log into batched-kernel segments while
    simulating exactly the allocation bookkeeping F would perform, so
    hazards are detected wherever sequential replay would behave differently
    from a batch."""
    segments = []  # (kind, start, stop, aux)
    n = len(opcode)
    i = 0
    while i < n:
        op = int(opcode[i])
        if op == NOP:
            j = i
            while j < n and opcode[j] == NOP:
                j += 1
            segments.append(("nop", i, j, None))
        elif op == INSERT:
            j = i
            seg_ids = set()
            while j < n and opcode[j] == INSERT:
                a = int(arg0[j])
                if a in alloc.id2slot or a in seg_ids:
                    break  # upsert or duplicate ⇒ order matters ⇒ hazard
                if not alloc.next_slot_virgin():
                    break  # reused slot has stale inbound edges ⇒ hazard
                alloc.insert(a)
                seg_ids.add(a)
                j += 1
            if j > i:  # clean run
                segments.append(("insert", i, j, None))
            else:      # hazardous single insert → sequential segment
                alloc.insert(int(arg0[i]))
                j = i + 1
                segments.append(("seq", i, j, None))
        elif op == DELETE:
            j = min(i + _BATCH_CHUNK, n)
            k = i
            seen = set()
            first_occ = []
            while k < j and opcode[k] == DELETE:
                a = int(arg0[k])
                first_occ.append(a not in seen)
                seen.add(a)
                alloc.delete(a)
                k += 1
            segments.append(("delete", i, k, np.asarray(first_occ, bool)))
            j = k
        elif op == SET_META:
            j = min(i + _BATCH_CHUNK, n)
            k = i
            while k < j and opcode[k] == op:
                k += 1
            segments.append(("run", i, k, op))
            j = k
        else:  # LINK / UNLINK: order-sensitive ⇒ sequential kernel
            k = i
            while k < n and opcode[k] == op:
                k += 1
            segments.append(("seq", i, k, None))
            j = k
        i = j

    # coalesce adjacent sequential segments: a reuse-heavy log (every fresh
    # insert landing on a non-virgin slot) otherwise degrades to one jit
    # dispatch per command; merged, it is a single padded scan like replay's
    merged = []
    for seg in segments:
        if merged and seg[0] == "seq" and merged[-1][0] == "seq":
            merged[-1] = ("seq", merged[-1][1], seg[2], None)
        else:
            merged.append(seg)
    return merged


def bulk_apply(state: MemoryState, log: CommandLog,
               *, ef_construction: int = 32) -> MemoryState:
    """Apply a whole command log in batched form.

    Bit-identical to ``replay(state, log)`` — same final hash under
    ``hashing.hash_pytree`` — including upserts, tombstone reuse, full-arena
    rejections and ``version`` accounting (tests/test_bulk_apply.py), but
    with the write path vectorized as described in DESIGN.md §3."""
    n = len(log)
    if n == 0:
        return state

    opcode = np.asarray(log.opcode)
    arg0 = np.asarray(log.arg0)
    arg1 = np.asarray(log.arg1)
    arg2 = np.asarray(log.arg2)

    for kind, a, b, aux in _segment_log(opcode, arg0, _HostAllocator(state)):
        m = b - a
        n_real = jnp.int32(m)
        if kind == "nop":
            state = dataclasses.replace(state, version=state.version + m)
        elif kind == "insert":
            sub = _pad_log(log.slice(a, b), _pow2(m))
            state = _apply_insert_segment(state, sub, n_real,
                                          ef_construction=ef_construction)
        elif kind == "delete":
            width = _pow2(m)
            a0 = np.zeros((width,), np.int64)
            a0[:m] = arg0[a:b]
            occ = np.zeros((width,), bool)
            occ[:m] = aux
            state = _apply_delete_segment(state, jnp.asarray(a0),
                                          jnp.asarray(occ), n_real)
        elif kind == "run" and aux == SET_META:
            width = _pow2(m)
            a0 = np.zeros((width,), np.int64)
            a1 = np.zeros((width,), np.int64)
            a2 = np.zeros((width,), np.int64)
            a0[:m] = arg0[a:b]
            a1[:m] = arg1[a:b]
            a2[:m] = arg2[a:b]
            mslots = np.clip(a1[:m], 0, state.meta.shape[1] - 1)
            occ = np.zeros((width,), bool)
            seen = set()
            for t in range(m - 1, -1, -1):  # last write per (id, slot) wins
                key = (int(a0[t]), int(mslots[t]))
                occ[t] = key not in seen
                seen.add(key)
            state = _apply_meta_segment(state, jnp.asarray(a0),
                                        jnp.asarray(a1), jnp.asarray(a2),
                                        jnp.asarray(occ), n_real)
        else:  # "seq" and LINK/UNLINK runs
            sub = _pad_log(log.slice(a, b), _pow2(m))
            state = _apply_seq_segment(state, sub, n_real,
                                       ef_construction=ef_construction)
    return state
