"""The pure state-machine transition function F (paper §3.1, §5.2).

``S_{t+1} = F(S_t, C_t)``: a single jittable function dispatching on opcode
via ``lax.switch``. ``replay`` folds a whole command log with ``lax.scan`` —
the paper's replayability guarantee is literally this scan. Every branch
returns a full next-state so the switch is shape-stable.

Semantics (all deterministic, total — invalid commands are no-ops that still
advance logical time, so a log replays identically even past rejections):

* INSERT(id, vec): upsert. Existing id → overwrite row in place (graph edges
  and HNSW links for that slot are rebuilt from the new vector lazily via the
  next index touch; vector content is what distance math reads). New id →
  lowest free slot; HNSW incremental insert runs for new rows.
* DELETE(id): clear valid bit (tombstone). Slot becomes reusable; HNSW keeps
  the tombstoned node as a traversal waypoint (classic soft-delete) but it
  can never be returned (search masks on ``valid``).
* LINK(a, b) / UNLINK(a, b): typed user edges in ``links`` (first free /
  matching entry). Distinct from HNSW adjacency.
* SET_META(id, slot, value): write a metadata word.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw
from repro.core.commands import (DELETE, INSERT, LINK, NOP, NUM_OPCODES,
                                 SET_META, UNLINK, CommandLog)
from repro.core.state import MemoryState, slot_of_id


def _bump(state: MemoryState) -> MemoryState:
    return dataclasses.replace(state, version=state.version + 1)


# --------------------------------------------------------------------------- #
# opcode handlers — each: (state, rec) -> state
# --------------------------------------------------------------------------- #


def _op_nop(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    return state


def _op_insert(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    ext_id = rec.arg0
    existing = slot_of_id(state, ext_id)
    has_existing = existing >= 0
    free_mask = ~state.valid
    any_free = jnp.any(free_mask)
    free_slot = jnp.argmax(free_mask).astype(jnp.int32)  # lowest free slot
    slot = jnp.where(has_existing, existing, free_slot)
    can_write = has_existing | any_free  # full arena rejects new ids

    def write(state: MemoryState) -> MemoryState:
        vectors = state.vectors.at[slot].set(rec.vec)
        ids = state.ids.at[slot].set(ext_id)
        valid = state.valid.at[slot].set(True)
        count = state.count + jnp.where(has_existing, 0, 1).astype(jnp.int32)
        cursor = jnp.maximum(state.cursor, slot + 1)
        new_state = dataclasses.replace(
            state, vectors=vectors, ids=ids, valid=valid,
            count=count, cursor=cursor,
        )
        # fresh rows enter the HNSW graph; overwrites keep their links
        return jax.lax.cond(
            has_existing,
            lambda s: s,
            lambda s: hnsw.hnsw_insert(s, slot, ef_construction=ef_construction),
            new_state,
        )

    return jax.lax.cond(can_write, write, lambda s: s, state)


def _op_delete(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    slot = slot_of_id(state, rec.arg0)
    found = slot >= 0
    safe = jnp.clip(slot, 0, state.capacity - 1)
    valid = state.valid.at[safe].set(jnp.where(found, False, state.valid[safe]))
    ids = state.ids.at[safe].set(jnp.where(found, jnp.int64(-1), state.ids[safe]))
    count = state.count - jnp.where(found, 1, 0).astype(jnp.int32)
    return dataclasses.replace(state, valid=valid, ids=ids, count=count)


def _op_link(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    a = slot_of_id(state, rec.arg0)
    b = slot_of_id(state, rec.arg1)
    ok = (a >= 0) & (b >= 0)
    sa = jnp.clip(a, 0, state.capacity - 1)
    row = state.links[sa]  # [max_links]
    already = jnp.any(row == b)
    free = row < 0
    has_free = jnp.any(free)
    pos = jnp.argmax(free)
    do = ok & has_free & ~already
    new_row = jnp.where(
        do, row.at[pos].set(b.astype(jnp.int32)), row
    )
    return dataclasses.replace(state, links=state.links.at[sa].set(new_row))


def _op_unlink(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    a = slot_of_id(state, rec.arg0)
    b = slot_of_id(state, rec.arg1)
    ok = (a >= 0) & (b >= 0)
    sa = jnp.clip(a, 0, state.capacity - 1)
    row = state.links[sa]
    new_row = jnp.where(ok & (row == b), jnp.int32(-1), row)
    return dataclasses.replace(state, links=state.links.at[sa].set(new_row))


def _op_set_meta(state: MemoryState, rec: CommandLog, ef_construction: int) -> MemoryState:
    slot = slot_of_id(state, rec.arg0)
    ok = slot >= 0
    safe = jnp.clip(slot, 0, state.capacity - 1)
    mslot = jnp.clip(rec.arg1, 0, state.meta.shape[1] - 1).astype(jnp.int32)
    cur = state.meta[safe, mslot]
    val = jnp.where(ok, rec.arg2, cur)
    return dataclasses.replace(state, meta=state.meta.at[safe, mslot].set(val))


_HANDLERS = [_op_nop, _op_insert, _op_delete, _op_link, _op_unlink, _op_set_meta]


# --------------------------------------------------------------------------- #
# F and replay
# --------------------------------------------------------------------------- #


def apply_command(state: MemoryState, rec: CommandLog,
                  *, ef_construction: int = 32) -> MemoryState:
    """S_{t+1} = F(S_t, C_t). Total function; always advances ``version``."""
    op = jnp.clip(rec.opcode, 0, NUM_OPCODES - 1)
    branches = [partial(h, ef_construction=ef_construction) for h in _HANDLERS]
    state = jax.lax.switch(op, branches, state, rec)
    return _bump(state)


@partial(jax.jit, static_argnames=("ef_construction",))
def replay(state: MemoryState, log: CommandLog,
           *, ef_construction: int = 32) -> MemoryState:
    """Apply a whole log: the paper's Apply(S_0, {C_i}). One lax.scan."""

    def step(s, rec):
        return apply_command(s, rec, ef_construction=ef_construction), None

    final, _ = jax.lax.scan(step, state, log)
    return final


def apply_chunked(state: MemoryState, log: CommandLog, chunk: int,
                  *, ef_construction: int = 32) -> MemoryState:
    """Replay in host-driven chunks (used by tests to prove that batch
    boundaries cannot affect the final state)."""
    n = len(log)
    for start in range(0, n, chunk):
        state = replay(state, log.slice(start, min(start + chunk, n)),
                       ef_construction=ef_construction)
    return state
