"""Deterministic Q-format gradient all-reduce (beyond-paper, DESIGN.md §2).

The paper's insight — integer arithmetic makes reductions order-invariant —
applied to cross-pod gradient sync:

  1. consistent scale: per-tensor max|g| is shared via lax.pmax (float max is
     order-invariant, so this is deterministic);
  2. quantize to a narrow Q-contract (int16 wire at Q2.13 by default) with
     round-half-away-from-zero — the same boundary as core.boundary;
  3. integer psum over the pod axis — exact, associative ⇒ bitwise identical
     regardless of ring order/topology;
  4. dequantize + average; optional error feedback carries the quantization
     residual into the next step (residual update is also deterministic).

Wire cost: int16 vs f32 = 2x compression on the cross-pod (DCI) hop, and the
training step becomes replayable across pod counts — the paper's replay
guarantee extended to distributed optimization.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.contracts import PrecisionContract, get_contract


def _quantize(g: jax.Array, scale: jax.Array, c: PrecisionContract) -> jax.Array:
    """g/scale ∈ [-1, 1] → raw fixed point (saturating, round-half-away)."""
    x = g.astype(jnp.float32) / jnp.maximum(scale, 1e-30)
    s = x * c.one
    r = jnp.sign(s) * jnp.floor(jnp.abs(s) + 0.5)
    return jnp.clip(r, c.min_raw, c.max_raw).astype(c.storage_dtype)


def _dequantize(raw: jax.Array, scale: jax.Array, c: PrecisionContract
                ) -> jax.Array:
    return raw.astype(jnp.float32) * (scale / c.one)


def integer_psum_grads(
    grads: Any,
    axis_name: str,
    contract: str = "Q2.13",
    residuals: Optional[Any] = None,
) -> Tuple[Any, Any]:
    """Cross-`axis_name` deterministic mean of a gradient pytree.

    Must run inside shard_map/pmap context where ``axis_name`` is bound.
    Returns (mean_grads, new_residuals) — residuals is None-safe.
    """
    c = get_contract(contract)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if r is not None:
            g32 = g32 + r
        local_max = jnp.max(jnp.abs(g32))
        scale = jax.lax.pmax(local_max, axis_name)  # consistent across pods
        raw = _quantize(g32, scale, c)
        # accumulate in int32/int64: n_pods * |raw| stays in range
        summed = jax.lax.psum(raw.astype(c.acc_dtype), axis_name)
        mean = _dequantize(summed, scale, c) / n.astype(jnp.float32)
        new_r = None
        if r is not None:
            # error feedback: what this pod failed to transmit
            sent = _dequantize(raw, scale, c)
            new_r = g32 - sent
        return mean.astype(g.dtype), new_r

    if residuals is None:
        out = jax.tree.map(lambda g: one(g, None)[0], grads)
        return out, None
    pairs = jax.tree.map(one, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return mean, res
