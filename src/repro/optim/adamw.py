"""AdamW, self-contained (no optax in the container), ZeRO-friendly.

Optimizer state mirrors the param pytree, so the FSDP/TP param shardings
apply verbatim to m and v — sharded optimizer state (ZeRO-1 semantics under
GSPMD) without any extra machinery.

All state is explicit and deterministic; the checkpoint module hashes it the
same way it hashes Valori snapshots.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m.astype(p.dtype), v.astype(p.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
