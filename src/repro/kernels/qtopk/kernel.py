"""Pallas TPU kernel: deterministic k-smallest selection over wide scores.

Input scores are int64 conceptually, carried as two int32 planes:
    hi = s >> 32,  lo = (s & 0xFFFFFFFF) XOR 0x80000000  (sign-bias)
so that signed lexicographic (hi, lo) comparison equals int64 comparison —
again because the target TPU has no native int64 (DESIGN.md §2).

Selection is deterministic by construction: ties on (hi, lo) are broken by
the smallest int32 tie key (caller supplies arena positions or external ids).

Tiling: grid (nq/BQ, n/BN). Each grid step extracts its block's k best
candidates with k passes of a three-stage vectorized min reduction
(hi → lo → key), writing [BQ, k] triples per block. The host-side ops.py
merges the per-block candidates (n/BN × k per query) with one small sort.
A k-pass VPU reduction keeps everything in registers/VMEM — no cross-lane
sort network needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core import compat

_CompilerParams = compat.pallas_tpu_compiler_params()

I32_MAX = 2**31 - 1  # Python int: folded into the kernel as an immediate


def _qtopk_kernel(hi_ref, lo_ref, key_ref, out_hi_ref, out_lo_ref, out_key_ref, *, k: int):
    hi = hi_ref[...]           # [BQ, BN] int32
    lo = lo_ref[...]           # [BQ, BN] int32 (sign-biased)
    key = key_ref[...]         # [1, BN] int32 tie keys (broadcast over BQ)
    bq, bn = hi.shape
    key = jnp.broadcast_to(key, (bq, bn))

    for t in range(k):
        min_hi = jnp.min(hi, axis=1, keepdims=True)
        on_hi = hi == min_hi
        lo_m = jnp.where(on_hi, lo, I32_MAX)
        min_lo = jnp.min(lo_m, axis=1, keepdims=True)
        on_lo = on_hi & (lo_m == min_lo)
        key_m = jnp.where(on_lo, key, I32_MAX)
        min_key = jnp.min(key_m, axis=1, keepdims=True)
        chosen = key_m == min_key  # exactly one lane per row

        out_hi_ref[:, t] = min_hi[:, 0]
        out_lo_ref[:, t] = min_lo[:, 0]
        out_key_ref[:, t] = min_key[:, 0]

        # retire the chosen lane
        hi = jnp.where(chosen, I32_MAX, hi)
        lo = jnp.where(chosen, I32_MAX, lo)


def qtopk_pallas(
    hi: jax.Array,   # [nq, n] int32
    lo: jax.Array,   # [nq, n] int32 sign-biased
    key: jax.Array,  # [1, n] int32 tie keys
    k: int,
    *,
    block_q: int = 128,
    block_n: int = 1024,
    interpret: bool = True,
):
    """Per-block candidates: three int32 arrays [nq, n_blocks * k]."""
    nq, n = hi.shape
    assert nq % block_q == 0 and n % block_n == 0
    n_blocks = n // block_n
    grid = (nq // block_q, n_blocks)

    kern = lambda *refs: _qtopk_kernel(*refs, k=k)
    out_shape = [jax.ShapeDtypeStruct((nq, n_blocks * k), jnp.int32)] * 3
    out_spec = pl.BlockSpec((block_q, k), lambda i, j: (i, j))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(hi, lo, key)
