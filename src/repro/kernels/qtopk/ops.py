"""jit'd public wrapper for qtopk: plane split, padding, final candidate merge."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.qtopk import kernel as _kernel

# plain int, not a jnp scalar: a module-level jnp constant would become a
# leaked tracer when this module is first imported inside a jit trace
# (core.search lazily imports us from within jitted exact_search)
_BIAS = 0x80000000


def split_planes(scores: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int64 scores → (hi int32, sign-biased lo int32); lex order preserved."""
    s = scores.astype(jnp.int64)
    hi = (s >> 32).astype(jnp.int32)
    lo_u = (s & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32) ^ jnp.uint32(_BIAS)
    return hi, lo_u.astype(jnp.int32)


def combine_planes(hi: jax.Array, lo: jax.Array) -> jax.Array:
    lo_u = (jax.lax.bitcast_convert_type(lo.astype(jnp.int32), jnp.uint32)
            ^ jnp.uint32(_BIAS)).astype(jnp.int64)
    return (hi.astype(jnp.int64) << 32) | lo_u


@partial(jax.jit, static_argnames=("k", "interpret", "use_pallas"))
def qtopk(scores: jax.Array, keys: jax.Array, k: int, *,
          interpret: bool = True, use_pallas: bool = True
          ) -> Tuple[jax.Array, jax.Array]:
    """Deterministic k smallest (score, key) per row.

    scores [nq, n] int64 wide scores; keys [n] int32 tie keys (unique).
    Returns (scores [nq, k] int64, keys [nq, k] int32), sorted.
    Bit-identical to ref.qtopk_ref.
    """
    if not use_pallas:
        from repro.kernels.qtopk import ref
        return ref.qtopk_ref(scores, keys, k)

    nq, n = scores.shape
    bq = min(128, max(8, nq))
    bn = 1024 if n >= 1024 else max(128, n) if n >= 128 else n
    hi, lo = split_planes(scores)

    pq = (-nq) % bq
    pn = (-n) % bn
    if pq or pn:
        hi = jnp.pad(hi, ((0, pq), (0, pn)), constant_values=2**31 - 1)
        lo = jnp.pad(lo, ((0, pq), (0, pn)), constant_values=2**31 - 1)
    keys_p = jnp.pad(
        keys.astype(jnp.int32), (0, pn), constant_values=2**31 - 1
    )[None, :]

    kk = min(k, bn)
    c_hi, c_lo, c_key = _kernel.qtopk_pallas(
        hi, lo, keys_p, kk, block_q=bq, block_n=bn, interpret=interpret
    )
    # final merge over n_blocks*k candidates (small): exact int64 sort
    cand_scores = combine_planes(c_hi, c_lo)[:nq]
    cand_keys = c_key[:nq]
    s, i = jax.lax.sort(
        (cand_scores, cand_keys.astype(jnp.int32)), num_keys=2, dimension=1
    )
    return s[:, :k], i[:, :k]
