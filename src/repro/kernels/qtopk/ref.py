"""Pure-jnp oracle for qtopk: full (score, key) lexicographic sort."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def qtopk_ref(scores: jax.Array, keys: jax.Array, k: int
              ) -> Tuple[jax.Array, jax.Array]:
    """k smallest (score int64, key int32) pairs per row, sorted lexicographically.

    scores [nq, n]; keys [n] (tie-break). Returns (scores [nq,k], keys [nq,k]).
    """
    nq, n = scores.shape
    keys_b = jnp.broadcast_to(keys[None, :].astype(jnp.int32), (nq, n))
    s, i = jax.lax.sort((scores, keys_b), num_keys=2, dimension=1)
    return s[:, :k], i[:, :k]
