from repro.kernels.qtopk.ops import qtopk  # noqa: F401
