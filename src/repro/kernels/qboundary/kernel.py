"""Pallas TPU kernel: the fused determinism boundary (paper §5.3).

Every embedding that enters the memory substrate crosses
float → Q-encode (round-half-away, saturate) → exact integer L2-normalize.
In serving this runs per request batch, so it is the substrate's hottest
entry point. The fusion keeps the whole pipeline in VMEM: one row tile is
read once from HBM and the raw fixed-point unit vector is written once.

Integer sqrt inside the kernel is the same 32-step digit recurrence as
fixedpoint.isqrt, but expressed with a fori_loop over VMEM-resident rows.

Tiling: grid over row blocks [BR, D]; D ≤ MAX_D so a row's wide accumulator
(int64 semantics emulated exactly: the squared-norm of a Q16.16-bounded row
fits 62 bits, and we carry it as two f32-free int32 limbs? No — inside the
kernel we use jnp int64 ops, which interpret mode executes exactly and which
Mosaic lowers to 32-bit pairs on TPU; the kernel only relies on exactness,
verified bit-for-bit against ref.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core import compat

_CompilerParams = compat.pallas_tpu_compiler_params()


def _qboundary_kernel(x_ref, out_ref, *, one: int, min_raw: int, max_raw: int,
                      unit_norm: bool):
    x = x_ref[...].astype(jnp.float32)            # [BR, D]
    # encode: round half away from zero, saturate
    scaled = x * one
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    raw = jnp.clip(rounded, min_raw, max_raw).astype(jnp.int32)

    if unit_norm:
        wide = raw.astype(jnp.int64)
        sq = jnp.sum(wide * wide, axis=-1, keepdims=True)  # [BR, 1] ≤ 2^62

        def isqrt_body(i, carry):
            rem, res = carry
            bit = jnp.int64(1) << (62 - 2 * i)
            take = rem >= res + bit
            rem = jnp.where(take, rem - (res + bit), rem)
            res = jnp.where(take, (res >> 1) + bit, res >> 1)
            return rem, res

        _, norm = jax.lax.fori_loop(
            0, 32, isqrt_body, (sq, jnp.zeros_like(sq)))
        safe = jnp.where(norm == 0, jnp.ones_like(norm), norm)
        num = wide << 16
        # round-to-nearest integer division (half away from zero)
        q = jnp.abs(num) // safe
        rem = jnp.abs(num) - q * safe
        adjust = (2 * rem >= safe).astype(jnp.int64)
        signed = jnp.sign(num) * (q + adjust)
        raw = jnp.where(norm == 0, wide, signed).astype(jnp.int32)
        raw = jnp.clip(raw, min_raw, max_raw)

    out_ref[...] = raw


def qboundary_pallas(x: jax.Array, *, one: int, min_raw: int, max_raw: int,
                     unit_norm: bool = True, block_rows: int = 128,
                     interpret: bool = True) -> jax.Array:
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    kern = lambda xr, orr: _qboundary_kernel(
        xr, orr, one=one, min_raw=min_raw, max_raw=max_raw,
        unit_norm=unit_norm)
    return pl.pallas_call(
        kern,
        grid=(n // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
