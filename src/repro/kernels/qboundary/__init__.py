from repro.kernels.qboundary.ops import qboundary  # noqa: F401
