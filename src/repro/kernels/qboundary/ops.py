"""jit'd wrapper for the fused boundary kernel (padding + contract plumbing)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.contracts import DEFAULT_CONTRACT, PrecisionContract
from repro.kernels.qboundary import kernel as _kernel


@partial(jax.jit, static_argnames=("contract", "unit_norm", "interpret",
                                   "use_pallas"))
def qboundary(x: jax.Array, contract: PrecisionContract = DEFAULT_CONTRACT,
              *, unit_norm: bool = True, interpret: bool = True,
              use_pallas: bool = True) -> jax.Array:
    """float [n, d] → raw fixed-point unit vectors [n, d] int32.

    Bit-identical to core.boundary.normalize_embedding (the ref oracle);
    only contracts with int32 storage are kernelized.
    """
    if not use_pallas or jnp.dtype(contract.storage_dtype) != jnp.int32:
        from repro.kernels.qboundary import ref
        return ref.qboundary_ref(x, contract, unit_norm)
    n, d = x.shape
    br = min(128, n) if n % 8 == 0 or n < 8 else 1
    while n % br:
        br //= 2
    br = max(br, 1)
    pad = (-n) % br
    xp = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0)))
    out = _kernel.qboundary_pallas(
        xp, one=contract.one, min_raw=contract.min_raw,
        max_raw=contract.max_raw, unit_norm=unit_norm, block_rows=br,
        interpret=interpret)
    return out[:n]
