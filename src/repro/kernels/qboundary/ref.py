"""Oracle: the boundary as composed pure-jnp core ops (encode ∘ qnorm)."""
from __future__ import annotations

import jax

from repro.core import boundary
from repro.core.contracts import PrecisionContract


def qboundary_ref(x: jax.Array, contract: PrecisionContract,
                  unit_norm: bool = True) -> jax.Array:
    return boundary.normalize_embedding(x, contract, unit_norm=unit_norm)
