"""Pallas TPU kernels for the substrate's compute hot-spots.

Each kernel ships three files (kernel.py: pl.pallas_call + BlockSpec VMEM
tiling; ops.py: jit'd public wrapper with padding/fallbacks; ref.py: pure-jnp
oracle) and is validated BITWISE against its oracle across shape sweeps —
integer kernels admit no tolerance.

  qgemm     — exact fixed-point scoring matmul; int64 accumulation realized
              as three int32 limb planes (TPU has no native int64)
  qtopk     — deterministic k-smallest with tie keys over dual-plane scores
  qboundary — fused float→Q-encode→integer-L2-normalize (the paper's §5.3
              determinism boundary, the hottest serving entry point)

Kernels run in interpret mode on the CPU container (exact semantics); on TPU
the same BlockSpecs drive Mosaic compilation.
"""
