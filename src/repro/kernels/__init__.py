"""Pallas TPU kernels for the substrate's compute hot-spots.

Each kernel ships three files (kernel.py: pl.pallas_call + BlockSpec VMEM
tiling; ops.py: jit'd public wrapper with padding/fallbacks; ref.py: pure-jnp
oracle) and is validated BITWISE against its oracle across shape sweeps —
integer kernels admit no tolerance.

  qgemm     — exact fixed-point scoring matmul; int64 accumulation realized
              as three int32 limb planes (TPU has no native int64)
  qcoarse   — int8 coarse-scan scoring for the compressed tier: int32 query
              weights decomposed into four 8-bit limb planes against int8
              codes (1/4 the bytes streamed of the exact scan)
  qtopk     — deterministic k-smallest with tie keys over dual-plane scores
  qboundary — fused float→Q-encode→integer-L2-normalize (the paper's §5.3
              determinism boundary, the hottest serving entry point)

Kernels run in interpret mode on the CPU container (exact semantics); on TPU
the same BlockSpecs drive Mosaic compilation.
"""
