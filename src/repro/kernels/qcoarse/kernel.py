"""Pallas TPU kernel: int8 coarse-scan scoring (the compressed tier's scan).

The coarse tier ranks rows by an integer dot between an int32 query weight
vector w (``codes.query_weights``) and the int8 code rows. The database
operand streams as *int8* — a 4x bytes-scanned reduction against the int32
exact scan — while the weights, too wide for one int8 multiply, decompose
into four 8-bit limbs (the same move qgemm makes for Q16.16 values):

    w = (w >> 24)<<24 + ((w >> 16) & 0xFF)<<16 + ((w >> 8) & 0xFF)<<8
        + (w & 0xFF)

(exact for signed w under arithmetic shifts). Four int32 partial planes

    P_3 = sum w3*c,  P_2 = sum w2*c,  P_1 = sum w1*c,  P_0 = sum w0*c

combine outside the kernel, where XLA's int64 emulation is available, as

    S = (P_3 << 24) + (P_2 << 16) + (P_1 << 8) + P_0.

Range analysis (why int32 accumulation is exact): |w| <= W_BOUND = 2^28
(codes.py clips), so |w3| <= 2^4 and the unsigned low limbs are < 2^8;
|c| <= 127, so every plane's accumulation over D dims is bounded by
255 * 127 * D < 2^31 for D <= 2^13 = 8192 — checked by ops.py.

Tiling mirrors qgemm: grid (nq/BQ, nn/BN, d/BK), output tile [BQ, BN, 4]
accumulated across the BK grid axis ('arbitrary' semantics). In interpret
mode every op is exact NumPy, so CPU validation is bit-exact against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core import compat

_CompilerParams = compat.pallas_tpu_compiler_params()


def _qcoarse_kernel(w_ref, c_ref, out_ref):
    """One (BQ, BN) output tile, accumulated across the K grid dimension."""
    k = pl.program_id(2)

    w = w_ref[...]                      # [BQ, BK] int32 query weights
    c = c_ref[...].astype(jnp.int32)    # [BN, BK] int8 codes, widened in-reg

    w3 = w >> 24
    w2 = (w >> 16) & 0xFF
    w1 = (w >> 8) & 0xFF
    w0 = w & 0xFF

    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((1,), (1,)), ((), ())),  # contract BK, no batch
        preferred_element_type=jnp.int32,
    )
    planes = jnp.stack(
        [dot(w3, c), dot(w2, c), dot(w1, c), dot(w0, c)], axis=-1
    )  # [BQ, BN, 4]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = planes

    @pl.when(k != 0)
    def _accum():
        out_ref[...] += planes


def qcoarse_planes_pallas(
    weights: jax.Array,  # [nq, d] int32 query weights (|w| <= W_BOUND)
    codes: jax.Array,    # [nn, d] int8 code rows
    *,
    block_q: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns the four int32 limb planes [nq, nn, 4].

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    nq, d = weights.shape
    nn, d2 = codes.shape
    assert d == d2, (d, d2)
    assert nq % block_q == 0 and nn % block_n == 0 and d % block_k == 0

    grid = (nq // block_q, nn // block_n, d // block_k)
    return pl.pallas_call(
        _qcoarse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n, 4), lambda i, j, k: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, nn, 4), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(weights, codes)
