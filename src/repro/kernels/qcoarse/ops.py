"""jit'd public wrapper for the qcoarse kernel: padding, range checks, combine."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.qcoarse import kernel as _kernel

# |w| <= W_BOUND (codes.query_weights clips) keeps all four int32 planes
# overflow-free up to MAX_DIM: 255 * 127 * 2^13 < 2^31.
W_BOUND = 1 << 28
MAX_DIM = 1 << 13


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _pick_blocks(nq: int, nn: int, d: int):
    bq = min(128, max(8, nq))
    bn = 128 if nn >= 128 else max(8, nn)
    bk = 512 if d >= 512 else max(128, d) if d >= 128 else d
    return bq, bn, bk


@partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def qcoarse_planes(weights: jax.Array, codes: jax.Array, *,
                   interpret: bool = True, use_pallas: bool = True
                   ) -> jax.Array:
    """Four int32 limb planes [nq, nn, 4] for int32 weights x int8 codes."""
    if weights.shape[-1] > MAX_DIM:
        raise ValueError(
            f"qcoarse exactness bound needs dim ≤ {MAX_DIM}, "
            f"got {weights.shape[-1]}"
        )
    nq, d = weights.shape
    nn = codes.shape[0]
    if not use_pallas:
        from repro.kernels.qcoarse import ref
        return ref.qcoarse_planes_ref(weights, codes)
    bq, bn, bk = _pick_blocks(nq, nn, d)
    wp = _pad_to(weights.astype(jnp.int32), bq, bk)
    cp = _pad_to(codes.astype(jnp.int8), bn, bk)
    planes = _kernel.qcoarse_planes_pallas(
        wp, cp, block_q=bq, block_n=bn, block_k=bk, interpret=interpret
    )
    return planes[:nq, :nn]


@partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def qcoarse(weights: jax.Array, codes: jax.Array, *,
            interpret: bool = True, use_pallas: bool = True) -> jax.Array:
    """Exact weighted-dot scores S [nq, nn] int64 — planes + int64 combine.

    Bit-identical to ref.qcoarse_ref for |w| <= W_BOUND and dim <= 8192
    (the bounds codes.query_weights guarantees for boundary-normalized
    inputs).
    """
    planes = qcoarse_planes(
        weights, codes, interpret=interpret, use_pallas=use_pallas
    ).astype(jnp.int64)
    return ((planes[..., 0] << 24) + (planes[..., 1] << 16)
            + (planes[..., 2] << 8) + planes[..., 3])
