"""Pure-jnp oracle for the qcoarse kernel: direct i64 accumulation."""
from __future__ import annotations

import jax.numpy as jnp


def qcoarse_ref(weights: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Exact weighted dot S [nq, nn] int64 — the i64-accumulator rule."""
    return jnp.einsum(
        "qd,nd->qn", weights.astype(jnp.int64), codes.astype(jnp.int64)
    )


def qcoarse_planes_ref(weights: jnp.ndarray, codes: jnp.ndarray
                       ) -> jnp.ndarray:
    """The four-limb partial planes, computed without Pallas (tile tests)."""
    w = weights.astype(jnp.int32)
    c = codes.astype(jnp.int32)
    limbs = (w >> 24, (w >> 16) & 0xFF, (w >> 8) & 0xFF, w & 0xFF)
    planes = [jnp.einsum("qd,nd->qn", l, c) for l in limbs]
    return jnp.stack(planes, axis=-1)


def combine_planes_ref(planes: jnp.ndarray) -> jnp.ndarray:
    p = planes.astype(jnp.int64)
    return (p[..., 0] << 24) + (p[..., 1] << 16) + (p[..., 2] << 8) + p[..., 3]
