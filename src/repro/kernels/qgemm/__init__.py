from repro.kernels.qgemm.ops import qgemm, qgemm_planes  # noqa: F401
