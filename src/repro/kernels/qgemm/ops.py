"""jit'd public wrapper for the qgemm kernel: padding, range checks, combine."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.qgemm import kernel as _kernel

# |raw| ≤ RAW_BOUND keeps all three int32 planes overflow-free up to MAX_DIM.
RAW_BOUND = 1 << 16
MAX_DIM = 1 << 13


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _pick_blocks(nq: int, nn: int, d: int):
    bq = min(128, max(8, nq))
    bn = 128 if nn >= 128 else max(8, nn)
    bk = 512 if d >= 512 else max(128, d) if d >= 128 else d
    return bq, bn, bk


@partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def qgemm_planes(queries: jax.Array, database: jax.Array, *,
                 interpret: bool = True, use_pallas: bool = True) -> jax.Array:
    """Three int32 limb planes [nq, nn, 3] for raw fixed-point inputs."""
    if queries.shape[-1] > MAX_DIM:
        raise ValueError(
            f"qgemm exactness bound needs dim ≤ {MAX_DIM}, got {queries.shape[-1]}"
        )
    nq, d = queries.shape
    nn = database.shape[0]
    if not use_pallas:
        from repro.kernels.qgemm import ref
        return ref.qgemm_planes_ref(queries, database)
    bq, bn, bk = _pick_blocks(nq, nn, d)
    qp = _pad_to(queries.astype(jnp.int32), bq, bk)
    dp = _pad_to(database.astype(jnp.int32), bn, bk)
    planes = _kernel.qgemm_planes_pallas(
        qp, dp, block_q=bq, block_n=bn, block_k=bk, interpret=interpret
    )
    return planes[:nq, :nn]


@partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def qgemm(queries: jax.Array, database: jax.Array, *,
          interpret: bool = True, use_pallas: bool = True) -> jax.Array:
    """Exact wide int64 dot scores [nq, nn] — kernel planes + int64 combine.

    Bit-identical to ref.qgemm_ref for boundary-normalized inputs
    (|raw| ≤ 2^16, dim ≤ 8192).
    """
    planes = qgemm_planes(
        queries, database, interpret=interpret, use_pallas=use_pallas
    ).astype(jnp.int64)
    return (planes[..., 0] << 16) + (planes[..., 1] << 8) + planes[..., 2]
