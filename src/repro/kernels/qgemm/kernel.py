"""Pallas TPU kernel: exact fixed-point scoring matmul (paper §5.1, hot spot).

The paper's dot products accumulate in i64. TPUs have no native int64, so the
TPU-native adaptation (DESIGN.md §2) decomposes each Q16.16 raw value into
8-bit limbs

    raw = h * 2^8 + l,   h = raw >> 8 (signed),  l = raw & 0xFF (unsigned)

and computes three int32 partial-sum planes

    S_hh = Σ h·h',   S_hl = Σ (h·l' + l·h'),   S_ll = Σ l·l'

whose exact int64 combination is  (S_hh << 16) + (S_hl << 8) + S_ll.

Range analysis (why int32 accumulation is exact): boundary-normalized vectors
satisfy |raw| ≤ 2^16, so |h| ≤ 2^8, l < 2^8, giving
    |S_hh| ≤ 2^16·D,  |S_hl| ≤ 2^17·D,  |S_ll| < 2^16·D,
all < 2^31 for D ≤ 2^13 = 8192 — checked by ops.py. The combination step runs
outside the kernel where XLA's int64 emulation is available.

Tiling: grid (nq/BQ, nn/BN, nd/BK); Q and DB tiles live in VMEM; the output
tile [BQ, BN, 3] accumulates across the BK grid axis (revisited, 'arbitrary'
semantics). All matmuls are lax.dot_general with int32 preferred type — on
TPU these map to MXU/VPU integer paths; in interpret mode they are exact
NumPy-level ops, so CPU validation is bit-exact against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.core import compat

_CompilerParams = compat.pallas_tpu_compiler_params()


def _qgemm_kernel(q_ref, d_ref, out_ref):
    """One (BQ, BN) output tile, accumulated across the K grid dimension."""
    k = pl.program_id(2)

    q = q_ref[...]  # [BQ, BK] int32
    d = d_ref[...]  # [BN, BK] int32

    qh = q >> 8
    ql = q & 0xFF
    dh = d >> 8
    dl = d & 0xFF

    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((1,), (1,)), ((), ())),  # contract BK, no batch
        preferred_element_type=jnp.int32,
    )
    s_hh = dot(qh, dh)
    s_hl = dot(qh, dl) + dot(ql, dh)
    s_ll = dot(ql, dl)

    planes = jnp.stack([s_hh, s_hl, s_ll], axis=-1)  # [BQ, BN, 3]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = planes

    @pl.when(k != 0)
    def _accum():
        out_ref[...] += planes


def qgemm_planes_pallas(
    queries: jax.Array,   # [nq, d] int32 raw fixed-point
    database: jax.Array,  # [nn, d] int32 raw fixed-point
    *,
    block_q: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns the three int32 partial planes [nq, nn, 3].

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    nq, d = queries.shape
    nn, d2 = database.shape
    assert d == d2, (d, d2)
    assert nq % block_q == 0 and nn % block_n == 0 and d % block_k == 0

    grid = (nq // block_q, nn // block_n, d // block_k)
    return pl.pallas_call(
        _qgemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n, 3), lambda i, j, k: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, nn, 3), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(queries, database)
