"""Pure-jnp oracle for the qgemm kernel: direct i64 accumulation (paper §5.1)."""
from __future__ import annotations

import jax.numpy as jnp


def qgemm_ref(queries: jnp.ndarray, database: jnp.ndarray) -> jnp.ndarray:
    """Exact wide dot scores [nq, nn] int64 — the paper's i64-accumulator rule."""
    return jnp.einsum(
        "qd,nd->qn", queries.astype(jnp.int64), database.astype(jnp.int64)
    )


def qgemm_planes_ref(queries: jnp.ndarray, database: jnp.ndarray) -> jnp.ndarray:
    """The three-limb partial planes, computed without Pallas (for tile tests)."""
    qh, ql = queries >> 8, queries & 0xFF
    dh, dl = database >> 8, database & 0xFF
    s_hh = jnp.einsum("qd,nd->qn", qh.astype(jnp.int32), dh.astype(jnp.int32))
    s_hl = jnp.einsum("qd,nd->qn", qh.astype(jnp.int32), dl.astype(jnp.int32)) + \
           jnp.einsum("qd,nd->qn", ql.astype(jnp.int32), dh.astype(jnp.int32))
    s_ll = jnp.einsum("qd,nd->qn", ql.astype(jnp.int32), dl.astype(jnp.int32))
    return jnp.stack([s_hh, s_hl, s_ll], axis=-1)


def combine_planes_ref(planes: jnp.ndarray) -> jnp.ndarray:
    p = planes.astype(jnp.int64)
    return (p[..., 0] << 16) + (p[..., 1] << 8) + p[..., 2]
