from repro.data.pipeline import (DataConfig, DeterministicPipeline,  # noqa: F401
                                 feistel_permute)
