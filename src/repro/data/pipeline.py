"""Deterministic, resumable data pipeline.

The paper's replayable-command-log discipline applied to training data: the
batch served at step t is a pure function of (seed, step, dp_rank), so

  * restarts resume mid-epoch bit-identically (checkpoint stores only `step`);
  * elastic re-sharding (dp_size change) re-partitions the SAME global order;
  * shuffling is a Feistel permutation over [0, N) — integer-only, stateless,
    invertible, no shuffle buffer to checkpoint.

Sources: a synthetic LM stream (deterministic token soup with local structure
so loss curves are meaningful) or a memory-mapped token file.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


# --------------------------------------------------------------------------- #
# Feistel permutation over [0, n): deterministic stateless shuffle
# --------------------------------------------------------------------------- #


def _feistel_round(left: np.ndarray, right: np.ndarray, key: int) -> tuple:
    mixed = (right.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             ^ np.uint64(key)) * np.uint64(0xC2B2AE3D27D4EB4F)
    mixed = (mixed >> np.uint64(29)) ^ mixed
    return right, left ^ (mixed & np.uint64(0xFFFFFFFF))


def feistel_permute(idx: np.ndarray, n: int, seed: int, rounds: int = 4
                    ) -> np.ndarray:
    """Map indices → permuted indices over [0, n). Cycle-walking Feistel:
    bijective for any n, pure integer ops ⇒ platform-invariant."""
    assert n > 0
    bits = max(2, int(np.ceil(np.log2(n))))
    half = (bits + 1) // 2
    mask = np.uint64((1 << half) - 1)

    def encrypt(x: np.ndarray) -> np.ndarray:
        left = (x >> np.uint64(half)) & mask
        right = x & mask
        for r in range(rounds):
            left, right = _feistel_round(left, right, seed * 1000003 + r)
            left &= mask
            right &= mask
        return (left << np.uint64(half)) | right

    out = idx.astype(np.uint64)
    domain = np.uint64(1) << np.uint64(2 * half)
    result = encrypt(out)
    # cycle-walk values that landed outside [0, n)
    for _ in range(64):  # P(escape) halves each round; 64 is overkill-safe
        bad = result >= n
        if not bad.any():
            break
        result = np.where(bad, encrypt(result), result)
    return result.astype(np.int64)


# --------------------------------------------------------------------------- #
# pipeline
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_documents: int = 1 << 20   # synthetic corpus size (documents)
    source: str = "synthetic"      # synthetic | file
    token_file: Optional[str] = None


class DeterministicPipeline:
    """batch(step, dp_rank, dp_size) → {'tokens','labels'} int32 arrays."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "file":
            assert cfg.token_file, "file source needs token_file"
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32, mode="r")
            self._n_docs = len(self._tokens) // (cfg.seq_len + 1)
        else:
            self._tokens = None
            self._n_docs = cfg.num_documents

    # ------------------------------------------------------------------ #
    def _doc_ids_for(self, step: int, dp_rank: int, dp_size: int) -> np.ndarray:
        """Global sample order is permutation(seed, epoch); rank r takes the
        contiguous slice [r·b_local, (r+1)·b_local) of each global batch —
        identical global order for ANY dp_size (elasticity invariant)."""
        b = self.cfg.global_batch
        assert b % dp_size == 0, (b, dp_size)
        b_local = b // dp_size
        start = step * b + dp_rank * b_local
        linear = np.arange(start, start + b_local, dtype=np.int64)
        epoch = linear // self._n_docs
        within = linear % self._n_docs
        out = np.empty_like(within)
        for e in np.unique(epoch):
            m = epoch == e
            out[m] = feistel_permute(within[m], self._n_docs,
                                     self.cfg.seed * 7919 + int(e))
        return out

    def _synthesize(self, doc_ids: np.ndarray) -> np.ndarray:
        """Deterministic 'token soup' with Markov-ish structure: token t+1
        depends on (doc hash, token t) so models can actually learn."""
        L = self.cfg.seq_len + 1
        V = self.cfg.vocab_size
        n = len(doc_ids)
        toks = np.empty((n, L), dtype=np.int64)
        state = (doc_ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15))
        cur = (state >> np.uint64(33)) % np.uint64(V)
        toks[:, 0] = cur
        for t in range(1, L):
            state = (state ^ cur) * np.uint64(0xC2B2AE3D27D4EB4F) + np.uint64(t)
            nxt = ((state >> np.uint64(31)) ^ state) % np.uint64(V)
            # 75% markov-predictable continuation, 25% "noise"
            predictable = ((state >> np.uint64(13)) & np.uint64(3)) != 0
            cont = (cur * np.uint64(31) + np.uint64(7)) % np.uint64(V)
            cur = np.where(predictable, cont, nxt)
            toks[:, t] = cur
        return toks.astype(np.int32)

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1
              ) -> Dict[str, np.ndarray]:
        doc_ids = self._doc_ids_for(step, dp_rank, dp_size)
        if self._tokens is not None:
            L = self.cfg.seq_len + 1
            rows = np.stack([
                self._tokens[i * L:(i + 1) * L] for i in doc_ids
            ]).astype(np.int32)
        else:
            rows = self._synthesize(doc_ids)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
