"""Mesh-aware activation sharding constraints.

Constraints apply only when the ambient (set_mesh) mesh defines the axes and
the dimension divides — so the same model code runs unsharded smoke tests,
host meshes, and the 512-chip production mesh unchanged.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.core import compat


def _mesh():
    return compat.get_abstract_mesh()


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_divides(n: int) -> bool:
    """True iff the ambient mesh has a `model` axis that divides n."""
    mesh = _mesh()
    return (mesh is not None and "model" in mesh.axis_names
            and n % mesh.shape["model"] == 0)


def constrain(x: jax.Array, *dim_roles: Optional[str]) -> jax.Array:
    """dim_roles per axis: 'batch' | 'model' | None.

    'batch' → the DP axes (if the dim divides their product);
    'model' → TP axis (if divisible); None → replicated.
    """
    mesh = _mesh()
    if mesh is None:
        return x
    spec = []
    for dim, role in zip(x.shape, dim_roles):
        if role == "batch":
            axes = dp_axes(mesh)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            spec.append(axes if (axes and dim % size == 0) else None)
        elif role == "model":
            ok = "model" in mesh.axis_names and dim % mesh.shape["model"] == 0
            spec.append("model" if ok else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
