"""Decoder blocks: attention+FFN (dense/MoE) and shared-attention (zamba2).

Block param layout is uniform so layers stack for lax.scan. Norm styles:
  pre      : h += f(norm(h))                       (llama family)
  pre_post : h += post_norm(f(pre_norm(h)))        (gemma2 sandwich)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import init_rmsnorm, rmsnorm


def init_decoder_block(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    p = {
        "ln_attn": init_rmsnorm(cfg.d_model, cfg.params_dtype),
        "ln_ffn": init_rmsnorm(cfg.d_model, cfg.params_dtype),
        "attn": attn_lib.init_attention(ks[0], cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                            cfg.params_dtype)
    if cfg.norm_style == "pre_post":
        p["ln_attn_post"] = init_rmsnorm(cfg.d_model, cfg.params_dtype)
        p["ln_ffn_post"] = init_rmsnorm(cfg.d_model, cfg.params_dtype)
    return p


def decoder_block(
    params: dict,
    h: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool,
    mode: str,
    cache_slice: Optional[dict] = None,
    angles: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (h, new_cache, aux_loss)."""
    a_in = rmsnorm(params["ln_attn"], h, cfg.rms_eps)
    a_out, new_cache = attn_lib.attention(
        params["attn"], a_in, positions, cfg,
        local=local, mode=mode, cache_slice=cache_slice, angles=angles,
    )
    if cfg.norm_style == "pre_post":
        a_out = rmsnorm(params["ln_attn_post"], a_out, cfg.rms_eps)
    h = h + a_out

    f_in = rmsnorm(params["ln_ffn"], h, cfg.rms_eps)
    if cfg.family == "moe":
        f_out, aux = moe_lib.moe_ffn(params["moe"], f_in, cfg)
    else:
        f_out = mlp(params["mlp"], f_in, cfg.activation)
        aux = jnp.zeros((), jnp.float32)
    if cfg.norm_style == "pre_post":
        f_out = rmsnorm(params["ln_ffn_post"], f_out, cfg.rms_eps)
    h = h + f_out
    return h, new_cache, aux


def init_mamba_layer(key, cfg: ModelConfig) -> dict:
    return {
        "ln": init_rmsnorm(cfg.d_model, cfg.params_dtype),
        "mamba": ssm_lib.init_mamba(key, cfg),
    }


def mamba_layer(params: dict, h: jax.Array, cfg: ModelConfig, *, mode: str,
                cache_slice: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    m_in = rmsnorm(params["ln"], h, cfg.rms_eps)
    m_out, new_cache = ssm_lib.mamba_block(
        params["mamba"], m_in, cfg, mode=mode, cache_slice=cache_slice
    )
    return h + m_out, new_cache
