"""Parameter / activation partition rules (FSDP over `data`, TP over `model`).

Divisibility-aware: each rule proposes shardings in priority order and the
first one whose dimension divides the mesh axis wins; otherwise the dim is
replicated. This one engine covers all 10 archs (MQA kv=1, gemma2's 8 heads,
qwen2-vl's 28 heads, granite-moe's 40 experts, mamba's packed projections —
each falls back gracefully; the roofline table shows what got replicated).

Conventions:
  * params may have extra *leading* stack axes (scan layers / groups /
    shared blocks); rules match on trailing dims and leading axes replicate.
  * the `pod` axis is pure DP: params are replicated across pods (cross-pod
    traffic = one gradient all-reduce per step, see optim/compress.py).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.config import ModelConfig


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n > 0


class Rules:
    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg

    def m(self, dim: int) -> Optional[str]:
        """TP-shard a dim over `model` if divisible."""
        return "model" if _div(dim, self.mesh, "model") else None

    def d(self, dim: int) -> Optional[str]:
        """FSDP-shard a dim over `data` if divisible."""
        return "data" if _div(dim, self.mesh, "data") else None

    # ------------------------------------------------------------------ #
    def spec_for(self, path: str, shape: tuple) -> P:
        cfg = self.cfg
        name = path.split("/")[-1]
        H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_

        def attn_qkv(heads: int) -> tuple:
            # shard heads over model when divisible; otherwise the weights
            # stay FSDP-only and the *sequence* dim of q is model-sharded in
            # the flash path (sequence-parallel attention — see
            # attention.py). head_dim TP was measured catastrophically
            # collective-bound (score psum per kv chunk; EXPERIMENTS.md §Perf).
            if self.m(heads):
                return (self.d(shape[-3]), "model", None)
            return (self.d(shape[-3]), None, None)

        table = {
            "embed": lambda: (self.m(shape[-2]), self.d(shape[-1])),
            "lm_head": lambda: (self.d(shape[-2]), self.m(shape[-1])),
            "wq": lambda: attn_qkv(H),
            "wk": lambda: attn_qkv(KV),
            "wv": lambda: attn_qkv(KV),
            "wo": lambda: self._wo_spec(shape),
            "bq": lambda: (None, None),
            "bk": lambda: (None, None),
            "bv": lambda: (None, None),
            # dense mlp
            "w_gate": lambda: self._ffn_in(shape),
            "w_up": lambda: self._ffn_in(shape),
            "w_down": lambda: self._ffn_out(shape),
            # router
            "router": lambda: (self.d(shape[-2]), None),
            # mamba
            "in_proj": lambda: (self.d(shape[-2]), self.m(shape[-1])),
            "out_proj": lambda: (self.m(shape[-2]), self.d(shape[-1])),
            "conv_w": lambda: (None, self.m(shape[-1])),
            "conv_b": lambda: (self.m(shape[-1]),),
            "A_log": lambda: (None,),
            "D_skip": lambda: (None,),
            "dt_bias": lambda: (None,),
            "norm_scale": lambda: (None,),
            "scale": lambda: (None,),
        }
        if name not in table:
            raise KeyError(f"no sharding rule for param {path!r} {shape}")
        spec = table[name]()
        # prepend replication for stack axes
        lead = len(shape) - len(spec)
        assert lead >= 0, (path, shape, spec)
        return P(*((None,) * lead + tuple(spec)))

    def _ffn_in(self, shape) -> tuple:
        if len(shape) >= 3 and shape[-3] == self.cfg.num_experts and \
                self.cfg.family == "moe":
            # expert weights [E, D, Fe]: EP over model, else TP inner dim
            if self.m(shape[-3]):
                return ("model", self.d(shape[-2]), None)
            return (None, self.d(shape[-2]), self.m(shape[-1]))
        return (self.d(shape[-2]), self.m(shape[-1]))

    def _ffn_out(self, shape) -> tuple:
        if len(shape) >= 3 and shape[-3] == self.cfg.num_experts and \
                self.cfg.family == "moe":
            if self.m(shape[-3]):
                return ("model", None, self.d(shape[-1]))
            return (None, self.m(shape[-2]), self.d(shape[-1]))
        return (self.m(shape[-2]), self.d(shape[-1]))

    def _wo_spec(self, shape) -> tuple:
        H = self.cfg.num_heads
        if self.m(H):
            return ("model", None, self.d(shape[-1]))
        return (None, None, self.d(shape[-1]))


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching a params (or ShapeDtypeStruct) pytree."""
    rules = Rules(mesh, cfg)

    def one(path, leaf):
        return rules.spec_for(_path_str(path), np.shape(leaf))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_shape, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------- #
# batch / cache specs
# --------------------------------------------------------------------------- #


def _bspec(cfg, mesh, global_batch):
    """DP axes for the batch dim, or None (replicate) when non-divisible."""
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    return dp if global_batch % dp_size == 0 else None


def train_batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> Any:
    b = _bspec(cfg, mesh, global_batch)
    if cfg.external_embeddings:
        return {"embeds": P(b, None, None), "labels": P(b, None)}
    return {"tokens": P(b, None), "labels": P(b, None)}


def logits_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> P:
    b = _bspec(cfg, mesh, global_batch)
    rules = Rules(mesh, cfg)
    return P(b, None, rules.m(cfg.vocab_size))


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, caches_shape: Any
                ) -> Any:
    """Specs for the decode cache pytree (shapes from eval_shape).

    KV caches [n, B, S, KV, Dh]: batch over DP when divisible; else context
    parallelism — shard the S axis over `data` (the long_500k path). Heads
    over `model` when divisible, else head_dim, else sequence gets model too.
    SSM states [n, B, H, P, N]: heads over model (else P dim).
    """
    rules = Rules(mesh, cfg)
    b = _bspec(cfg, mesh, batch)

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        shape = leaf.shape
        if name in ("k", "v"):
            n, B, S, KV, Dh = shape
            kv_ax = rules.m(KV)
            dh_ax = rules.m(Dh) if kv_ax is None else None
            seq_ax = None
            if b is None:
                seq_ax = "data" if S % mesh.shape["data"] == 0 else None
            return P(None, b, seq_ax, kv_ax, dh_ax)
        if name == "pos":
            n, B, S = shape
            seq_ax = None
            if b is None:
                seq_ax = "data" if S % mesh.shape["data"] == 0 else None
            return P(None, b, seq_ax)
        if name == "ssm":
            extra = len(shape) - 5
            n_axes = (None,) * (1 + extra)
            _, B, H, Pd, N = shape[extra:]
            h_ax = rules.m(H)
            p_ax = rules.m(Pd) if h_ax is None else None
            return P(*n_axes, b, h_ax, p_ax, None)
        if name == "conv":
            extra = len(shape) - 4
            n_axes = (None,) * (1 + extra)
            _, B, K, C = shape[extra:]
            return P(*n_axes, b, None, rules.m(C))
        raise KeyError(f"no cache rule for {name} {shape}")

    return jax.tree_util.tree_map_with_path(one, caches_shape)
