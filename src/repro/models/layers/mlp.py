"""Feed-forward blocks: SwiGLU / GeGLU (gated) and plain GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.initializers import dense_init


def init_mlp(key, d_model: int, d_ff: int, activation: str, param_dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), param_dtype),
            "w_up": dense_init(k2, (d_model, d_ff), param_dtype),
            "w_down": dense_init(k3, (d_ff, d_model), param_dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), param_dtype),
        "w_down": dense_init(k2, (d_ff, d_model), param_dtype),
    }


def mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    dtype = x.dtype
    if activation in ("swiglu", "geglu"):
        gate = x @ params["w_gate"].astype(dtype)
        up = x @ params["w_up"].astype(dtype)
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        return (act * up) @ params["w_down"].astype(dtype)
    h = jax.nn.gelu(x @ params["w_up"].astype(dtype))
    return h @ params["w_down"].astype(dtype)
