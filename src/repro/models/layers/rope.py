"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

All tables are computed in float32 on the fly from integer positions (no
persistent buffers — keeps the param pytree pure and the dry-run clean).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def _freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions [...,] int → angles [..., head_dim/2] f32."""
    return positions.astype(jnp.float32)[..., None] * _freqs(head_dim, theta)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x [..., n_heads, head_dim], angles [..., head_dim/2] (broadcast over heads).

    Rotate-half convention (llama): pairs are (x[..:d/2], x[..d/2:]).
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = xf[..., :d2], xf[..., d2:]
    cos = jnp.cos(angles)[..., None, :]  # add head axis
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def mrope_angles(positions_3d: jax.Array, head_dim: int, theta: float,
                 sections: Sequence[int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions_3d [3, B, L] (temporal, height, width). The head_dim/2 frequency
    slots are partitioned into ``sections`` (e.g. 16/24/24); each section takes
    its angle from the corresponding positional stream. For pure text the three
    streams are identical and M-RoPE reduces to standard RoPE exactly.

    Returns angles [B, L, head_dim/2].
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = _freqs(head_dim, theta)  # [d2]
    # angles per stream: [3, B, L, d2]
    ang = positions_3d.astype(jnp.float32)[..., None] * freqs
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., start:start + sec])
        start += sec
    return jnp.concatenate(parts, axis=-1)  # [B, L, d2]


def text_positions_3d(positions: jax.Array) -> jax.Array:
    """Lift text positions [B, L] → [3, B, L] (all streams equal)."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
