"""Token-choice top-k MoE with deterministic sort-based capacity dispatch.

Design goals (in priority order):
  1. determinism — routing uses stable integer sorts (ties by token index);
     no RNG, no atomics, so the same batch routes identically everywhere,
     matching the framework's replayability story;
  2. EP-shardability — the expert buffer [E, C, D] carries the expert axis,
     which the sharding rules place on the ``model`` mesh axis; GSPMD turns
     the scatter/gather into all-to-alls;
  3. O(T·k) memory — no [T, E, C] one-hot dispatch tensors (those explode at
     32k-token microbatches); instead tokens are sorted by expert and
     scattered into per-expert capacity slots.

Overflow tokens (rank ≥ capacity) are dropped, standard for capacity-factor
routing; their combine weight is zero so the residual passes through.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import pspec
from repro.models.config import ModelConfig
from repro.models.initializers import dense_init
from repro.core import compat


def init_moe(key, cfg: ModelConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.padded_experts, cfg.expert_d_ff
    pd = cfg.params_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (D, E), pd, fan_in=D),
        "w_gate": dense_init(k2, (E, D, Fe), pd, fan_in=D),
        "w_up": dense_init(k3, (E, D, Fe), pd, fan_in=D),
        "w_down": dense_init(k4, (E, Fe, D), pd, fan_in=Fe),
    }


def capacity_of(tokens: int, cfg: ModelConfig) -> int:
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    c = int(tokens * k * cfg.moe_capacity_factor / E)
    return max(8, ((c + 7) // 8) * 8)  # pad to lane multiple


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x [B, L, D] → (y [B, L, D], aux_loss scalar f32).

    Two implementations:
      * shard_map EP (production): trunk activations are replicated across
        `model`, so every model rank recomputes the (cheap) routing
        identically and runs ONLY its expert shard on the tokens routed
        there — dispatch needs zero communication and combine is a single
        bf16 psum over `model` per layer. Measured 9.09e12 → 1.4e11 wire
        bytes on phi3.5-moe train_4k vs the GSPMD-scatter version
        (EXPERIMENTS.md §Perf).
      * dense fallback (no mesh / non-divisible experts): sort-based
        capacity dispatch under plain GSPMD.

    aux = load-balancing loss (Switch-style mean(f_e · p_e) · E).
    """
    mesh = pspec._mesh()
    E = cfg.padded_experts
    if (mesh is not None and "model" in mesh.axis_names
            and E % mesh.shape["model"] == 0
            and x.shape[0] % _dp_size(mesh) == 0):
        return _moe_shardmap(params, x, cfg, mesh)
    return _moe_dense(params, x, cfg)


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _route(params, xt, cfg: ModelConfig):
    """Shared routing: top-k probs/experts + load-balance aux (f32)."""
    E, E_real, K = (cfg.padded_experts, cfg.num_experts,
                    cfg.num_experts_per_tok)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    if E != E_real:
        eidx = jnp.arange(E, dtype=jnp.int32)
        logits = jnp.where(eidx[None, :] < E_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def _expert_mlp(params, buf, cfg: ModelConfig, dtype):
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
    return jnp.einsum("ecf,efd->ecd", act * up, params["w_down"].astype(dtype))


def _moe_shardmap(params: dict, x: jax.Array, cfg: ModelConfig, mesh
                  ) -> Tuple[jax.Array, jax.Array]:
    from functools import partial

    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_model = mesh.shape["model"]
    E, K = cfg.padded_experts, cfg.num_experts_per_tok
    E_loc = E // n_model

    param_specs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }

    # fully-manual shard_map: `model` carries EP; the dp axes shard the batch
    # dim explicitly. (Partial-manual psum crashes XLA CPU's
    # AllReducePromotion; fully-manual works but requires the caller's jit to
    # pass explicit out_shardings — see train/step.py.)
    @partial(compat.shard_map, mesh=mesh,
             in_specs=(param_specs, P(dp, None, None)),
             out_specs=(P(dp, None, None), P()),
             check_vma=False)
    def fn(p, x_loc):
        B_loc, L, D = x_loc.shape  # local batch (dp-sharded)
        T = B_loc * L
        C = capacity_of(T, cfg)
        dtype = x_loc.dtype
        xt = x_loc.reshape(T, D)
        my = jax.lax.axis_index("model")

        probs, top_p, top_e = _route(p, xt, cfg)  # router replicated

        # identical on every model rank (same tokens, same router) — each
        # rank then takes only its expert slice. Deterministic by symmetry.
        flat_e = top_e.reshape(T * K).astype(jnp.int32)
        pair_idx = jnp.arange(T * K, dtype=jnp.int32)
        sorted_e, sorted_pair = jax.lax.sort((flat_e, pair_idx), num_keys=2)
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = (jnp.arange(T * K, dtype=jnp.int32)
                - starts[sorted_e].astype(jnp.int32))
        mine = (sorted_e // E_loc) == my
        keep = (rank < C) & mine
        dest = jnp.where(keep, (sorted_e % E_loc) * C + rank, E_loc * C)

        src_token = sorted_pair // K
        buf = jnp.zeros((E_loc * C, D), dtype)
        buf = buf.at[dest].set(xt[src_token], mode="drop")
        out_buf = _expert_mlp(p, buf.reshape(E_loc, C, D), cfg, dtype)
        out_flat = out_buf.reshape(E_loc * C, D)

        # combine locally then ONE psum over the expert shards
        pair_dest = jnp.full((T * K,), -1, jnp.int32).at[sorted_pair].set(
            jnp.where(keep, dest, -1))
        safe = jnp.clip(pair_dest, 0, E_loc * C - 1)
        gathered = out_flat[safe]
        w = jnp.where(pair_dest >= 0, top_p.reshape(T * K), 0.0).astype(dtype)
        y = jnp.sum((gathered * w[:, None]).reshape(T, K, D), axis=1)
        # psum in f32: XLA CPU's AllReducePromotion pass crashes on bf16
        # all-reduce inside partially-manual shard_map (checked 0.8.2);
        # f32 avoids the pass. TPU would take the bf16 path.
        y = jax.lax.psum(y.astype(jnp.float32), "model").astype(dtype)

        frac_tokens = counts.astype(jnp.float32) / jnp.float32(T * K)
        frac_probs = jnp.mean(probs, axis=0)
        aux = jnp.sum(frac_tokens * frac_probs) * E
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(B_loc, L, D), aux

    moe_params = {k: params[k] for k in
                  ("router", "w_gate", "w_up", "w_down")}
    return fn(moe_params, x)


def _moe_dense(params: dict, x: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, jax.Array]:
    """Fallback: sort-based capacity dispatch under plain GSPMD."""
    B, L, D = x.shape
    T = B * L
    E, K = cfg.padded_experts, cfg.num_experts_per_tok
    E_real = cfg.num_experts
    C = capacity_of(T, cfg)
    dtype = x.dtype
    xt = pspec.constrain(x.reshape(T, D), "batch", None)

    # ---- routing (f32 for numerics) ----------------------------------- #
    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if E != E_real:
        # padded experts are unroutable (deterministically -inf)
        eidx = jnp.arange(E, dtype=jnp.int32)
        logits = jnp.where(eidx[None, :] < E_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    top_p, top_e = jax.lax.top_k(probs, K)                      # [T, K]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- deterministic dispatch: stable sort by expert ----------------- #
    flat_e = top_e.reshape(T * K).astype(jnp.int32)             # pair -> expert
    pair_idx = jnp.arange(T * K, dtype=jnp.int32)
    # two-key sort (expert, pair index) — deterministic ties by construction
    sorted_e, sorted_pair = jax.lax.sort((flat_e, pair_idx), num_keys=2)
    # rank of each pair within its expert = position - segment start
    counts = jnp.bincount(flat_e, length=E)                     # [E]
    starts = jnp.cumsum(counts) - counts                        # exclusive
    rank = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = rank < C
    # overflow pairs scatter out of bounds → dropped by mode="drop"
    dest = jnp.where(keep, sorted_e * C + rank, E * C)          # [T*K]

    src_token = sorted_pair // K                                 # token of pair
    buf = jnp.zeros((E * C, D), dtype)
    buf = buf.at[dest].set(xt[src_token], mode="drop")
    # EP: expert axis over `model` (no-op when E is TP-indivisible)
    buf = pspec.constrain(buf.reshape(E, C, D), "model", None, None)

    # ---- expert computation (batched over E; EP shards this axis) ------ #
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
    out_buf = jnp.einsum("ecf,efd->ecd", act * up, params["w_down"].astype(dtype))
    out_buf = pspec.constrain(out_buf, "model", None, None)
    out_flat = out_buf.reshape(E * C, D)

    # ---- combine: gather each pair's expert output, weight, sum over K - #
    # invert the sort: pair -> dest slot (or -1 if dropped)
    pair_dest = jnp.full((T * K,), -1, jnp.int32).at[sorted_pair].set(
        jnp.where(keep, dest, -1)
    )
    safe = jnp.clip(pair_dest, 0, E * C - 1)
    gathered = out_flat[safe]                                    # [T*K, D]
    w = jnp.where(pair_dest >= 0, top_p.reshape(T * K), 0.0).astype(dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(T, K, D), axis=1)
    y = pspec.constrain(y, "batch", None)

    # ---- aux load-balance loss ----------------------------------------- #
    frac_tokens = counts.astype(jnp.float32) / jnp.float32(T * K)
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * E

    return y.reshape(B, L, D), aux
