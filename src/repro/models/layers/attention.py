"""GQA attention: naive, flash-chunked (memory-O(L)), and decode paths.

Covers every attention variant in the assigned pool:
  * GQA / MQA / MHA via num_kv_heads
  * RoPE / M-RoPE (qwen2-vl)
  * sliding-window (h2o-danube, gemma2 local layers) incl. ring-buffer decode
  * logit softcapping (gemma2)
  * qkv bias (qwen family)

The flash path is a pure-JAX online-softmax: vmap over query chunks (parallel
on device), lax.scan over KV chunks (sequential reduction). Baseline masks
the full causal square (HLO FLOPs ≈ 2x ideal — see EXPERIMENTS.md §Perf for
the balanced-pair optimization that removes the waste).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.initializers import dense_init
from repro.models import pspec
from repro.models.layers import rope as rope_lib

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #


def init_attention(key, cfg: ModelConfig) -> dict:
    D, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    pd = cfg.params_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (D, H, Dh), pd, fan_in=D),
        "wk": dense_init(k2, (D, KV, Dh), pd, fan_in=D),
        "wv": dense_init(k3, (D, KV, Dh), pd, fan_in=D),
        "wo": dense_init(k4, (H, Dh, D), pd, fan_in=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), pd)
        p["bk"] = jnp.zeros((KV, Dh), pd)
        p["bv"] = jnp.zeros((KV, Dh), pd)
    return p


# --------------------------------------------------------------------------- #
# qkv projection + rope
# --------------------------------------------------------------------------- #


def _project_qkv(params, x, cfg: ModelConfig, angles):
    dtype = x.dtype
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = rope_lib.apply_rope(q, angles)
    k = rope_lib.apply_rope(k, angles)
    return q, k, v


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _mask_bias(q_pos, k_pos, window: Optional[int]):
    """[..., Lq, Lk] additive bias: 0 where attendable, NEG_INF otherwise."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# --------------------------------------------------------------------------- #
# naive attention (short sequences, smoke tests)
# --------------------------------------------------------------------------- #


def _naive_attend(q, k, v, q_pos, k_pos, cfg: ModelConfig, window):
    B, Lq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Lq, KV, G, Dh)
    logits = jnp.einsum("blkgd,bmkd->bkglm", qg, k).astype(jnp.float32)
    logits = _softcap(logits * cfg.query_scale, cfg.attn_logit_softcap)
    logits = logits + _mask_bias(q_pos, k_pos, window)[:, None, None]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkglm,bmkd->blkgd", w, v)
    return out.reshape(B, Lq, H, Dh)


# --------------------------------------------------------------------------- #
# flash attention (pure JAX online softmax)
# --------------------------------------------------------------------------- #


def _flash_attend(q, k, v, q_pos, k_pos, cfg: ModelConfig, window):
    """Memory-O(chunk) attention. q [B,Lq,H,Dh]; k,v [B,Lk,KV,Dh]."""
    B, Lq, H, Dh = q.shape
    Lk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = min(cfg.flash_q_chunk, Lq)
    kc = min(cfg.flash_kv_chunk, Lk)
    assert Lq % qc == 0 and Lk % kc == 0, (Lq, qc, Lk, kc)
    nq, nk = Lq // qc, Lk // kc

    qg = q.reshape(B, nq, qc, KV, G, Dh)
    qp = q_pos.reshape(B, nq, qc)
    kg = k.reshape(B, nk, kc, KV, Dh)
    vg = v.reshape(B, nk, kc, KV, Dh)
    kp = k_pos.reshape(B, nk, kc)
    scale = cfg.query_scale
    cap = cfg.attn_logit_softcap

    def per_qchunk(q_blk, qpos_blk):
        # q_blk [B, qc, KV, G, Dh]; qpos_blk [B, qc]
        def kv_step(carry, blk):
            m, l, acc = carry
            k_blk, v_blk, kpos_blk = blk  # [B, kc, KV, Dh], [B, kc]
            s = jnp.einsum("bqkgd,bmkd->bkgqm", q_blk, k_blk).astype(jnp.float32)
            s = _softcap(s * scale, cap)
            bias = _mask_bias(qpos_blk, kpos_blk, window)  # [B, qc, kc]
            ok = (bias > NEG_INF / 2)[:, None, None]       # [B,1,1,qc,kc]
            s = s + bias[:, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            # explicit zeroing: a fully-masked block has s == m_new == -1e30,
            # where exp(s - m_new) would wrongly be 1 (classic online-softmax
            # pitfall caught by tests/test_models.py flash-vs-naive)
            p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqm,bmkd->bkgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        acc0 = jnp.zeros((B, KV, G, qc, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0), jnp.moveaxis(kp, 1, 0)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # [B, qc, KV, G, Dh]

    out = jax.vmap(per_qchunk, in_axes=(1, 1), out_axes=1)(qg, qp)
    return out.reshape(B, Lq, H, Dh).astype(q.dtype)


def _flash_attend_zigzag(q, k, v, q_pos, k_pos, cfg: ModelConfig):
    """Work-balanced causal flash attention (beyond-paper §Perf).

    The masked-full baseline computes nq×nk blocks and throws half away to
    causality. Pairing q-chunk i with q-chunk nq-1-i makes every pair need
    exactly nq+1 kv-blocks (i+1 for the early member, nq-i for the late one),
    so a static-shape scan of nq+1 steps per pair does the *exact* causal
    work: FLOPs drop ~2× at identical results (validated vs naive attention
    in tests/test_models.py). Requires full-causal (no window), Lq == Lk,
    and an even chunk count — callers fall back to _flash_attend otherwise.
    """
    B, Lq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qc = min(cfg.flash_q_chunk, Lq)
    nq = Lq // qc
    kc = qc  # equal chunking keeps the pairing arithmetic exact
    qg = q.reshape(B, nq, qc, KV, G, Dh)
    qp = q_pos.reshape(B, nq, qc)
    kg = k.reshape(B, nq, kc, KV, Dh)
    vg = v.reshape(B, nq, kc, KV, Dh)
    kp = k_pos.reshape(B, nq, kc)
    scale = cfg.query_scale
    cap = cfg.attn_logit_softcap

    def per_pair(p):
        i = p
        j = nq - 1 - p
        q_i = qg[:, i]
        q_j = qg[:, j]
        qp_i = qp[:, i]
        qp_j = qp[:, j]

        def step(carry, t):
            m, l, acc = carry          # [2, B, KV, G, qc(, Dh)]
            late = t > i
            member = late.astype(jnp.int32)
            kv_idx = jnp.where(late, t - (i + 1), t)
            q_blk = jnp.where(late, q_j, q_i)
            qpos_blk = jnp.where(late, qp_j, qp_i)
            k_blk = jax.lax.dynamic_index_in_dim(kg, kv_idx, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vg, kv_idx, 1, keepdims=False)
            kpos_blk = jax.lax.dynamic_index_in_dim(kp, kv_idx, 1, keepdims=False)

            s = jnp.einsum("bqkgd,bmkd->bkgqm", q_blk, k_blk).astype(jnp.float32)
            s = _softcap(s * scale, cap)
            bias = _mask_bias(qpos_blk, kpos_blk, None)
            ok = (bias > NEG_INF / 2)[:, None, None]
            s = s + bias[:, None, None]

            m_sel = m[member]
            l_sel = l[member]
            acc_sel = acc[member]
            m_new = jnp.maximum(m_sel, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_sel - m_new)
            pblk = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
            l_new = l_sel * alpha + jnp.sum(pblk, axis=-1)
            acc_new = acc_sel * alpha[..., None] + jnp.einsum(
                "bkgqm,bmkd->bkgqd", pblk.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            m = m.at[member].set(m_new)
            l = l.at[member].set(l_new)
            acc = acc.at[member].set(acc_new)
            return (m, l, acc), None

        m0 = jnp.full((2, B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((2, B, KV, G, qc), jnp.float32)
        acc0 = jnp.zeros((2, B, KV, G, qc, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, acc0), jnp.arange(nq + 1, dtype=jnp.int32))
        out = acc / jnp.maximum(l[..., None], 1e-30)   # [2,B,KV,G,qc,Dh]
        return jnp.moveaxis(out, 4, 2)                 # [2,B,qc,KV,G,Dh]

    outs = jax.vmap(per_pair, out_axes=1)(jnp.arange(nq // 2))
    # outs [2, nq/2, B, qc, KV, G, Dh] → reassemble chunk order
    early = outs[0]                        # pair p ↔ chunk p
    late = outs[1][::-1]                   # pair p ↔ chunk nq-1-p
    full = jnp.concatenate([early, late], axis=0)  # [nq, B, qc, ...]
    full = jnp.moveaxis(full, 0, 1)        # [B, nq, qc, KV, G, Dh]
    return full.reshape(B, Lq, H, Dh).astype(q.dtype)


# --------------------------------------------------------------------------- #
# KV cache (decode). Ring buffer when S_cache < total positions.
# --------------------------------------------------------------------------- #


def init_cache(batch: int, s_cache: int, cfg: ModelConfig, n_stack: int) -> dict:
    KV, Dh = cfg.num_kv_heads, cfg.head_dim_
    dt = cfg.compute_dtype
    return {
        "k": jnp.zeros((n_stack, batch, s_cache, KV, Dh), dt),
        "v": jnp.zeros((n_stack, batch, s_cache, KV, Dh), dt),
        "pos": jnp.full((n_stack, batch, s_cache), -1, jnp.int32),
    }


def _decode_attend(params, x, positions, cfg: ModelConfig, cache_slice, window):
    """x [B, 1, D]; cache_slice {k,v [B,S,KV,Dh], pos [B,S]}. Ring write."""
    B = x.shape[0]
    S = cache_slice["k"].shape[1]
    angles = rope_lib.rope_angles(positions, cfg.head_dim_, cfg.rope_theta)
    q, k_new, v_new = _project_qkv(params, x, cfg, angles)

    write_idx = (positions[:, 0] % S).astype(jnp.int32)  # [B]
    bidx = jnp.arange(B)
    k_cache = cache_slice["k"].at[bidx, write_idx].set(k_new[:, 0])
    v_cache = cache_slice["v"].at[bidx, write_idx].set(v_new[:, 0])
    pos_cache = cache_slice["pos"].at[bidx, write_idx].set(positions[:, 0])

    KV, Dh, H = cfg.num_kv_heads, cfg.head_dim_, cfg.num_heads
    G = H // KV
    qg = q.reshape(B, KV, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = _softcap(s * cfg.query_scale, cfg.attn_logit_softcap)
    ok = (pos_cache >= 0) & (pos_cache <= positions)  # [B, S]
    if window is not None:
        ok &= (positions - pos_cache) < window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache).reshape(B, 1, H, Dh)
    o = jnp.einsum("blhd,hdo->blo", out, params["wo"].astype(x.dtype))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return o, new_cache


# --------------------------------------------------------------------------- #
# public entry
# --------------------------------------------------------------------------- #


def attention(
    params: dict,
    x: jax.Array,              # [B, L, D]
    positions: jax.Array,      # [B, L] int32 absolute positions
    cfg: ModelConfig,
    *,
    local: bool,
    mode: str,                 # train | prefill | decode
    cache_slice: Optional[dict] = None,
    angles: Optional[jax.Array] = None,  # precomputed (M-RoPE path)
) -> Tuple[jax.Array, Optional[dict]]:
    window = cfg.sliding_window if local else None

    if mode == "decode":
        return _decode_attend(params, x, positions, cfg, cache_slice, window)

    if angles is None:
        angles = rope_lib.rope_angles(positions, cfg.head_dim_, cfg.rope_theta)
    q, k, v = _project_qkv(params, x, cfg, angles)

    L = x.shape[1]
    if pspec.model_divides(cfg.num_heads):
        # tensor parallelism over heads (Megatron): q/k/v head-sharded
        q = pspec.constrain(q, "batch", None, "model", None)
        if pspec.model_divides(cfg.num_kv_heads):
            k = pspec.constrain(k, "batch", None, "model", None)
            v = pspec.constrain(v, "batch", None, "model", None)
    else:
        # sequence-parallel attention: q's sequence dim over `model`; k/v
        # replicated across model ranks (cheap for GQA). Each model rank
        # computes attention for L/model query rows — no score collectives.
        q = pspec.constrain(q, "batch", "model", None, None)
        k = pspec.constrain(k, "batch", None, None, None)
        v = pspec.constrain(v, "batch", None, None, None)
    use_flash = (cfg.attn_impl in ("flash", "latency")) or (
        cfg.attn_impl == "auto" and L >= cfg.flash_threshold
    )
    qc = min(cfg.flash_q_chunk, L)
    # zigzag only where attention is head-TP (or unsharded): under
    # sequence-parallel attention the pair/chunk reshape fights the L-dim
    # sharding (+86% wire measured on gemma2 — EXPERIMENTS.md §Perf)
    mesh_free = pspec._mesh() is None
    zigzag_ok = (
        use_flash and window is None and cfg.attn_impl != "flash"
        and L % qc == 0 and (L // qc) % 2 == 0 and L // qc >= 2
        and (mesh_free or pspec.model_divides(cfg.num_heads))
    )
    if zigzag_ok:
        ctx = _flash_attend_zigzag(q, k, v, positions, positions, cfg)
    elif use_flash:
        ctx = _flash_attend(q, k, v, positions, positions, cfg, window)
    else:
        ctx = _naive_attend(q, k, v, positions, positions, cfg, window)
    out = jnp.einsum("blhd,hdo->blo", ctx, params["wo"].astype(x.dtype))
    # NB: do NOT constrain `out` back to batch-only sharding here — measured
    # on gemma2 train_4k that the eager re-gather costs +31% wire and +27%
    # flops (GSPMD adds pre-wo gathers); deferring lets it pick the cheaper
    # point (EXPERIMENTS.md §Perf gemma2 it3, refuted)

    new_cache = None
    if mode == "prefill":
        assert cache_slice is not None
        S = cache_slice["k"].shape[1]
        # keep the last S positions (ring layout: slot = pos % S)
        if L <= S:
            idx = positions % S  # [B, L]
            bidx = jnp.arange(x.shape[0])[:, None]
            new_cache = {
                "k": cache_slice["k"].at[bidx, idx].set(k),
                "v": cache_slice["v"].at[bidx, idx].set(v),
                "pos": cache_slice["pos"].at[bidx, idx].set(positions),
            }
        else:
            keep = S
            k_tail, v_tail = k[:, -keep:], v[:, -keep:]
            p_tail = positions[:, -keep:]
            idx = p_tail % S
            bidx = jnp.arange(x.shape[0])[:, None]
            new_cache = {
                "k": cache_slice["k"].at[bidx, idx].set(k_tail),
                "v": cache_slice["v"].at[bidx, idx].set(v_tail),
                "pos": cache_slice["pos"].at[bidx, idx].set(p_tail),
            }
    return out, new_cache
