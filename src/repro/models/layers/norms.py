"""RMSNorm variants (plain + gemma's (1+w) form). Param dict style."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(d: int, param_dtype) -> dict:
    return {"scale": jnp.zeros((d,), param_dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float, *, gemma_style: bool = True
            ) -> jax.Array:
    """Computed in f32 for stability, cast back to the input dtype.

    ``gemma_style``: scale is stored zero-centered and applied as (1 + w) —
    matches gemma/llama-modern checkpoints and makes zero-init the identity.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = params["scale"].astype(jnp.float32)
    w = 1.0 + w if gemma_style else w
    return (xf * w).astype(dtype)
