"""Mamba2 SSD (state-space duality) block — chunked matmul form + decode.

Follows the minimal SSD reference (Dao & Gu 2024, alg. listing): sequence is
split into chunks; within-chunk outputs use the quadratic (attention-like)
form via a decay-masked matmul — MXU-friendly, the reason SSD maps well to
TPU — and cross-chunk state is carried by a short lax.scan over chunks.

Decode is the O(1) recurrence: state [B, H, P, N] per layer; no KV cache, no
dependence on context length — this is why the ssm/hybrid archs run the
long_500k cell (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.initializers import dense_init
from repro.models.layers.norms import rmsnorm


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #


def init_mamba(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Din, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    conv_dim = Din + 2 * G * N
    pd = cfg.params_dtype
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * Din + 2 * G * N + H), pd, fan_in=D),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), pd, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), pd),
        "A_log": jnp.zeros((H,), pd),       # A = -exp(A_log) → A=-1 at init
        "D_skip": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "norm_scale": jnp.zeros((Din,), pd),
        "out_proj": dense_init(ks[2], (Din, D), pd, fan_in=Din),
    }


# --------------------------------------------------------------------------- #
# SSD core
# --------------------------------------------------------------------------- #


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., cs] → [..., cs, cs] with out[i,j] = sum_{k=j+1..i} x_k (i ≥ j),
    -inf above the diagonal. Stable cumsum-difference construction."""
    cs = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    i = jnp.arange(cs)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
        chunk: int, init_state: Optional[jax.Array] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.

    x [b,l,h,p]; dt [b,l,h] (post-softplus); A [h] (negative);
    B, C [b,l,g,n] with h % g == 0. Returns (y [b,l,h,p], final_state [b,h,p,n]).
    Computation in f32 throughout (decays are exponentials).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    cs = min(chunk, l)
    pad = (-l) % cs
    if pad:
        # dt=0 padding is an exact identity step: decay exp(0·A)=1 and the
        # input contribution dt·B·x = 0 — state and real outputs unaffected
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l_pad = l + pad
    nc = l_pad // cs

    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bh = jnp.repeat(B.astype(f32), rep, axis=2)  # [b,l,h,n]
    Ch = jnp.repeat(C.astype(f32), rep, axis=2)

    xb = x * dt[..., None]                       # input-scaled x̄
    dA = dt * A.astype(f32)[None, None, :]       # [b,l,h] log-decay per step

    # chunked views: [b, nc, cs, ...]
    xc = xb.reshape(b, nc, cs, h, p)
    Bc = Bh.reshape(b, nc, cs, h, n)
    Cc = Ch.reshape(b, nc, cs, h, n)
    dAc = dA.reshape(b, nc, cs, h)

    dA_cum = jnp.cumsum(dAc, axis=2)             # [b,nc,cs,h]
    dA_total = dA_cum[:, :, -1]                  # [b,nc,h]

    # ---- intra-chunk (quadratic/attention-like form) -------------------- #
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dAc, 3, 2)))  # [b,nc,h,cs,cs]
    scores = jnp.einsum("bzihn,bzjhn->bzhij", Cc, Bc)  # [b,nc,h,cs,cs]
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", scores * Lmat, xc)

    # ---- chunk boundary states ----------------------------------------- #
    decay_to_end = jnp.exp(dA_total[:, :, None, :] - dA_cum)   # [b,nc,cs,h]
    chunk_states = jnp.einsum("bzchn,bzch,bzchp->bzhpn", Bc, decay_to_end, xc)

    # ---- inter-chunk recurrence over nc --------------------------------- #
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)

    def chunk_step(carry, inp):
        s_prev = carry                            # [b,h,p,n]
        states_z, total_z = inp                   # [b,h,p,n], [b,h]
        s_new = s_prev * jnp.exp(total_z)[:, :, None, None] + states_z
        return s_new, s_prev

    (final_state, prev_states) = jax.lax.scan(
        chunk_step, init_state.astype(f32),
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(dA_total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [b,nc,h,p,n] state entering chunk

    # ---- inter-chunk contribution to outputs ---------------------------- #
    state_decay = jnp.exp(dA_cum)                  # [b,nc,cs,h]
    y_off = jnp.einsum("bzchn,bzhpn,bzch->bzchp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l_pad, h, p)[:, :l]
    return y, final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. state [b,h,p,n]; x [b,h,p]; dt [b,h];
    B, C [b,g,n]. Returns (y [b,h,p], new_state)."""
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    rep = h // g
    f32 = jnp.float32
    Bh = jnp.repeat(B.astype(f32), rep, axis=1)   # [b,h,n]
    Ch = jnp.repeat(C.astype(f32), rep, axis=1)
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])  # [b,h]
    xb = x.astype(f32) * dt.astype(f32)[..., None]
    new_state = state * dA[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xb, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


# --------------------------------------------------------------------------- #
# full mamba2 block
# --------------------------------------------------------------------------- #


def init_ssm_cache(batch: int, cfg: ModelConfig, n_stack: int) -> dict:
    Din, N, G, H, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups,
                       cfg.ssm_nheads, cfg.ssm_headdim)
    conv_dim = Din + 2 * G * N
    return {
        "ssm": jnp.zeros((n_stack, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((n_stack, batch, cfg.ssm_conv - 1, conv_dim),
                          jnp.dtype(cfg.dtype)),
    }


def _split_proj(z_x_bc_dt: jax.Array, cfg: ModelConfig):
    Din, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z = z_x_bc_dt[..., :Din]
    x = z_x_bc_dt[..., Din:2 * Din]
    B = z_x_bc_dt[..., 2 * Din:2 * Din + G * N]
    C = z_x_bc_dt[..., 2 * Din + G * N:2 * Din + 2 * G * N]
    dt = z_x_bc_dt[..., 2 * Din + 2 * G * N:]
    return z, x, B, C, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv via shifted adds (width ≤ 4 ⇒ cheaper than
    conv_general for these shapes, and trivially deterministic).

    xbc [b, l, c]; w [k, c]; tail [b, k-1, c] (decode/prefill continuation).
    """
    kw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[2]), xbc.dtype)
    padded = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    L = xbc.shape[1]
    for i in range(kw):
        out = out + padded[:, i:i + L] * w[i].astype(xbc.dtype)
    return out + bias.astype(xbc.dtype)


def mamba_block(params: dict, h: jax.Array, cfg: ModelConfig, *,
                mode: str, cache_slice: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    """h [B, L, D] → [B, L, D]; cache carries (ssm state, conv tail)."""
    B_, L, D = h.shape
    dtype = h.dtype
    Din, N, G, H, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups,
                       cfg.ssm_nheads, cfg.ssm_headdim)
    proj = h @ params["in_proj"].astype(dtype)
    z, x, Bs, Cs, dt = _split_proj(proj, cfg)
    xbc = jnp.concatenate([x, Bs, Cs], axis=-1)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    new_cache = None
    if mode == "decode":
        assert cache_slice is not None
        tail = cache_slice["conv"]                      # [B, k-1, conv_dim]
        xbc_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], tail)
        xbc_conv = jax.nn.silu(xbc_conv)
        new_tail = jnp.concatenate([tail, xbc.astype(tail.dtype)], axis=1)[:, 1:]
        xc = xbc_conv[..., :Din].reshape(B_, Din // P, P)     # L == 1 squeezed
        Bc = xbc_conv[..., Din:Din + G * N].reshape(B_, G, N)
        Cc = xbc_conv[..., Din + G * N:].reshape(B_, G, N)
        y, new_state = ssd_decode_step(
            cache_slice["ssm"], xc, dt[:, 0], A, Bc, Cc
        )
        y = y + params["D_skip"].astype(jnp.float32)[None, :, None] * xc.astype(jnp.float32)
        y = y.reshape(B_, 1, Din)
        new_cache = {"ssm": new_state, "conv": new_tail}
    else:
        xbc_conv = jax.nn.silu(
            _causal_conv(xbc, params["conv_w"], params["conv_b"])
        )
        xc = xbc_conv[..., :Din].reshape(B_, L, H, P)
        Bc = xbc_conv[..., Din:Din + G * N].reshape(B_, L, G, N)
        Cc = xbc_conv[..., Din + G * N:].reshape(B_, L, G, N)
        y, final_state = ssd(xc, dt, A, Bc, Cc, cfg.ssm_chunk)
        y = y + params["D_skip"].astype(jnp.float32)[None, None, :, None] \
            * xc.astype(jnp.float32)
        y = y.reshape(B_, L, Din)
        if mode == "prefill":
            assert cache_slice is not None
            kw = cfg.ssm_conv
            new_cache = {
                "ssm": final_state,
                "conv": xbc[:, -(kw - 1):].astype(cache_slice["conv"].dtype),
            }

    # gated RMSNorm then out projection (mamba2 block epilogue)
    y = y.astype(dtype) * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.rms_eps)
    out = y @ params["out_proj"].astype(dtype)
    return out, new_cache
