"""The full LM: init / apply / prefill / decode for every assigned family.

Layer stacks are scanned (``lax.scan`` over stacked params), keeping HLO size
independent of depth — an 88-layer granite-34b compiles as fast as a 2-layer
smoke model, which the 512-device dry-run depends on.

Stack patterns by family:
  dense/vlm/audio/moe : uniform [L] stack, or [L/2]×(local, global) pairs
                        when attn_pattern == local_global (gemma2)
  ssm                 : uniform [L] mamba stack
  hybrid (zamba2)     : [n_groups] × (shared attn block (alternating 2) +
                        [period] mamba layers)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models import pspec
from repro.models.config import ModelConfig
from repro.models.initializers import embed_init
from repro.models.layers import attention as attn_lib
from repro.models.layers import rope as rope_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.norms import init_rmsnorm, rmsnorm

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def _stack_init(init_fn, key, n: int):
    """vmap an init function over n layer keys → stacked param leaves."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    k_embed, k_layers, k_head, k_shared = jax.random.split(key, 4)
    p: Params = {}
    # vocab rows padded to vocab_pad_multiple so embedding/head shard over the
    # model axis (MaxText practice); padded logits are masked in _head.
    p["embed"] = embed_init(k_embed, (cfg.padded_vocab, cfg.d_model),
                            cfg.params_dtype)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if cfg.attn_pattern == "local_global":
            assert cfg.num_layers % 2 == 0
            p["blocks"] = {
                "a": _stack_init(lambda k: blk.init_decoder_block(k, cfg),
                                 k_layers, cfg.num_layers // 2),
                "b": _stack_init(lambda k: blk.init_decoder_block(k, cfg),
                                 jax.random.fold_in(k_layers, 1),
                                 cfg.num_layers // 2),
            }
        else:
            p["blocks"] = _stack_init(lambda k: blk.init_decoder_block(k, cfg),
                                      k_layers, cfg.num_layers)
    elif cfg.family == "ssm":
        p["blocks"] = _stack_init(lambda k: blk.init_mamba_layer(k, cfg),
                                  k_layers, cfg.num_layers)
    elif cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid_period
        assert n_groups * cfg.hybrid_period == cfg.num_layers

        def group_init(k):
            return _stack_init(lambda kk: blk.init_mamba_layer(kk, cfg), k,
                               cfg.hybrid_period)

        p["blocks"] = _stack_init(group_init, k_layers, n_groups)
        p["shared"] = _stack_init(lambda k: blk.init_decoder_block(k, cfg),
                                  k_shared, cfg.num_shared_blocks)
    else:
        raise ValueError(cfg.family)

    p["final_norm"] = init_rmsnorm(cfg.d_model, cfg.params_dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                  cfg.params_dtype)
    return p


# --------------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------------- #


def init_caches(cfg: ModelConfig, batch: int, s_cache: int) -> Any:
    """Decode-state pytree matching the stack pattern. ``s_cache`` is the
    max context; sliding-window layers allocate min(window, s_cache)."""
    w = min(cfg.sliding_window, s_cache)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if cfg.attn_pattern == "local_global":
            half = cfg.num_layers // 2
            return {
                "a": attn_lib.init_cache(batch, w, cfg, half),
                "b": attn_lib.init_cache(batch, s_cache, cfg, half),
            }
        s = w if cfg.attn_pattern == "swa" else s_cache
        return attn_lib.init_cache(batch, s, cfg, cfg.num_layers)
    if cfg.family == "ssm":
        return ssm_lib.init_ssm_cache(batch, cfg, cfg.num_layers)
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid_period
        return {
            "mamba": jax.tree.map(
                lambda x: x.reshape((n_groups, cfg.hybrid_period) + x.shape[1:]),
                ssm_lib.init_ssm_cache(batch, cfg, cfg.num_layers),
            ),
            "shared": attn_lib.init_cache(batch, s_cache, cfg, n_groups),
        }
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------- #
# trunk
# --------------------------------------------------------------------------- #


def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    if cfg.remat == "block" and mode == "train":
        return jax.checkpoint(fn)
    return fn


def _run_stack(params: Params, h: jax.Array, positions: jax.Array,
               cfg: ModelConfig, mode: str, caches: Any,
               angles: Optional[jax.Array]) -> Tuple[jax.Array, Any, jax.Array]:
    """Dispatch on family/pattern; returns (h, new_caches, aux_sum)."""
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if cfg.attn_pattern == "local_global":
            def step(h, xs):
                pa, pb, ca, cb = xs
                h, nca, aux_a = blk.decoder_block(
                    pa, h, positions, cfg, local=True, mode=mode,
                    cache_slice=ca, angles=angles)
                h, ncb, aux_b = blk.decoder_block(
                    pb, h, positions, cfg, local=False, mode=mode,
                    cache_slice=cb, angles=angles)
                return h, (nca, ncb, aux_a + aux_b)

            xs = (params["blocks"]["a"], params["blocks"]["b"],
                  caches["a"] if caches else _none_like(params["blocks"]["a"]),
                  caches["b"] if caches else _none_like(params["blocks"]["b"]))
            h, (nca, ncb, aux) = jax.lax.scan(_maybe_remat(step, cfg, mode), h, xs)
            new_caches = {"a": nca, "b": ncb} if caches else None
            return h, new_caches, jnp.sum(aux)

        local = cfg.attn_pattern == "swa"

        def step(h, xs):
            pl_, cs = xs
            h, nc, aux = blk.decoder_block(
                pl_, h, positions, cfg, local=local, mode=mode,
                cache_slice=cs, angles=angles)
            return h, (nc, aux)

        xs = (params["blocks"],
              caches if caches else _none_like(params["blocks"]))
        h, (nc, aux) = jax.lax.scan(_maybe_remat(step, cfg, mode), h, xs)
        return h, (nc if caches else None), jnp.sum(aux)

    if cfg.family == "ssm":
        def step(h, xs):
            pl_, cs = xs
            h, nc = blk.mamba_layer(pl_, h, cfg, mode=mode, cache_slice=cs)
            return h, nc

        xs = (params["blocks"], caches if caches else _none_like(params["blocks"]))
        h, nc = jax.lax.scan(_maybe_remat(step, cfg, mode), h, xs)
        return h, (nc if caches else None), jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid_period
        shared = params["shared"]

        def group_step(h, xs):
            g_idx, p_group, c_mamba, c_shared = xs
            p_shared = jax.tree.map(
                lambda x: x[g_idx % cfg.num_shared_blocks], shared
            )
            h, nc_shared, _ = blk.decoder_block(
                p_shared, h, positions, cfg, local=False, mode=mode,
                cache_slice=c_shared, angles=angles)

            def inner(h, ys):
                p_l, c_l = ys
                h, nc = blk.mamba_layer(p_l, h, cfg, mode=mode, cache_slice=c_l)
                return h, nc

            h, nc_mamba = jax.lax.scan(inner, h, (p_group, c_mamba))
            return h, (nc_mamba, nc_shared)

        xs = (jnp.arange(n_groups, dtype=jnp.int32), params["blocks"],
              caches["mamba"] if caches
              else jnp.zeros((n_groups, cfg.hybrid_period, 0), jnp.int32),
              caches["shared"] if caches else _none_like2(n_groups))
        h, (ncm, ncs) = jax.lax.scan(_maybe_remat(group_step, cfg, mode), h, xs)
        new_caches = {"mamba": ncm, "shared": ncs} if caches else None
        return h, new_caches, jnp.zeros((), jnp.float32)

    raise ValueError(cfg.family)


def _none_like(stacked: Any):
    """Scan needs a pytree with a leading axis even when caches are unused."""
    any_leaf = jax.tree_util.tree_leaves(stacked)[0]
    n = any_leaf.shape[0]
    return jnp.zeros((n, 0), jnp.int32)


def _none_like2(n: int):
    return jnp.zeros((n, 0), jnp.int32)


# --------------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------------- #


def _embed(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
           ) -> jax.Array:
    if cfg.external_embeddings:
        return batch["embeds"].astype(cfg.compute_dtype)
    h = params["embed"][batch["tokens"]].astype(cfg.compute_dtype)
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return pspec.constrain(h, "batch", None, None)


def _head(params: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rmsnorm(params["final_norm"], h, cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", h, params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("bld,dv->blv", h, params["lm_head"].astype(h.dtype))
    logits = pspec.constrain(logits, "batch", None, "model")
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab != cfg.vocab_size:
        # padded rows never win: mask to a large negative (keeps softmax exact)
        v = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
        logits = jnp.where(v[None, None, :] < cfg.vocab_size, logits, -1e30)
    return logits


def _angles_for(batch: Dict[str, jax.Array], positions: jax.Array,
                cfg: ModelConfig) -> Optional[jax.Array]:
    if cfg.rope_type != "mrope":
        return None
    pos3 = batch.get("positions_3d")
    if pos3 is None:
        pos3 = rope_lib.text_positions_3d(positions)
    return rope_lib.mrope_angles(pos3, cfg.head_dim_, cfg.rope_theta,
                                 cfg.mrope_sections)


def apply(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array]:
    """Training/eval forward: full-sequence logits. Returns (logits, aux)."""
    h = _embed(params, batch, cfg)
    B, L = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    angles = _angles_for(batch, positions, cfg)
    h, _, aux = _run_stack(params, h, positions, cfg, "train", None, angles)
    return _head(params, h, cfg), aux


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (labels = tokens shifted by the caller) + MoE aux."""
    logits, aux = apply(params, batch, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    # CE without take_along_axis: gathering along the vocab axis would force
    # GSPMD to all-gather the (vocab-sharded) logits — ~67 GB/step for gemma2.
    # iota-compare + masked reduce keeps everything vocab-local; only the
    # [B, L] partials cross the model axis.
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(
        jnp.where(v == safe[..., None], logits, 0.0), axis=-1
    )
    nll = lse - picked
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    total = ce + 0.01 * aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            s_cache: int) -> Tuple[jax.Array, Any]:
    """Process a prompt; return (last-position logits [B, V], caches)."""
    h = _embed(params, batch, cfg)
    B, L = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    angles = _angles_for(batch, positions, cfg)
    caches = init_caches(cfg, B, s_cache)
    h, caches, _ = _run_stack(params, h, positions, cfg, "prefill", caches, angles)
    logits = _head(params, h[:, -1:], cfg)
    return logits[:, 0], caches


def decode_step(params: Params, caches: Any, tokens: jax.Array,
                positions: jax.Array, cfg: ModelConfig,
                embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Any]:
    """One decode step. tokens [B, 1] (or embeds [B, 1, D]); positions [B, 1].
    Returns (logits [B, V], new caches)."""
    batch = {"tokens": tokens} if embeds is None else {"embeds": embeds}
    h = _embed(params, batch, cfg)
    angles = None  # decode uses text positions; mrope reduces to rope
    h, caches, _ = _run_stack(params, h, positions, cfg, "decode", caches, angles)
    logits = _head(params, h, cfg)
    return logits[:, 0], caches
