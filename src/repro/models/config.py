"""Model configuration schema covering the 10 assigned architectures.

One dataclass drives every family (dense / ssm / moe / vlm / audio / hybrid);
family-specific fields are ignored elsewhere. Configs in repro.configs fill
these with the exact published dimensions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | moe | vlm | audio | hybrid

    # trunk
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 → d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # attention behaviour
    attn_pattern: str = "full"       # full | swa | local_global
    sliding_window: int = 4096       # window for swa / local layers
    attn_logit_softcap: float = 0.0  # 0 = off (gemma2: 50.0)
    final_logit_softcap: float = 0.0  # (gemma2: 30.0)
    qkv_bias: bool = False           # qwen-family
    query_scale_dim: int = 0         # 0 → head_dim (gemma2-2b: 256)
    rope_theta: float = 10_000.0
    rope_type: str = "rope"          # rope | mrope
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # mlp
    activation: str = "swiglu"       # swiglu | geglu | gelu_mlp
    # norms
    rms_eps: float = 1e-6
    norm_style: str = "pre"          # pre | pre_post (gemma2 sandwich norms)

    # embeddings
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma2: embed * sqrt(d_model)
    external_embeddings: bool = False  # vlm/audio stub: inputs are embeddings

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2)
    hybrid_period: int = 6           # mamba layers per shared-attention hit
    num_shared_blocks: int = 2       # alternating shared attention blocks

    # compute
    vocab_pad_multiple: int = 256    # pad embedding/head rows for TP (MaxText
                                     # practice); padded logits masked to -inf
    dtype: str = "bfloat16"          # activations/compute
    param_dtype: str = "float32"     # master params
    remat: str = "block"             # none | block
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 1024
    flash_threshold: int = 2048      # use flash attention for seq ≥ this
    attn_impl: str = "auto"          # auto | flash | naive | latency(2-pass balanced)

    # --------------------------------------------------------------- #
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def padded_experts(self) -> int:
        """Experts padded to a TP-width multiple so EP shards cleanly
        (granite-moe's 40 → 48). Padded experts get -inf router logits and
        are never routed to; their (zero-init) weights are dead weight, the
        standard price for even sharding."""
        e = self.num_experts
        if e == 0 or e <= 16 or e % 16 == 0:
            return e
        return ((e + 15) // 16) * 16

    @property
    def query_scale(self) -> float:
        d = self.query_scale_dim or self.head_dim_
        return d ** -0.5

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def layer_is_local(self, layer_idx: int) -> bool:
        """local_global pattern: even layers local (sliding), odd global."""
        if self.attn_pattern == "swa":
            return True
        if self.attn_pattern == "local_global":
            return layer_idx % 2 == 0
        return False

    # parameter counting (used by roofline MODEL_FLOPS) ---------------- #
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, Dh = self.num_heads, self.num_kv_heads, self.head_dim_
        n = V * D  # embedding
        if not self.tie_embeddings and not self.external_embeddings:
            n += V * D  # lm_head
        if self.family in ("dense", "vlm", "audio", "moe"):
            attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
            if self.qkv_bias:
                attn += (H + 2 * KV) * Dh
            if self.family == "moe":
                E, Fe = self.num_experts, self.expert_d_ff
                ff = D * E + E * (2 * D * Fe + Fe * D)
            else:
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                ff = mult * D * F
            norms = 2 * D if self.norm_style == "pre" else 4 * D
            n += L * (attn + ff + norms)
        elif self.family == "ssm":
            n += L * self._mamba_block_params()
        elif self.family == "hybrid":
            n += L * self._mamba_block_params()
            attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
            ff = 3 * D * F
            n += self.num_shared_blocks * (attn + ff + 2 * D)
        n += D  # final norm
        return n

    def _mamba_block_params(self) -> int:
        D, Din, N, G, P = (self.d_model, self.d_inner, self.ssm_state,
                           self.ssm_ngroups, self.ssm_headdim)
        H = self.ssm_nheads
        in_proj = D * (2 * Din + 2 * G * N + H)
        conv = self.ssm_conv * (Din + 2 * G * N)
        out = Din * D
        extras = 2 * H + Din  # A_log, D skip, norm-ish
        return in_proj + conv + out + extras + D

    def active_param_count(self) -> int:
        """Active params per token (= dense count except MoE top-k subset)."""
        if self.family != "moe":
            return self.param_count()
        E, k = self.num_experts, self.num_experts_per_tok
        Fe, D, L = self.expert_d_ff, self.d_model, self.num_layers
        total = self.param_count()
        inactive = L * (E - k) * (2 * D * Fe + Fe * D)
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: an input shape + which step it lowers."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}
