"""Deterministic parameter initializers (explicit dtypes, truncated normal)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def dense_init(key, shape, param_dtype, *, fan_in: int | None = None):
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[-2] default)."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(param_dtype)


def embed_init(key, shape, param_dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(param_dtype)
