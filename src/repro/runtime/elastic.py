"""Elastic re-meshing: recompute a coherent mesh after node loss/join.

At 1000+ node scale, single-node failures are routine; the recovery path is
  1. detect (heartbeat miss / XLA error),
  2. pick the largest supported mesh that fits the surviving chips,
  3. re-lower the step for the new mesh (shardings are divisibility-aware,
     so every mesh from this planner is valid for every arch),
  4. restore the latest deterministic checkpoint and continue — the data
     pipeline is step-indexed and dp_size-invariant (pipeline.py), so the
     global batch order is IDENTICAL post-resize: bitwise-reproducible
     elastic training, which is the paper's replay property at cluster scale.

The planner prefers shrinking the `data` axis (pure DP — no re-partition of
params across a different TP width ⇒ cheapest restart), then `pod`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_chips: int

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_remesh(available_chips: int, *, model: int = 16,
                prefer_pods: Optional[int] = None) -> ElasticPlan:
    """Largest (pod, data, model) mesh with ≤ available chips.

    `model` (TP width) is held fixed: changing it would re-partition every
    weight; `data`/`pod` shrink instead. data is kept a power of two so the
    step-indexed pipeline keeps dividing global_batch evenly.
    """
    if available_chips < model:
        raise ValueError(
            f"cannot keep TP width {model} with {available_chips} chips")
    best: Optional[ElasticPlan] = None
    max_pods = prefer_pods or max(available_chips // model, 1)
    for pods in range(max_pods, 0, -1):
        per_pod = available_chips // pods
        data = 1
        while data * 2 * model <= per_pod:
            data *= 2
        if data < 1:
            continue
        used = pods * data * model
        plan = (
            ElasticPlan((pods, data, model), ("pod", "data", "model"),
                        available_chips - used)
            if pods > 1 else
            ElasticPlan((data, model), ("data", "model"),
                        available_chips - used)
        )
        if best is None or plan.size > best.size:
            best = plan
    assert best is not None
    return best
