"""Fault-tolerant coordinators: training checkpoint/restart and serve-side
primary failover by replica promotion.

Wraps the train loop with the large-scale survival kit:
  * periodic deterministic checkpoints (hash-manifested, Valori semantics);
  * failure detection hooks (in production: heartbeat / JAX distributed
    errors; in tests: injected via `failure_injector`);
  * restart path: elastic remesh (elastic.py) → checkpoint restore →
    step-indexed data pipeline resumes bit-identically;
  * straggler mitigation policy: synchronous steps with a deadline; ranks
    that exceed `deadline_factor` × median step time get flagged, and after
    `evict_after` consecutive flags the coordinator treats the rank as
    failed and triggers the elastic path (the standard "fail-slow = fail"
    doctrine). On a single-host dry run, timings come from the host clock;
    the policy logic is exercised by tests with synthetic timings.

The loop itself is deliberately simple: all the intelligence lives in the
substrate (deterministic data order, hashable state, divisibility-aware
shardings) — which is the paper's thesis: make the state machine
deterministic and recovery becomes trivial replay.

The second half of this module is the *serving* failover coordinator
(DESIGN.md §9): when a primary shard host dies (``TransportError`` / dead
subprocess), ``promote_on_primary_loss`` picks the surviving replica with
the max proven durable cursor, proves the takeover with one ``state_hash``
comparison against the durable prefix (per surviving straggler), and
promotes that replica's WAL as the new primary prefix — no replay, because
every record in a replica's WAL was hash-verified against the old primary
before it touched disk. ``promote_sharded`` runs one promotion per shard
and then reconciles the promoted fleet to one global cursor through the
existing ``ShardedDurableStore.recover()`` min-cursor rule (ahead shards
roll back), so a staggered failover lands on exactly the durable prefix
every shard can prove.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0   # step slower than 3x median = flagged
    evict_after: int = 3           # consecutive flags before eviction
    window: int = 20               # median window


@dataclasses.dataclass
class RunConfig:
    total_steps: int
    checkpoint_every: int = 100
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    straggler: StragglerPolicy = dataclasses.field(default_factory=StragglerPolicy)
    max_restarts: int = 8


class Coordinator:
    """Drives (state, batch) -> state steps with checkpoint/restart."""

    def __init__(
        self,
        run: RunConfig,
        train_step: Callable,          # (train_state, batch) -> (train_state, metrics)
        batch_fn: Callable[[int], Any],  # step -> batch (deterministic!)
        init_state_fn: Callable[[], Any],
        failure_injector: Optional[Callable[[int], Optional[str]]] = None,
        on_restart: Optional[Callable[[int], None]] = None,
    ):
        self.run = run
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.init_state_fn = init_state_fn
        self.failure_injector = failure_injector
        self.on_restart = on_restart
        self.ckpt = CheckpointManager(run.checkpoint_dir,
                                      keep=run.keep_checkpoints,
                                      async_save=False)
        self.step_times: List[float] = []
        self.flag_counts: Dict[int, int] = {}
        self.restarts = 0
        self.events: List[dict] = []

    # ------------------------------------------------------------------ #
    def _check_stragglers(self, rank_times: Dict[int, float]) -> List[int]:
        """Returns ranks to evict under the fail-slow policy."""
        pol = self.run.straggler
        if len(rank_times) < 2:
            return []
        med = statistics.median(rank_times.values())
        evict = []
        for rank, t in rank_times.items():
            if t > pol.deadline_factor * max(med, 1e-9):
                self.flag_counts[rank] = self.flag_counts.get(rank, 0) + 1
                if self.flag_counts[rank] >= pol.evict_after:
                    evict.append(rank)
            else:
                self.flag_counts[rank] = 0
        return evict

    # ------------------------------------------------------------------ #
    def train(self, rank_times_fn: Optional[Callable[[int], Dict[int, float]]]
              = None) -> Any:
        """Run to completion, surviving injected failures."""
        state = None
        step = 0
        proto = self.init_state_fn()
        restored = self.ckpt.restore_latest(proto)
        if restored is not None:
            state, step, _ = restored
            self.events.append({"event": "resume", "step": step})
        else:
            state = proto

        while step < self.run.total_steps:
            try:
                if state is None:
                    state = self.init_state_fn()
                fail = self.failure_injector(step) if self.failure_injector else None
                if fail:
                    raise RuntimeError(f"injected failure: {fail}")

                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state, metrics = self.train_step(state, batch)
                self.step_times.append(time.monotonic() - t0)

                if rank_times_fn is not None:
                    evict = self._check_stragglers(rank_times_fn(step))
                    if evict:
                        self.events.append(
                            {"event": "straggler_evict", "ranks": evict,
                             "step": step})
                        raise RuntimeError(f"stragglers evicted: {evict}")

                step += 1
                if step % self.run.checkpoint_every == 0 or \
                        step == self.run.total_steps:
                    self.ckpt.save(state, step)
                    self.events.append({"event": "checkpoint", "step": step})
            except Exception as e:  # noqa: BLE001 — the recovery path IS the feature
                self.restarts += 1
                self.events.append({"event": "failure", "step": step,
                                    "error": str(e)})
                if self.restarts > self.run.max_restarts:
                    raise
                if self.on_restart:
                    self.on_restart(self.restarts)
                restored = self.ckpt.restore_latest(self.init_state_fn())
                if restored is None:
                    state, step = None, 0
                else:
                    state, step, _ = restored
                self.events.append({"event": "restart", "from_step": step})
        return state


# --------------------------------------------------------------------------- #
# serve-side failover: promotion of a verified replica (DESIGN.md §9)
# --------------------------------------------------------------------------- #


def proven_cursor(replica) -> int:
    """The cursor a replica can *prove*: its own durable WAL cursor (every
    appended slice was hash-verified against the primary before it touched
    disk — the verify-then-append discipline in net/replica.py). A
    SIGKILLed replica may hold one verified slice its in-memory state never
    committed; the WAL is authoritative, so that slice still counts."""
    if replica.store is None:
        raise ValueError("an in-memory follower has no proven durable "
                         "prefix to promote")
    return replica.store.t


def promote_on_primary_loss(replicas, *, ef_construction: int = 32,
                            epoch: Optional[int] = None):
    """Failover for one shard: promote the best surviving replica.

    1. Pick the replica with the **max proven durable cursor** — acked
       work is never lost (every acked cursor <= some replica's proven
       cursor), and the old primary's unshipped suffix is never
       resurrected (nothing past the max proven cursor survives).
    2. Prove the takeover: for each surviving straggler, the winner's
       durable prefix at the straggler's committed cursor must hash to the
       straggler's proven ``state_hash()`` — one ``state_hash`` comparison
       against the durable prefix per survivor. A tampered WAL (winner or
       straggler) breaks this and the promotion is **refused** with
       ``ReplicaDivergence``: a primary that cannot prove its prefix never
       serves.
    3. ``promote()`` the winner: its store, verified state and side-table
       mirror become a ``ShardHost`` with no replay (one lockstep + hash
       check). ``epoch``, when given, stamps the promoted host with the
       new fleet epoch durably (DESIGN.md §12) — promotion IS an epoch
       change, so the dead primary's clients are fenced the moment the
       new one serves.

    Returns ``(host, winner_index, t)``.
    """
    from repro.net.replica import ReplicaDivergence

    replicas = list(replicas)
    if not replicas:
        raise ValueError("no surviving replicas to promote")
    cursors = [proven_cursor(r) for r in replicas]
    winner_idx = int(np.argmax(cursors))
    winner = replicas[winner_idx]
    t = cursors[winner_idx]
    # reconcile the winner's crash window first (WAL may be one verified
    # slice ahead of the committed state) so the prefix checks below read
    # the durable truth
    if winner.store.t != winner.t:
        winner.state, winner._hash, winner.t = winner.store.recover(
            ef_construction=ef_construction)
    for i, straggler in enumerate(replicas):
        if i == winner_idx:
            continue
        st = straggler.t  # committed (acked) cursor: proven at both ends
        expect = straggler.state_hash()
        got = winner.store.restore_at(st, ef_construction=ef_construction)[1]
        if got != expect:
            raise ReplicaDivergence(
                f"promotion refused: winner (replica {winner.replica_id}) "
                f"prefix at t={st} hashes to {got:#x}, surviving replica "
                f"{straggler.replica_id} proved {expect:#x} — a WAL was "
                "tampered with or replication diverged")
    return winner.promote(epoch=epoch), winner_idx, t


def promote_sharded(directory, replica_sets, *, ef_construction: int = 32,
                    epoch: Optional[int] = None):
    """Failover for a sharded fleet: one promotion per shard, then the
    promoted hosts are reconciled to **one global cursor** through the
    existing ``ShardedDurableStore.recover()`` min-cursor rule — per-shard
    winners at staggered cursors roll the ahead shards back, exactly the
    crash-reconciliation path local shards already take.

    ``directory`` is the coordinator's own store dir (holds ``store.json``
    and the merged-hash records); ``replica_sets[s]`` is the list of
    surviving replicas of shard ``s``. Returns
    ``(store, state, state_hash, t, hosts)`` — the reconciled sharded
    store over the promoted hosts and its recovered global state."""
    from repro.core.shard_wal import ShardedDurableStore
    from repro.net.client import LocalTransport, RemoteShardClient

    hosts = []
    for shard_replicas in replica_sets:
        host, _, _ = promote_on_primary_loss(
            shard_replicas, ef_construction=ef_construction, epoch=epoch)
        hosts.append(host)
    store = ShardedDurableStore(
        directory, backends=[RemoteShardClient(LocalTransport(h))
                             for h in hosts])
    state, state_hash, t = store.recover(ef_construction=ef_construction)
    return store, state, state_hash, t, hosts


# --------------------------------------------------------------------------- #
# lease-based failure detection → automatic verified promotion (DESIGN.md §12)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    """The lease the detector extends on every answered heartbeat.

    A primary holds its lease while it answers HEARTBEAT frames; after
    ``lease_misses`` consecutive unanswered beats (each bounded by the
    transport's timeout — a wedged host times out, it does not hang the
    detector) the lease is expired and failover triggers. ``interval_s``
    paces the optional background thread; ``poll()`` callers pace
    themselves (tests drive the detector deterministically)."""
    interval_s: float = 0.25
    lease_misses: int = 3


class FailureDetector:
    """Heartbeats primary shard hosts; expires leases; auto-promotes.

    ``probes[s]`` is a client with the replication surface's
    ``heartbeat(node_id=...)`` verb (a ``RemoteShardClient``, usually on
    its own connection so a wedged data path cannot starve the lease
    path); ``replica_sets[s]`` is the list of surviving replicas of shard
    ``s`` to promote from when shard ``s``'s lease expires.

    The detector owns the **fleet epoch**: every beat stamps the probed
    host with it (hosts adopt a greater epoch durably), and a promotion
    bumps it first — so the promoted host starts fenced against the dead
    regime's writers, and a *revived* old primary is stamped by the very
    first beat that reaches it, after which its pre-failover clients'
    APPENDs are refused with ``StaleEpochError`` (the fencing invariant:
    at most one epoch's writers can ever commit, and it is the newest
    proven one).

    One-shot per shard: an expired shard promotes once
    (``promote_on_primary_loss`` — every promotion is verified: max
    proven WAL prefix wins, stragglers must hash-match it, divergence
    refuses) and the result lands in ``promoted[s]``; a fleet-wide
    coordinator can instead pass ``sharded_dir`` to reconcile ALL shards
    through ``promote_sharded`` on the first expiry. ``poll()`` runs one
    deterministic round; ``start()`` runs it on a daemon thread every
    ``interval_s``."""

    def __init__(self, probes, replica_sets, *, lease: LeaseConfig = None,
                 epoch: int = 1, node_id: int = 0,
                 sharded_dir: Optional[str] = None,
                 ef_construction: int = 32):
        self.probes = list(probes)
        self.replica_sets = [list(rs) for rs in replica_sets]
        if len(self.probes) != len(self.replica_sets):
            raise ValueError(
                f"{len(self.probes)} probes but "
                f"{len(self.replica_sets)} replica sets")
        self.lease = lease or LeaseConfig()
        self.epoch = int(epoch)
        self.node_id = node_id
        self.sharded_dir = sharded_dir
        self.ef_construction = ef_construction
        self.misses = [0] * len(self.probes)
        self.promoted: Dict[int, Any] = {}   # shard -> promoted ShardHost
        self.sharded_result = None           # promote_sharded(...) tuple
        self.events: List[dict] = []
        self._thread = None
        self._stop = None

    def expired(self, shard: int) -> bool:
        return self.misses[shard] >= self.lease.lease_misses

    def poll(self) -> Dict[int, Any]:
        """One detection round: beat every un-promoted shard, expire
        leases, promote where expired. Returns ``promoted``."""
        from repro.net import protocol as p
        for s, probe in enumerate(self.probes):
            if s in self.promoted or self.sharded_result is not None:
                continue
            try:
                # stamp the probe with the fleet epoch first: the beat is
                # what fences a revived old primary (hosts adopt durably)
                bump = getattr(probe, "bump_epoch", None)
                if bump is not None:
                    bump(self.epoch)
                t, host_epoch, h = probe.heartbeat(node_id=self.node_id)
            except (p.TransportError, p.ProtocolError) as e:
                self.misses[s] += 1
                self.events.append({"event": "miss", "shard": s,
                                    "misses": self.misses[s],
                                    "error": str(e)})
                if self.expired(s):
                    self._fail_over(s)
                continue
            self.misses[s] = 0
            # another detector may have promoted and out-epoched us: adopt
            # (the fleet epoch is a max over everything proven durable)
            self.epoch = max(self.epoch, host_epoch)
            self.events.append({"event": "beat", "shard": s, "t": t,
                                "epoch": host_epoch, "state_hash": h})
        return self.promoted

    def _fail_over(self, shard: int) -> None:
        """The lease expired: bump the fleet epoch FIRST (the promoted
        host must refuse the dead regime's writers from its first
        request), then run the existing verified promotion. A promotion
        that refuses (``ReplicaDivergence``) is recorded and re-raised —
        a survivor that cannot prove its prefix never serves."""
        self.epoch += 1
        self.events.append({"event": "lease_expired", "shard": shard,
                            "epoch": self.epoch})
        try:
            if self.sharded_dir is not None:
                self.sharded_result = promote_sharded(
                    self.sharded_dir, self.replica_sets,
                    ef_construction=self.ef_construction, epoch=self.epoch)
                for s in range(len(self.probes)):
                    self.promoted[s] = self.sharded_result[4][s]
            else:
                host, winner_idx, t = promote_on_primary_loss(
                    self.replica_sets[shard],
                    ef_construction=self.ef_construction, epoch=self.epoch)
                self.promoted[shard] = host
                self.events.append({"event": "promoted", "shard": shard,
                                    "winner": winner_idx, "t": t,
                                    "epoch": self.epoch})
        except Exception as e:
            self.events.append({"event": "promotion_refused",
                                "shard": shard, "error": str(e)})
            raise

    def start(self) -> "FailureDetector":
        """Run ``poll`` on a daemon thread every ``interval_s`` until
        ``stop()`` (or until every shard has failed over)."""
        import threading
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop = threading.Event()

        def loop():
            while not self._stop.wait(timeout=self.lease.interval_s):
                try:
                    self.poll()
                except Exception as e:  # noqa: BLE001 — recorded above
                    self.events.append({"event": "detector_error",
                                        "error": str(e)})
                    return
                if (len(self.promoted) == len(self.probes)
                        or self.sharded_result is not None):
                    return

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="failure-detector")
        self._thread.start()
        return self

    def stop(self, *, timeout: float = 10.0) -> None:
        if self._stop is not None:
            self._stop.set()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=timeout)
        self._thread = None
