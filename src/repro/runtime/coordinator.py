"""Fault-tolerant training coordinator.

Wraps the train loop with the large-scale survival kit:
  * periodic deterministic checkpoints (hash-manifested, Valori semantics);
  * failure detection hooks (in production: heartbeat / JAX distributed
    errors; in tests: injected via `failure_injector`);
  * restart path: elastic remesh (elastic.py) → checkpoint restore →
    step-indexed data pipeline resumes bit-identically;
  * straggler mitigation policy: synchronous steps with a deadline; ranks
    that exceed `deadline_factor` × median step time get flagged, and after
    `evict_after` consecutive flags the coordinator treats the rank as
    failed and triggers the elastic path (the standard "fail-slow = fail"
    doctrine). On a single-host dry run, timings come from the host clock;
    the policy logic is exercised by tests with synthetic timings.

The loop itself is deliberately simple: all the intelligence lives in the
substrate (deterministic data order, hashable state, divisibility-aware
shardings) — which is the paper's thesis: make the state machine
deterministic and recovery becomes trivial replay.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0   # step slower than 3x median = flagged
    evict_after: int = 3           # consecutive flags before eviction
    window: int = 20               # median window


@dataclasses.dataclass
class RunConfig:
    total_steps: int
    checkpoint_every: int = 100
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    straggler: StragglerPolicy = dataclasses.field(default_factory=StragglerPolicy)
    max_restarts: int = 8


class Coordinator:
    """Drives (state, batch) -> state steps with checkpoint/restart."""

    def __init__(
        self,
        run: RunConfig,
        train_step: Callable,          # (train_state, batch) -> (train_state, metrics)
        batch_fn: Callable[[int], Any],  # step -> batch (deterministic!)
        init_state_fn: Callable[[], Any],
        failure_injector: Optional[Callable[[int], Optional[str]]] = None,
        on_restart: Optional[Callable[[int], None]] = None,
    ):
        self.run = run
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.init_state_fn = init_state_fn
        self.failure_injector = failure_injector
        self.on_restart = on_restart
        self.ckpt = CheckpointManager(run.checkpoint_dir,
                                      keep=run.keep_checkpoints,
                                      async_save=False)
        self.step_times: List[float] = []
        self.flag_counts: Dict[int, int] = {}
        self.restarts = 0
        self.events: List[dict] = []

    # ------------------------------------------------------------------ #
    def _check_stragglers(self, rank_times: Dict[int, float]) -> List[int]:
        """Returns ranks to evict under the fail-slow policy."""
        pol = self.run.straggler
        if len(rank_times) < 2:
            return []
        med = statistics.median(rank_times.values())
        evict = []
        for rank, t in rank_times.items():
            if t > pol.deadline_factor * max(med, 1e-9):
                self.flag_counts[rank] = self.flag_counts.get(rank, 0) + 1
                if self.flag_counts[rank] >= pol.evict_after:
                    evict.append(rank)
            else:
                self.flag_counts[rank] = 0
        return evict

    # ------------------------------------------------------------------ #
    def train(self, rank_times_fn: Optional[Callable[[int], Dict[int, float]]]
              = None) -> Any:
        """Run to completion, surviving injected failures."""
        state = None
        step = 0
        proto = self.init_state_fn()
        restored = self.ckpt.restore_latest(proto)
        if restored is not None:
            state, step, _ = restored
            self.events.append({"event": "resume", "step": step})
        else:
            state = proto

        while step < self.run.total_steps:
            try:
                if state is None:
                    state = self.init_state_fn()
                fail = self.failure_injector(step) if self.failure_injector else None
                if fail:
                    raise RuntimeError(f"injected failure: {fail}")

                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state, metrics = self.train_step(state, batch)
                self.step_times.append(time.monotonic() - t0)

                if rank_times_fn is not None:
                    evict = self._check_stragglers(rank_times_fn(step))
                    if evict:
                        self.events.append(
                            {"event": "straggler_evict", "ranks": evict,
                             "step": step})
                        raise RuntimeError(f"stragglers evicted: {evict}")

                step += 1
                if step % self.run.checkpoint_every == 0 or \
                        step == self.run.total_steps:
                    self.ckpt.save(state, step)
                    self.events.append({"event": "checkpoint", "step": step})
            except Exception as e:  # noqa: BLE001 — the recovery path IS the feature
                self.restarts += 1
                self.events.append({"event": "failure", "step": step,
                                    "error": str(e)})
                if self.restarts > self.run.max_restarts:
                    raise
                if self.on_restart:
                    self.on_restart(self.restarts)
                restored = self.ckpt.restore_latest(self.init_state_fn())
                if restored is None:
                    state, step = None, 0
                else:
                    state, step, _ = restored
                self.events.append({"event": "restart", "from_step": step})
        return state
