from repro.runtime.coordinator import (Coordinator, RunConfig,  # noqa: F401
                                       StragglerPolicy)
from repro.runtime.elastic import ElasticPlan, plan_remesh  # noqa: F401
