"""Train / serve step builders (pjit-ready pure functions)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.core import compat


def make_train_step(cfg: ModelConfig, optc: AdamWConfig):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            tf.loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, om = adamw_update(optc, params, grads, opt_state)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_compressed_train_step(cfg: ModelConfig, optc: AdamWConfig, mesh,
                               contract: str = "Q2.13",
                               error_feedback: bool = True):
    """Pod-DP train step with deterministic integer cross-pod gradient sync.

    shard_map over the `pod` axis only; `data`/`model` stay GSPMD-auto inside.
    opt_state gains a `residual` tree when error feedback is on.
    """
    from jax.sharding import PartitionSpec as P

    from repro.optim import compress

    inner_axes = frozenset(n for n in mesh.axis_names if n != "pod")

    def step(params, opt_state, batch):
        @functools.partial(
            compat.shard_map, mesh=mesh,
            in_specs=(P(), P(), P("pod")),
            out_specs=(P(), P(), P()),
            axis_names={"pod"}, check_vma=False,
        )
        def pod_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                tf.loss_fn, has_aux=True)(params, batch, cfg)
            residual = opt_state.get("residual")
            grads, new_res = compress.integer_psum_grads(
                grads, "pod", contract, residual)
            params, new_opt, om = adamw_update(optc, params, grads,
                                               {k: v for k, v in opt_state.items()
                                                if k != "residual"})
            if new_res is not None:
                new_opt["residual"] = new_res
            metrics = {**metrics, **om}
            metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), metrics)
            return params, new_opt, metrics

        return pod_step(params, opt_state, batch)

    return step


def make_prefill_step(cfg: ModelConfig, s_cache: int):
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg, s_cache)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, tokens, positions, embeds=None):
        return tf.decode_step(params, caches, tokens, positions, cfg,
                              embeds=embeds)
    return decode_step
