from repro.checkpoint.manager import (CheckpointManager,  # noqa: F401
                                      DurableCheckpointManager,
                                      load_checkpoint, save_checkpoint)
