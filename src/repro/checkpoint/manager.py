"""Deterministic training checkpoints — Valori snapshot semantics for trainer
state (paper §5.2/§8.1 applied to params/optimizer/data-cursor).

Every checkpoint is a directory:
  manifest.json  — step, FNV-1a tree hash (hashing.hash_pytree), leaf index
  <n>.npy        — one file per leaf, little-endian, in sorted-path order

Restore re-hashes and refuses a mismatch, exactly like snapshot transfer in
the paper (H_A ≡ H_B). An async mode hides the host write behind compute
(double-buffered thread), standard for large-scale training.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core import hashing


def _leaves_with_paths(tree: Any):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def save_checkpoint(path: str | pathlib.Path, tree: Any, step: int) -> int:
    """Write a checkpoint; returns the state hash."""
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _leaves_with_paths(tree)
    index = []
    for i, (kp, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"{i}.npy", arr)
        index.append({"path": jax.tree_util.keystr(kp),
                      "dtype": str(arr.dtype), "shape": list(arr.shape)})
    h = hashing.hash_pytree(tree)
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "hash": f"{h:#x}", "leaves": index}))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)  # atomic-ish publish
    return h


def load_checkpoint(path: str | pathlib.Path, tree_like: Any
                    ) -> Tuple[Any, int, int]:
    """Restore into the structure of ``tree_like``; verifies the hash.
    Returns (tree, step, hash)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves = _leaves_with_paths(tree_like)
    assert len(leaves) == len(manifest["leaves"]), "structure mismatch"
    restored = []
    for i, ((kp, proto), meta) in enumerate(zip(leaves, manifest["leaves"])):
        assert jax.tree_util.keystr(kp) == meta["path"], (
            f"leaf order mismatch at {i}: {jax.tree_util.keystr(kp)} vs "
            f"{meta['path']}")
        arr = np.load(path / f"{i}.npy")
        restored.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    h = hashing.hash_pytree(tree)
    expect = int(manifest["hash"], 16)
    if h != expect:
        raise ValueError(
            f"checkpoint hash mismatch: manifest {expect:#x}, recomputed {h:#x}"
        )
    return tree, int(manifest["step"]), h


@dataclasses.dataclass
class CheckpointManager:
    """Rotating checkpoints + optional async writes."""

    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        self._dir = pathlib.Path(self.directory)
        self._dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.last_hash: Optional[int] = None

    # ------------------------------------------------------------------ #
    def _ckpt_path(self, step: int) -> pathlib.Path:
        return self._dir / f"step_{step:08d}"

    def steps(self):
        out = []
        for p in sorted(self._dir.glob("step_*")):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree: Any, step: int) -> None:
        # snapshot to host synchronously (cheap vs device compute), write
        # + rotate in a background thread (the async part that matters)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            self.last_hash = save_checkpoint(self._ckpt_path(step), host_tree,
                                             step)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, tree_like: Any) -> Optional[Tuple[Any, int, int]]:
        self.wait()
        steps = self.steps()
        if not steps:
            return None
        return load_checkpoint(self._ckpt_path(steps[-1]), tree_like)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._ckpt_path(s), ignore_errors=True)
