"""Deterministic training checkpoints — Valori snapshot semantics for trainer
state (paper §5.2/§8.1 applied to params/optimizer/data-cursor).

Every checkpoint is a directory:
  manifest.json  — step, FNV-1a tree hash (hashing.hash_pytree), leaf index
  <n>.npy        — one file per leaf, little-endian, in sorted-path order
                   (or, in dedup mode, chunk references into a shared
                   content-addressed ChunkStore — identical leaves across
                   steps are stored once; see DESIGN.md §5)

Restore re-hashes and refuses a mismatch, exactly like snapshot transfer in
the paper (H_A ≡ H_B). An async mode hides the host write behind compute
(double-buffered thread), standard for large-scale training; a failure in
the background writer is recorded and re-raised on the next ``save()`` /
``wait()`` — silent checkpoint loss is worse than a crashed trainer.

``DurableCheckpointManager`` applies the same rotation policy to a memory
``DurableStore``: each save appends the new commands to the WAL, writes an
incremental v2 snapshot, and retains the last ``keep`` (snapshot,
WAL-segment) pairs together.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import hashing
from repro.core.commands import CommandLog
from repro.core.durability import DurableStore
from repro.core.snapshot import ChunkStore
from repro.core.state import MemoryState


def _leaves_with_paths(tree: Any):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def save_checkpoint(path: str | pathlib.Path, tree: Any, step: int,
                    chunk_store: Optional[ChunkStore] = None) -> int:
    """Write a checkpoint; returns the state hash. With ``chunk_store``,
    leaf payloads go into the shared content-addressed store (deduplicated
    across steps) and the step directory holds only the manifest."""
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _leaves_with_paths(tree)
    index = []
    for i, (kp, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        entry = {"path": jax.tree_util.keystr(kp),
                 "dtype": str(arr.dtype), "shape": list(arr.shape)}
        if chunk_store is None:
            np.save(tmp / f"{i}.npy", arr)
        else:
            payload = arr.astype(arr.dtype.newbyteorder("<"),
                                 copy=False).tobytes()
            key, _ = chunk_store.put(payload)
            entry["chunk"] = f"{key:016x}"
        index.append(entry)
    h = hashing.hash_pytree(tree)
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "hash": f"{h:#x}", "leaves": index}))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)  # atomic-ish publish
    return h


def load_checkpoint(path: str | pathlib.Path, tree_like: Any,
                    chunk_store: Optional[ChunkStore] = None
                    ) -> Tuple[Any, int, int]:
    """Restore into the structure of ``tree_like``; verifies the hash.
    Returns (tree, step, hash)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves = _leaves_with_paths(tree_like)
    assert len(leaves) == len(manifest["leaves"]), "structure mismatch"
    restored = []
    for i, ((kp, proto), meta) in enumerate(zip(leaves, manifest["leaves"])):
        assert jax.tree_util.keystr(kp) == meta["path"], (
            f"leaf order mismatch at {i}: {jax.tree_util.keystr(kp)} vs "
            f"{meta['path']}")
        if "chunk" in meta:
            if chunk_store is None:
                raise ValueError(
                    f"{path} is a deduplicated checkpoint; pass its "
                    "ChunkStore to load it")
            dtype = np.dtype(meta["dtype"])
            payload = chunk_store.get(int(meta["chunk"], 16))
            arr = np.frombuffer(payload, dtype=dtype.newbyteorder("<")
                                ).astype(dtype).reshape(meta["shape"])
        else:
            arr = np.load(path / f"{i}.npy")
        restored.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    h = hashing.hash_pytree(tree)
    expect = int(manifest["hash"], 16)
    if h != expect:
        raise ValueError(
            f"checkpoint hash mismatch: manifest {expect:#x}, recomputed {h:#x}"
        )
    return tree, int(manifest["step"]), h


@dataclasses.dataclass
class CheckpointManager:
    """Rotating checkpoints + optional async writes + optional dedup."""

    directory: str
    keep: int = 3
    async_save: bool = True
    dedup: bool = False  # content-address leaves in a shared chunk store

    def __post_init__(self):
        self._dir = pathlib.Path(self.directory)
        self._dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_hash: Optional[int] = None
        self._chunks = ChunkStore(self._dir / "chunks") if self.dedup else None

    # ------------------------------------------------------------------ #
    def _ckpt_path(self, step: int) -> pathlib.Path:
        return self._dir / f"step_{step:08d}"

    def steps(self):
        out = []
        for p in sorted(self._dir.glob("step_*")):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def wait(self):
        """Join any in-flight write; re-raise an error it recorded. A save
        that failed in the background MUST NOT vanish — the trainer would
        keep running believing it has a restart point."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    def save(self, tree: Any, step: int) -> None:
        # snapshot to host synchronously (cheap vs device compute), write
        # + rotate in a background thread (the async part that matters)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()  # raises here if the previous async save failed

        def work():
            try:
                self.last_hash = save_checkpoint(
                    self._ckpt_path(step), host_tree, step,
                    chunk_store=self._chunks)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — recorded, re-raised
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise RuntimeError("checkpoint save failed") from err

    def restore_latest(self, tree_like: Any) -> Optional[Tuple[Any, int, int]]:
        self.wait()
        steps = self.steps()
        if not steps:
            return None
        return load_checkpoint(self._ckpt_path(steps[-1]), tree_like,
                               chunk_store=self._chunks)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._ckpt_path(s), ignore_errors=True)
        if self._chunks is not None:
            referenced = set()
            for s in self.steps():
                manifest = json.loads(
                    (self._ckpt_path(s) / "manifest.json").read_text())
                for meta in manifest["leaves"]:
                    if "chunk" in meta:
                        referenced.add(int(meta["chunk"], 16))
            for key in self._chunks.keys():
                if key not in referenced:
                    self._chunks.delete(key)


class DurableCheckpointManager:
    """Rotation policy over a memory DurableStore: append → snapshot →
    retain the newest ``keep`` (snapshot, WAL-segment) pairs. The async
    error contract matches ``CheckpointManager`` — background failures
    surface on the next call, never silently."""

    def __init__(self, directory: str, genesis: Optional[MemoryState] = None,
                 *, keep: int = 3, async_save: bool = False, **store_kwargs):
        self.store = DurableStore(directory, genesis, **store_kwargs)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_stats: Optional[Dict[str, int]] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async durable checkpoint failed") from err

    def save(self, state: MemoryState,
             new_commands: Optional[CommandLog] = None) -> None:
        """Durably persist ``state``: append its new commands (if any) to
        the WAL, snapshot at its cursor, age out old pairs."""
        self.wait()
        host_state = jax.tree.map(np.asarray, state)

        def work():
            try:
                if new_commands is not None:
                    self.store.append(new_commands)
                stats = self.store.checkpoint(host_state)
                stats.update(self.store.retain(self.keep))
                self.last_stats = stats
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise RuntimeError("durable checkpoint failed") from err

    def recover(self) -> Tuple[MemoryState, int, int]:
        """(state, hash, t) at the last durable prefix."""
        self.wait()
        return self.store.recover()
