"""Serving engine: the paper's boundary + audit-trail properties end to end."""
import jax
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced_config("h2o_danube_1_8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8))
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (24, 24), dtype=np.int32)
    eng.insert_documents(docs)
    return eng


def test_ingest_and_hash(engine):
    assert int(engine.memory.count) == 24
    assert engine.state_hash() == engine.replay_log_fresh()


def test_retrieval_deterministic(engine):
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, engine.cfg.vocab_size, (4, 10), dtype=np.int32)
    a_ids, a_s = engine.retrieve(prompts)
    b_ids, b_s = engine.retrieve(prompts)
    assert (a_ids == b_ids).all() and (a_s == b_s).all()
    assert (a_ids >= 0).all()


def test_generation_runs_and_is_deterministic(engine):
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    out1 = engine.generate(prompts, augment=True)
    out2 = engine.generate(prompts, augment=True)
    assert out1.shape == (2, 4)
    assert (out1 == out2).all()


def test_snapshot_transferable(engine):
    from repro.core import snapshot
    blob = engine.snapshot_bytes()
    restored, h = snapshot.restore_bytes(blob)
    assert h == engine.state_hash()


def test_engine_crash_recovery(engine, tmp_path):
    """WAL-first serving: kill the engine, recover a fresh one from the
    durable store, get the same memory hash and the same retrievals."""
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    rng = np.random.default_rng(3)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"),
                     checkpoint_every=16)
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (20, 16), dtype=np.int32)
    eng.insert_documents(docs[:12])
    eng.insert_documents(docs[12:])  # crosses checkpoint_every=16
    eng.wait_durable()
    h_before = eng.state_hash()
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    rh_before = eng.retrieval_hash(prompts)
    assert eng.durable.snapshots()[0] == 0  # genesis snapshot exists
    assert eng.durable.t == 20

    # "crash": a brand-new engine over the same directory, then recover
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, h = eng2.recover()
    assert t == 20 and h == h_before
    assert eng2.retrieval_hash(prompts) == rh_before
    assert eng2.state_hash() == eng2.replay_log_fresh()  # audit still holds
    # recovered engines keep ingesting with fresh, non-colliding ids
    new_ids = eng2.insert_documents(docs[:2])
    assert min(new_ids) == 20


def test_engine_refuses_durability_policies_without_durable_dir(engine):
    from repro.core import wal
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    sc = ServeConfig(capacity=32, group_commit=wal.GroupCommitPolicy())
    with pytest.raises(ValueError, match="durable_dir"):
        MemoryAugmentedEngine(engine.cfg, engine.params, sc)


def test_engine_group_commit_sync_on_read(engine, tmp_path):
    """Group-commit serving: ingested batches buffer toward one fsync per
    group; the read path flushes first, so everything a retrieval observed
    is durable — and recovery reproduces exactly those retrievals."""
    from repro.core import wal
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    rng = np.random.default_rng(5)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"),
                     group_commit=wal.GroupCommitPolicy(max_batch=64,
                                                        max_delay_s=3600))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (12, 16), dtype=np.int32)
    eng.insert_documents(docs[:8])
    assert eng.durable.t == 0, "small batch must buffer, not fsync"
    assert eng._group.pending == 8

    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    rh = eng.retrieval_hash(prompts)         # sync-on-read barrier
    assert eng.durable.t == 8, "reads must flush pending commands first"
    assert eng._group.pending == 0

    eng.insert_documents(docs[8:])           # pending again, then "crash"
    assert eng.durable.t == 8
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, _ = eng2.recover()
    assert t == 8, "only the flushed (read-observed) prefix is durable"
    assert eng2.retrieval_hash(prompts) == rh
    assert eng2.state_hash() == eng2.replay_log_fresh()


# --------------------------------------------------------------------------- #
# sharded serve engine (DESIGN.md §7)
# --------------------------------------------------------------------------- #


def test_sharded_engine_matches_flat_bit_for_bit(engine):
    """ServeConfig(shards=N) on the same documents reports the same
    memory_hash() and the same retrieval sets as the single-host engine —
    exact route and (beam-exhaustive) HNSW route alike."""
    rng = np.random.default_rng(7)
    docs = rng.integers(0, engine.cfg.vocab_size, (20, 16), dtype=np.int32)
    prompts = rng.integers(0, engine.cfg.vocab_size, (3, 8), dtype=np.int32)

    def mk(shards):
        return MemoryAugmentedEngine(engine.cfg, engine.params, ServeConfig(
            capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards))

    flat, sharded = mk(1), mk(4)
    for eng in (flat, sharded):
        eng.insert_documents(docs[:12])
        eng.insert_documents(docs[12:])
    assert sharded.n_shards == 4
    assert flat.memory_hash() == sharded.memory_hash()

    for route in ("exact", "hnsw"):  # ef=64 >= live: beams are exhaustive
        flat.sc.route = sharded.sc.route = route
        fi, fs = flat.retrieve(prompts)
        si, ss = sharded.retrieve(prompts)
        assert sharded.last_plan.route == route
        assert (fi == si).all() and (fs == ss).all(), route
    flat.sc.route = sharded.sc.route = "auto"

    # generation conditions on the same retrieved context in both modes
    assert (flat.generate(prompts) == sharded.generate(prompts)).all()

    # native-layout audit: sharded replay re-derives the sharded state
    assert sharded.replay_log_fresh() == sharded.state_hash()
    assert flat.replay_log_fresh() == flat.state_hash()


def test_sharded_engine_durable_crash_recovery(engine, tmp_path):
    """The sharded serving path end to end: group-committed ingest into a
    ShardedDurableStore, checkpoint, kill, recover — state hash, retrieval
    hashes and the doc cache all come back; rollback_to time-travels."""
    from repro.core import wal
    rng = np.random.default_rng(9)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, shards=2,
                     durable_dir=str(tmp_path / "d"),
                     group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                                        max_delay_s=3600))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (18, 16), dtype=np.int32)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)

    eng.insert_documents(docs[:10])
    rh_mid = eng.retrieval_hash(prompts)     # sync-on-read flushes group
    t_mid = eng.durable.t
    eng.checkpoint()
    assert eng.durable.merged_records() == [t_mid]

    eng.insert_documents(docs[10:])
    rh_full = eng.retrieval_hash(prompts)
    t_full = eng.durable.t
    h_full = eng.state_hash()
    assert t_full > t_mid

    # crash: a brand-new engine over the same directory, then recover
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, h = eng2.recover()
    assert (t, h) == (t_full, h_full)
    assert eng2.retrieval_hash(prompts) == rh_full
    assert eng2.memory_hash() == eng.memory_hash()
    assert eng2.state_hash() == eng2.replay_log_fresh()
    new_ids = eng2.insert_documents(docs[:2])
    assert min(new_ids) == 18  # fresh, non-colliding ids after recovery

    # time travel: roll the recovered engine back to the checkpoint cursor
    eng3 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    eng3.recover()
    t3, _ = eng3.rollback_to(t_mid)
    assert t3 == t_mid and eng3.durable.t == t_mid
    assert eng3.retrieval_hash(prompts) == rh_mid
    assert eng3.state_hash() == eng3.replay_log_fresh()


def test_sharded_engine_rejects_indivisible_capacity(engine):
    with pytest.raises(ValueError, match="divide"):
        MemoryAugmentedEngine(engine.cfg, engine.params,
                              ServeConfig(capacity=100, shards=3))


def test_doc_cache_recovers_from_side_table(engine, tmp_path):
    """Recover-then-generate: the recovered engine's doc cache (token
    prefixes) reloads from the durable side table, so generation conditions
    on the same retrieved context as before the crash — no lazy refill."""
    rng = np.random.default_rng(11)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (10, 16), dtype=np.int32)
    eng.insert_documents(docs)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    out_a = eng.generate(prompts, augment=True)

    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, _ = eng2.recover()
    assert t == 10
    assert sorted(eng2.docs) == sorted(eng.docs)
    for k in eng.docs:
        assert (eng2.docs[k] == eng.docs[k]).all()
    out_b = eng2.generate(prompts, augment=True)
    assert (out_a == out_b).all(), \
        "recovered generation must condition on the same doc prefixes"


def test_flat_engine_rollback_to_time_travels(engine, tmp_path):
    """rollback_to(t) on the single-host engine: durable history above t is
    dropped, memory restores at t, retrievals and id allocation rewind."""
    rng = np.random.default_rng(13)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (12, 16), dtype=np.int32)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    eng.insert_documents(docs[:6])
    rh6, h6 = eng.retrieval_hash(prompts), eng.state_hash()
    eng.checkpoint()
    eng.insert_documents(docs[6:])
    assert eng.durable.t == 12
    t, h = eng.rollback_to(6)
    assert (t, h) == (6, h6)
    assert eng.retrieval_hash(prompts) == rh6
    assert eng.replay_log_fresh() == eng.state_hash()
    assert min(eng.insert_documents(docs[:2])) == 6, \
        "id allocation must rewind with the rolled-back state"


def test_doc_side_table_never_lags_reused_ids(engine, tmp_path):
    """Rollback then reinsert reuses ids. A crash right after the insert —
    no read barrier, no flush — must still recover the NEW tokens for the
    reused id: side-table records are durable before their commands, so a
    live id can never outrun its token prefix."""
    rng = np.random.default_rng(17)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs_a = rng.integers(0, engine.cfg.vocab_size, (6, 16), dtype=np.int32)
    doc_b = rng.integers(0, engine.cfg.vocab_size, (1, 16), dtype=np.int32)
    eng.insert_documents(docs_a)
    eng.rollback_to(3)                         # ids 3..5 rolled away
    assert eng.insert_documents(doc_b) == [3]  # id 3 reused, new content
    # crash with NO flush: a recovered engine must see the new tokens
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, _ = eng2.recover()
    assert t == 4
    assert (eng2.docs[3] == doc_b[0]).all(), \
        "recovered doc cache served stale pre-rollback tokens"


def test_group_commit_policy_flush_syncs_doc_table(engine, tmp_path):
    """A policy-driven flush inside submit() (max_batch reached) must sync
    the doc side table through the writer's pre_flush hook — command
    durability may never outrun the cache's."""
    from repro.core import wal
    from repro.core.durability import SideTable
    rng = np.random.default_rng(19)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"),
                     group_commit=wal.GroupCommitPolicy(max_batch=4,
                                                        max_delay_s=3600))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (4, 16), dtype=np.int32)
    eng.insert_documents(docs)      # max_batch hit: flushes inside submit
    assert eng.durable.t == 4
    table = SideTable(tmp_path / "d" / "docs.sdt")  # reads what is on disk
    try:
        assert sorted(table.entries) == [0, 1, 2, 3], \
            "doc records must be durable once their commands are"
    finally:
        table.close()
    eng.close()


def test_empty_ingest_batch_is_a_true_noop_in_both_modes(engine, tmp_path):
    """An empty batch must not advance any cursor: in sharded mode routing
    would pad it to one NOP per shard (advancing memory but not the
    durable store, which skips empty logs) — the engine refuses up front."""
    from repro.core import wal
    for shards, d in ((1, "f"), (2, "s")):
        sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4,
                         s_cache=96, context_tokens=8, shards=shards,
                         durable_dir=str(tmp_path / d),
                         group_commit=wal.GroupCommitPolicy(
                             max_batch=1 << 20, max_delay_s=3600))
        eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
        h0 = eng.state_hash()
        assert eng.insert_documents(
            np.empty((0, 8), np.int32)) == []
        assert eng.state_hash() == h0 and eng.durable.t == 0
        assert eng._cursor() == 0 and eng._next_id == 0
        eng.close()


# --------------------------------------------------------------------------- #
# churn serving: delete_documents + the re-link schedule (DESIGN.md §11)
# --------------------------------------------------------------------------- #


def _churn_engine(engine, shards=1, relink=None, **kw):
    return MemoryAugmentedEngine(engine.cfg, engine.params, ServeConfig(
        capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8, shards=shards, relink=relink, **kw))


def test_delete_documents_through_the_full_serving_path(engine):
    """DELETEs ride the same audit/apply/doc-cache path INSERTs do: rows
    tombstone, the doc cache drops them, retrieval never returns them,
    unknown ids are counted-as-zero no-ops, and the audit replay still
    restates the serving state bit-for-bit."""
    from repro.core import hnsw
    rng = np.random.default_rng(23)
    docs = rng.integers(0, engine.cfg.vocab_size, (12, 16), dtype=np.int32)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    eng = _churn_engine(engine)
    ids = eng.insert_documents(docs)
    assert eng.delete_documents(ids[:5]) == 5
    assert eng.delete_documents([]) == 0
    assert eng.delete_documents([9999]) == 0     # unknown: no-op
    assert eng.delete_documents(ids[:2]) == 0    # already dead: no-op
    assert all(i not in eng.docs for i in ids[:5])
    from repro.core import shard_wal
    assert shard_wal.live_count(eng.memory) == 7
    got, _ = eng.retrieve(prompts, k=7)
    assert not (set(ids[:5]) & set(got.reshape(-1).tolist()))
    assert eng.state_hash() == eng.replay_log_fresh()
    # the entry survived the churn (repair invariant)
    e = int(np.asarray(eng.memory.hnsw_entry).reshape(-1)[0])
    assert e >= 0 and bool(np.asarray(eng.memory.valid)[e])


def test_relink_policy_fires_on_dead_ratio(engine):
    """Scheduling parity with CompactionPolicy: the pass fires once
    effective deletes reach the dead fraction, at a check boundary."""
    from repro.core import hnsw
    rng = np.random.default_rng(29)
    docs = rng.integers(0, engine.cfg.vocab_size, (16, 16), dtype=np.int32)
    eng = _churn_engine(engine, relink=hnsw.RelinkPolicy(
        dead_ratio=0.25, min_deletes=4, check_every=8))
    ids = eng.insert_documents(docs)     # 16 cmds: checked, 0 dead → skip
    assert eng.graph_gen == 0 and eng.relink_ts == []
    eng.delete_documents(ids[:3])        # 3 dead < min_deletes=4 → skip
    assert eng.graph_gen == 0
    eng.delete_documents(ids[3:8])       # 8 dead >= 4, 8 >= .25*16 → FIRE
    assert eng.graph_gen == 1 and len(eng.relink_ts) == 1
    assert eng._deletes_since_relink == 0  # counter reset at the firing
    assert eng.state_hash() == eng.replay_log_fresh()


def test_relink_policy_respects_min_deletes_and_check_every(engine):
    """Below min_deletes, or between check boundaries, the pass must not
    fire no matter the dead fraction."""
    from repro.core import hnsw
    rng = np.random.default_rng(31)
    docs = rng.integers(0, engine.cfg.vocab_size, (8, 16), dtype=np.int32)
    eng = _churn_engine(engine, relink=hnsw.RelinkPolicy(
        dead_ratio=0.01, min_deletes=10_000, check_every=1))
    ids = eng.insert_documents(docs)
    eng.delete_documents(ids[:6])        # dead fraction huge, min not met
    assert eng.graph_gen == 0 and eng.relink_ts == []

    eng2 = _churn_engine(engine, relink=hnsw.RelinkPolicy(
        dead_ratio=0.01, min_deletes=1, check_every=10_000))
    ids2 = eng2.insert_documents(docs)
    eng2.delete_documents(ids2[:6])      # no check boundary reached yet
    assert eng2.graph_gen == 0 and eng2.relink_ts == []


def test_relink_policy_validation():
    from repro.core import hnsw
    with pytest.raises(ValueError, match="dead_ratio"):
        hnsw.RelinkPolicy(dead_ratio=0.0)
    with pytest.raises(ValueError, match="dead_ratio"):
        hnsw.RelinkPolicy(dead_ratio=1.5)
    with pytest.raises(ValueError, match="check_every"):
        hnsw.RelinkPolicy(check_every=0)
    with pytest.raises(ValueError, match="min_deletes"):
        hnsw.RelinkPolicy(min_deletes=0)


def test_plan_records_graph_gen_and_manual_relink(engine):
    """``QueryPlan.graph_gen`` makes replayed plans auditable against the
    re-link schedule; ``relink_now()`` bumps it and keeps retrieval and
    the audit replay bit-stable."""
    from repro.core import hnsw
    rng = np.random.default_rng(37)
    docs = rng.integers(0, engine.cfg.vocab_size, (10, 16), dtype=np.int32)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    eng = _churn_engine(engine, relink=hnsw.RelinkPolicy())
    ids = eng.insert_documents(docs)
    eng.delete_documents(ids[:4])
    rh = eng.retrieval_hash(prompts)
    assert eng.last_plan.graph_gen == 0
    t = eng.relink_now()
    assert eng.graph_gen == 1 and eng.relink_ts == [t]
    assert eng.retrieval_hash(prompts) == rh
    assert eng.last_plan.graph_gen == 1
    assert eng.state_hash() == eng.replay_log_fresh()
