"""Serving engine: the paper's boundary + audit-trail properties end to end."""
import jax
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced_config("h2o_danube_1_8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8))
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (24, 24), dtype=np.int32)
    eng.insert_documents(docs)
    return eng


def test_ingest_and_hash(engine):
    assert int(engine.memory.count) == 24
    assert engine.memory_hash() == engine.replay_log_fresh()


def test_retrieval_deterministic(engine):
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, engine.cfg.vocab_size, (4, 10), dtype=np.int32)
    a_ids, a_s = engine.retrieve(prompts)
    b_ids, b_s = engine.retrieve(prompts)
    assert (a_ids == b_ids).all() and (a_s == b_s).all()
    assert (a_ids >= 0).all()


def test_generation_runs_and_is_deterministic(engine):
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    out1 = engine.generate(prompts, augment=True)
    out2 = engine.generate(prompts, augment=True)
    assert out1.shape == (2, 4)
    assert (out1 == out2).all()


def test_snapshot_transferable(engine):
    from repro.core import snapshot
    blob = engine.snapshot_bytes()
    restored, h = snapshot.restore_bytes(blob)
    assert h == engine.memory_hash()
