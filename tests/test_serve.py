"""Serving engine: the paper's boundary + audit-trail properties end to end."""
import jax
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced_config("h2o_danube_1_8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8))
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (24, 24), dtype=np.int32)
    eng.insert_documents(docs)
    return eng


def test_ingest_and_hash(engine):
    assert int(engine.memory.count) == 24
    assert engine.memory_hash() == engine.replay_log_fresh()


def test_retrieval_deterministic(engine):
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, engine.cfg.vocab_size, (4, 10), dtype=np.int32)
    a_ids, a_s = engine.retrieve(prompts)
    b_ids, b_s = engine.retrieve(prompts)
    assert (a_ids == b_ids).all() and (a_s == b_s).all()
    assert (a_ids >= 0).all()


def test_generation_runs_and_is_deterministic(engine):
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    out1 = engine.generate(prompts, augment=True)
    out2 = engine.generate(prompts, augment=True)
    assert out1.shape == (2, 4)
    assert (out1 == out2).all()


def test_snapshot_transferable(engine):
    from repro.core import snapshot
    blob = engine.snapshot_bytes()
    restored, h = snapshot.restore_bytes(blob)
    assert h == engine.memory_hash()


def test_engine_crash_recovery(engine, tmp_path):
    """WAL-first serving: kill the engine, recover a fresh one from the
    durable store, get the same memory hash and the same retrievals."""
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    rng = np.random.default_rng(3)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"),
                     checkpoint_every=16)
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (20, 16), dtype=np.int32)
    eng.insert_documents(docs[:12])
    eng.insert_documents(docs[12:])  # crosses checkpoint_every=16
    eng.wait_durable()
    h_before = eng.memory_hash()
    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    rh_before = eng.retrieval_hash(prompts)
    assert eng.durable.snapshots()[0] == 0  # genesis snapshot exists
    assert eng.durable.t == 20

    # "crash": a brand-new engine over the same directory, then recover
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, h = eng2.recover()
    assert t == 20 and h == h_before
    assert eng2.retrieval_hash(prompts) == rh_before
    assert eng2.memory_hash() == eng2.replay_log_fresh()  # audit still holds
    # recovered engines keep ingesting with fresh, non-colliding ids
    new_ids = eng2.insert_documents(docs[:2])
    assert min(new_ids) == 20


def test_engine_refuses_durability_policies_without_durable_dir(engine):
    from repro.core import wal
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    sc = ServeConfig(capacity=32, group_commit=wal.GroupCommitPolicy())
    with pytest.raises(ValueError, match="durable_dir"):
        MemoryAugmentedEngine(engine.cfg, engine.params, sc)


def test_engine_group_commit_sync_on_read(engine, tmp_path):
    """Group-commit serving: ingested batches buffer toward one fsync per
    group; the read path flushes first, so everything a retrieval observed
    is durable — and recovery reproduces exactly those retrievals."""
    from repro.core import wal
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
    rng = np.random.default_rng(5)
    sc = ServeConfig(capacity=128, retrieve_k=3, max_new_tokens=4, s_cache=96,
                     context_tokens=8, durable_dir=str(tmp_path / "d"),
                     group_commit=wal.GroupCommitPolicy(max_batch=64,
                                                        max_delay_s=3600))
    eng = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    docs = rng.integers(0, engine.cfg.vocab_size, (12, 16), dtype=np.int32)
    eng.insert_documents(docs[:8])
    assert eng.durable.t == 0, "small batch must buffer, not fsync"
    assert eng._group.pending == 8

    prompts = rng.integers(0, engine.cfg.vocab_size, (2, 8), dtype=np.int32)
    rh = eng.retrieval_hash(prompts)         # sync-on-read barrier
    assert eng.durable.t == 8, "reads must flush pending commands first"
    assert eng._group.pending == 0

    eng.insert_documents(docs[8:])           # pending again, then "crash"
    assert eng.durable.t == 8
    eng2 = MemoryAugmentedEngine(engine.cfg, engine.params, sc)
    t, _ = eng2.recover()
    assert t == 8, "only the flushed (read-observed) prefix is durable"
    assert eng2.retrieval_hash(prompts) == rh
    assert eng2.memory_hash() == eng2.replay_log_fresh()
