"""Distributed memory: shard_map run must equal the single kernel bitwise.

Needs >1 device → runs itself in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the parent test process
must keep seeing 1 device, per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    import repro
    from repro.core import boundary, commands, distributed, hashing, machine, search
    from repro.core.state import init_state

    from repro.core import compat
    mesh = compat.make_mesh((4, 2), ("model", "data"))
    D, N, K = 16, 96, 5
    rng = np.random.default_rng(0)
    vecs = boundary.normalize_embedding(rng.normal(size=(N, D)).astype(np.float32))
    ids = jnp.arange(N, dtype=jnp.int64) * 3 + 1
    log = commands.insert_batch(ids, vecs)

    ref = machine.replay(init_state(256, D), log)
    q = boundary.admit_query(rng.normal(size=(8, D)).astype(np.float32))
    ref_ids, ref_scores = search.exact_search(ref, q, K)

    routed = distributed.route_commands(log, 4)
    st = distributed.init_sharded_state(mesh, "model", 64, D)
    st = distributed.distributed_replay(mesh, "model", st, routed)
    d_ids, d_scores = distributed.distributed_search(
        mesh, "model", st, q, K, query_axis="data")
    assert (np.asarray(d_ids) == np.asarray(ref_ids)).all(), "ids diverged"
    assert (np.asarray(d_scores) == np.asarray(ref_scores)).all(), "scores diverged"

    # replay determinism across different shard counts: 2 vs 4 shards give
    # identical search answers
    mesh2 = compat.make_mesh((2, 4), ("model", "data"))
    st2 = distributed.init_sharded_state(mesh2, "model", 128, D)
    st2 = distributed.distributed_replay(mesh2, "model", st2,
                                         distributed.route_commands(log, 2))
    d2_ids, d2_scores = distributed.distributed_search(
        mesh2, "model", st2, q, K, query_axis="data")
    assert (np.asarray(d2_ids) == np.asarray(ref_ids)).all()
    assert (np.asarray(d2_scores) == np.asarray(ref_scores)).all()

    # sharded HNSW: deterministic across runs + high recall vs sharded exact
    h_ids, h_d = distributed.distributed_hnsw_search(
        mesh, "model", st, q, K, ef=48, query_axis="data")
    h_ids2, h_d2 = distributed.distributed_hnsw_search(
        mesh, "model", st, q, K, ef=48, query_axis="data")
    assert (np.asarray(h_ids) == np.asarray(h_ids2)).all()
    assert (np.asarray(h_d) == np.asarray(h_d2)).all()
    hits = sum(len(set(np.asarray(h_ids)[i].tolist())
                   & set(np.asarray(d_ids)[i].tolist()))
               for i in range(q.shape[0]))
    recall = hits / (q.shape[0] * K)
    assert recall >= 0.85, f"sharded hnsw recall {recall}"
    print("DISTRIBUTED_OK", recall)
""")


def test_sharded_memory_equals_single_kernel():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_OK" in proc.stdout
