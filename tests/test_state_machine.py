"""State machine F / replay / snapshot / hashing (paper §3.1, §5.2, §8.1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import boundary, commands, hashing, machine, search, snapshot
from repro.core.state import init_state, slot_of_id

D = 16


def _mk_vecs(n, seed=0):
    rng = np.random.default_rng(seed)
    return boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))


def _mixed_log(n=24, seed=0):
    vecs = _mk_vecs(n, seed)
    ids = jnp.arange(n, dtype=jnp.int64)
    log = commands.insert_batch(ids, vecs)
    log = log.concat(commands.delete_cmd(3, D))
    log = log.concat(commands.link_cmd(1, 2, D))
    log = log.concat(commands.link_cmd(2, 4, D))
    log = log.concat(commands.unlink_cmd(1, 2, D))
    log = log.concat(commands.set_meta_cmd(5, 1, 42, D))
    log = log.concat(commands.insert_cmd(3, np.asarray(vecs[0])))  # re-insert
    return log


def test_replay_chunking_invariance():
    log = _mixed_log()
    full = machine.replay(init_state(64, D), log)
    h = hashing.hash_pytree(full)
    for chunk in (1, 2, 5, 7, 100):
        s = machine.apply_chunked(init_state(64, D), log, chunk)
        assert hashing.hash_pytree(s) == h, f"chunk={chunk} diverged"


def test_version_always_advances():
    log = _mixed_log()
    s = machine.replay(init_state(64, D), log)
    assert int(s.version) == len(log)


def test_insert_upsert_and_delete_semantics():
    s = init_state(8, D)
    v = _mk_vecs(3)
    s = machine.replay(s, commands.insert_batch(
        jnp.asarray([10, 20, 30], jnp.int64), v))
    assert int(s.count) == 3
    # upsert: same id, new vector — count unchanged, slot reused
    s2 = machine.replay(s, commands.insert_cmd(20, np.asarray(v[0])))
    assert int(s2.count) == 3
    slot = int(slot_of_id(s2, jnp.int64(20)))
    assert (np.asarray(s2.vectors[slot]) == np.asarray(v[0])).all()
    # delete frees the slot for reuse
    s3 = machine.replay(s2, commands.delete_cmd(10, D))
    assert int(s3.count) == 2
    s4 = machine.replay(s3, commands.insert_cmd(99, np.asarray(v[2])))
    assert int(s4.count) == 3
    assert int(slot_of_id(s4, jnp.int64(99))) == 0  # lowest free slot reused


def test_arena_full_rejects_deterministically():
    s = init_state(4, D)
    v = _mk_vecs(6)
    log = commands.insert_batch(jnp.arange(6, dtype=jnp.int64), v)
    s = machine.replay(s, log)
    assert int(s.count) == 4
    assert int(s.version) == 6  # rejected commands still advance time


def test_snapshot_roundtrip_bit_exact():
    s = machine.replay(init_state(64, D), _mixed_log())
    blob = snapshot.snapshot_bytes(s)
    s2, h = snapshot.restore_bytes(blob)
    assert h == hashing.hash_pytree(s)
    for f in s.__dataclass_fields__:
        if f == "contract_name":
            continue
        assert (np.asarray(getattr(s, f)) == np.asarray(getattr(s2, f))).all()


def test_snapshot_detects_corruption():
    s = machine.replay(init_state(64, D), _mixed_log())
    blob = bytearray(snapshot.snapshot_bytes(s))
    blob[300] ^= 0x40  # flip one bit inside a payload
    with pytest.raises(ValueError, match="hash mismatch"):
        snapshot.restore_bytes(bytes(blob))


def test_host_and_device_hash_agree():
    s = machine.replay(init_state(64, D), _mixed_log())
    assert int(hashing.hash_state_device(s)) == hashing.hash_pytree(s)


def test_hash_sensitive_to_content_and_order():
    s = machine.replay(init_state(64, D), _mixed_log())
    h = hashing.hash_pytree(s)
    # flipping one element changes the hash
    s2 = dataclasses.replace(
        s, vectors=s.vectors.at[0, 0].add(1))
    assert hashing.hash_pytree(s2) != h
    # permuting two rows changes the hash (order-sensitive mix)
    v = s.vectors
    s3 = dataclasses.replace(
        s, vectors=v.at[0].set(v[1]).at[1].set(v[0]))
    assert hashing.hash_pytree(s3) != h


@given(st.lists(st.integers(0, 500), min_size=1, max_size=40, unique=True))
@settings(max_examples=20, deadline=None)
def test_replay_determinism_property(ids):
    """Any id set, any chunking: Apply(S0, C) is a pure function (paper §3.1)."""
    vecs = _mk_vecs(len(ids), seed=sum(ids) % 1000)
    log = commands.insert_batch(jnp.asarray(ids, jnp.int64), vecs)
    a = machine.replay(init_state(64, D), log)
    b = machine.apply_chunked(init_state(64, D), log, 3)
    assert hashing.hash_pytree(a) == hashing.hash_pytree(b)


def test_search_excludes_tombstones():
    v = _mk_vecs(10)
    s = machine.replay(init_state(32, D),
                       commands.insert_batch(jnp.arange(10, dtype=jnp.int64), v))
    q = boundary.admit_query(np.asarray(v[4], np.float64))
    ids, _ = search.exact_search(s, q[None], k=1)
    assert int(ids[0, 0]) == 4
    s = machine.replay(s, commands.delete_cmd(4, D))
    ids, _ = search.exact_search(s, q[None], k=1)
    assert int(ids[0, 0]) != 4
