"""Fault-injection replication suite (DESIGN.md §8).

The property under test: a log-shipping replica either *refuses* a cursor
or *converges to the primary's exact state hash* — never a third thing.
Faulty transports drop, duplicate, delay, reorder and corrupt messages
between a ``ReplicaStore`` and its primary ``ShardHost``; tampering
transports rewrite the shipped log or the advertised hash. Under every
schedule the acked cursor implies a proven bit-identical state
(``ReplicaDivergence`` otherwise), the primary applies a retried APPEND
exactly once, a SIGKILLed durable replica restarts from its own WAL and
catches up, and the coordinator's ``recover()`` reconciles a stale remote
shard exactly as it would a local one.
"""
import dataclasses
import os
import pathlib
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np
import pytest

from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import boundary, distributed, hashing, machine
from repro.core import query as query_lib
from repro.core import shard_wal
from repro.core.commands import log_to_bytes
from repro.core.state import init_state
from repro.net import protocol as p
from repro.net.client import LocalTransport, RemoteShardClient, \
    SocketTransport
from repro.net.replica import FollowerPolicy, ReplicaDivergence, ReplicaStore
from repro.net.server import ShardHost, ShardServer, load_epoch
from repro.runtime.coordinator import FailureDetector, LeaseConfig, \
    promote_on_primary_loss, promote_sharded, proven_cursor
from test_bulk_apply import _random_log

D = 8
CAP = 32
ID_SPACE = 12
K = 5
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def _genesis():
    return init_state(CAP, D)


def _primary(directory, *, batches=3, seed=0):
    """A shard host with a few random mixed-opcode batches ingested through
    a clean wire client (the writer path)."""
    host = ShardHost(directory, _genesis())
    writer = RemoteShardClient(LocalTransport(host))
    for i in range(batches):
        writer.append(_random_log(seed * 1000 + i, 5, ID_SPACE))
    return host, writer


def _queries(seed=0, nq=4):
    rng = np.random.default_rng(seed)
    return boundary.admit_query(rng.normal(size=(nq, D)).astype(np.float32))


# --------------------------------------------------------------------------- #
# fault-injection transports
# --------------------------------------------------------------------------- #


class FaultyTransport:
    """An at-least-once adversary around a real transport: deterministically
    (seeded) drops requests, drops responses *after* the server executed
    them, duplicates deliveries, delays/reorders responses across requests,
    and flips bits. Counts each injected fault so tests can assert the
    schedule actually exercised them."""

    def __init__(self, inner, seed, *, drop_req=0.0, drop_resp=0.0,
                 duplicate=0.0, reorder=0.0, corrupt=0.0):
        self.inner = inner
        self.rng = np.random.default_rng(seed)
        self.drop_req = drop_req
        self.drop_resp = drop_resp
        self.duplicate = duplicate
        self.reorder = reorder
        self.corrupt = corrupt
        self.stash = []  # delayed responses, delivered out of order later
        self.faults = {"drop_req": 0, "drop_resp": 0, "duplicate": 0,
                       "reorder": 0, "corrupt": 0}

    def request(self, data: bytes) -> bytes:
        r = self.rng.random
        if r() < self.drop_req:
            self.faults["drop_req"] += 1
            raise p.TransportError("injected: request dropped")
        if r() < self.duplicate:
            # delivered twice; the first response is discarded in transit
            self.faults["duplicate"] += 1
            self.inner.request(data)
        resp = self.inner.request(data)
        if r() < self.drop_resp:
            self.faults["drop_resp"] += 1
            raise p.TransportError(
                "injected: response dropped (request DID execute)")
        if r() < self.reorder:
            self.faults["reorder"] += 1
            self.stash.append(resp)
            if len(self.stash) > 1:
                return self.stash.pop(0)  # an older response resurfaces
            raise p.TransportError("injected: response delayed")
        if r() < self.corrupt:
            self.faults["corrupt"] += 1
            out = bytearray(resp)
            bit = int(self.rng.integers(0, len(out) * 8))
            out[bit // 8] ^= 1 << (bit % 8)
            return bytes(out)
        return resp

    def close(self) -> None:
        self.inner.close()


class _TamperTransport:
    """Rewrites TailAck frames in flight with a caller-supplied function —
    the man-in-the-middle the digest can't catch (it re-signs the frame),
    so the *content*-level checks must."""

    def __init__(self, inner, rewrite):
        self.inner = inner
        self.rewrite = rewrite

    def request(self, data: bytes) -> bytes:
        resp = self.inner.request(data)
        msg, rid, _ = p.decode_frame(resp)
        if isinstance(msg, p.TailAck) and msg.t_end > msg.from_t:
            return p.encode_frame(self.rewrite(msg), rid)
        return resp

    def close(self) -> None:
        self.inner.close()


def _replica_over(host, transport_factory, **kw):
    """A ReplicaStore whose wire to ``host`` goes through
    ``transport_factory(LocalTransport(host))`` — the handshake runs clean
    so construction never depends on the fault schedule."""
    client = RemoteShardClient(LocalTransport(host))
    client.transport = transport_factory(LocalTransport(host))
    return ReplicaStore(client, _genesis(), **kw)


# --------------------------------------------------------------------------- #
# convergence under lossy schedules
# --------------------------------------------------------------------------- #


@settings(max_examples=5)
@given(st.integers(0, 10 ** 6))
def test_replica_converges_under_lossy_transport(seed):
    """Drop/duplicate/delay/reorder/corrupt at aggressive rates: the
    replica still converges to the primary's exact state hash, the primary
    records the proven cursor, and replica reads are bit-identical."""
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary", batches=3,
                           seed=seed)
        faulty = {}

        def factory(inner):
            faulty["t"] = FaultyTransport(
                inner, seed + 1, drop_req=0.15, drop_resp=0.15,
                duplicate=0.15, reorder=0.15, corrupt=0.10)
            return faulty["t"]

        rep = _replica_over(host, factory, replica_id=3)
        assert rep.catch_up(max_commands=2, max_rounds=400) == 0, \
            "catch-up gave up under the fault schedule"
        assert rep.t == host.store.t
        assert rep.state_hash() == host.state_hash()
        assert host.replica_cursors[3] == rep.t  # the ack round-tripped
        q = _queries(seed)
        plan = query_lib.plan_query(shard_wal.live_count(host.state), K, 64)
        ids, scores = query_lib.execute_plan(host.state, q, K, plan)
        assert rep.retrieval_hash(q, K) == query_lib.retrieval_hash(
            ids, scores)
        assert sum(faulty["t"].faults.values()) > 0, \
            "the schedule injected no faults — the test proved nothing"


def test_replica_interleaved_with_ingest_under_faults():
    """Catch-up between ingest bursts: every converged checkpoint along the
    way is hash-identical, under a lossy schedule throughout."""
    with tempfile.TemporaryDirectory() as td:
        host = ShardHost(pathlib.Path(td) / "primary", _genesis())
        writer = RemoteShardClient(LocalTransport(host))
        rep = _replica_over(
            host,
            lambda inner: FaultyTransport(inner, 42, drop_req=0.2,
                                          drop_resp=0.2, duplicate=0.2),
            replica_id=9)
        for i in range(4):
            writer.append(_random_log(7 * i + 1, 4, ID_SPACE))
            assert rep.catch_up(max_commands=3, max_rounds=200) == 0
            assert rep.t == host.store.t
            assert rep.state_hash() == host.state_hash()
        assert host.replica_cursors[9] == host.store.t


# --------------------------------------------------------------------------- #
# refusal: tampered logs / hashes never become served state
# --------------------------------------------------------------------------- #


def test_tampered_hash_is_refused_and_nothing_commits():
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary")
        rep = _replica_over(
            host,
            lambda inner: _TamperTransport(
                inner,
                lambda m: dataclasses.replace(
                    m, state_hash=m.state_hash ^ 1)),
            replica_id=1)
        h0, t0 = rep.state_hash(), rep.t
        with pytest.raises(ReplicaDivergence):
            rep.sync()
        # refused means refused: no cursor advance, no state change, no ack
        assert (rep.t, rep.state_hash()) == (t0, h0)
        assert host.replica_cursors == {}


def test_truncated_shipped_log_is_a_protocol_error():
    """A tail whose log is shorter than its claimed [from_t, t_end) range
    is rejected before any replay — torn shipping can't half-apply."""
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary")

        def chop(m):
            from repro.core.commands import log_from_bytes
            log = log_from_bytes(m.log, host.contract)
            return dataclasses.replace(
                m, log=log_to_bytes(log.slice(0, len(log) - 1)))

        rep = _replica_over(
            host, lambda inner: _TamperTransport(inner, chop), replica_id=2)
        with pytest.raises(p.ProtocolError):
            rep.sync()
        assert rep.t == 0 and host.replica_cursors == {}


def test_idle_sync_reverifies_position():
    """The no-news tail still compares hashes — a replica that silently
    diverged (bit rot, buggy local mutation) is caught on its next idle
    sync, not at the next write."""
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary")
        rep = _replica_over(host, lambda inner: inner, replica_id=5)
        rep.catch_up()
        assert rep.state_hash() == host.state_hash()
        rep._hash ^= 1  # simulated silent corruption of the served state
        with pytest.raises(ReplicaDivergence):
            rep.sync()


def test_primary_refuses_divergent_replica_ack():
    """Verification is two-ended: even a replica that *claims* a cursor
    with the wrong hash is refused by the primary's own check."""
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary")
        t = host.store.t
        good = host.state_hash()
        resp = host.handle(p.ReplicaCursorAck(replica_id=4, t=t,
                                              state_hash=good ^ 1))
        assert isinstance(resp, p.ErrorMsg) and resp.kind == "ValueError"
        assert host.replica_cursors == {}
        resp = host.handle(p.ReplicaCursorAck(replica_id=4, t=t,
                                              state_hash=good))
        assert isinstance(resp, p.ReplicaCursorAckAck)
        assert host.replica_cursors == {4: t}


# --------------------------------------------------------------------------- #
# exactly-once ingest over an at-least-once transport
# --------------------------------------------------------------------------- #


def test_duplicate_append_redelivery_is_reacked_not_reapplied():
    with tempfile.TemporaryDirectory() as td:
        host = ShardHost(pathlib.Path(td) / "s", _genesis())
        blob = log_to_bytes(_random_log(3, 5, ID_SPACE))
        ack = host.handle(p.Append(base_t=0, logs=(blob,)))
        assert isinstance(ack, p.AppendAck)
        t, h = ack.t, host.state_hash()
        # byte-identical redelivery (the ack was lost): re-ack, no re-apply
        ack2 = host.handle(p.Append(base_t=0, logs=(blob,)))
        assert isinstance(ack2, p.AppendAck) and ack2.t == t
        assert host.store.t == t and host.state_hash() == h
        # a DIFFERENT group at the same stale base is not a duplicate
        blob2 = log_to_bytes(_random_log(4, 5, ID_SPACE))
        err = host.handle(p.Append(base_t=0, logs=(blob2,)))
        assert isinstance(err, p.ErrorMsg) and err.kind == "ValueError"
        assert host.store.t == t and host.state_hash() == h


def test_append_retry_after_lost_ack_applies_exactly_once():
    class DropFirstAppendAck:
        def __init__(self, inner):
            self.inner = inner
            self.dropped = False

        def request(self, data):
            msg, _, _ = p.decode_frame(data)
            resp = self.inner.request(data)
            if isinstance(msg, p.Append) and not self.dropped:
                self.dropped = True  # the server DID commit; the ack died
                raise p.TransportError("injected: append ack lost")
            return resp

        def close(self):
            self.inner.close()

    with tempfile.TemporaryDirectory() as td:
        host = ShardHost(pathlib.Path(td) / "s", _genesis())
        client = RemoteShardClient(LocalTransport(host))
        client.transport = DropFirstAppendAck(LocalTransport(host))
        log = _random_log(11, 6, ID_SPACE)
        with pytest.raises(p.TransportError):
            client.append(log)
        t = client.append(log)  # stale base_t -> duplicate path -> re-ack
        assert t == client.t == host.store.t == len(log)
        # reference: the same log applied once
        ref = ShardHost(pathlib.Path(td) / "ref", _genesis())
        ref.handle(p.Append(base_t=0, logs=(log_to_bytes(log),)))
        assert host.state_hash() == ref.state_hash()


# --------------------------------------------------------------------------- #
# durable replica: simulated crash + real SIGKILL
# --------------------------------------------------------------------------- #


@settings(max_examples=4)
@given(st.integers(0, 10 ** 6), st.integers(1, 6))
def test_crashed_durable_replica_resumes_from_its_wal(seed, cut):
    """Property: drop a durable replica mid-catch-up (no close, no
    flush — the object just dies), reopen the directory, and the restarted
    replica resumes from its durable cursor and converges."""
    with tempfile.TemporaryDirectory() as td:
        host, _ = _primary(pathlib.Path(td) / "primary", batches=3,
                           seed=seed)
        rdir = pathlib.Path(td) / "replica"
        rep = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                           _genesis(), directory=rdir, replica_id=6)
        for _ in range(cut):
            rep.sync(max_commands=2)
        t_crash = rep.t
        del rep  # SIGKILL stand-in: nothing is closed or flushed

        rep2 = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                            directory=rdir, replica_id=6)
        assert rep2.t == t_crash, "durable cursor survived the crash"
        assert rep2.catch_up() == 0
        assert rep2.t == host.store.t
        assert rep2.state_hash() == host.state_hash()


_REPLICA_FOLLOW = """\
import pathlib
import sys
import time

import repro  # noqa: F401
from repro.core.state import init_state
from repro.net.client import RemoteShardClient, SocketTransport
from repro.net.replica import ReplicaStore

port, rdir, rounds = int(sys.argv[1]), pathlib.Path(sys.argv[2]), int(sys.argv[3])
genesis = None
if not (rdir / "store.json").exists():
    genesis = init_state({cap}, {dim})
rep = ReplicaStore(RemoteShardClient(SocketTransport("127.0.0.1", port)),
                   genesis, directory=rdir, replica_id=7)
if rounds:
    for _ in range(rounds):
        print("ACKED", rep.sync(max_commands=2), flush=True)
    time.sleep(600)  # hold the cursor until the parent SIGKILLs us
else:
    assert rep.catch_up() == 0
    print("DONE", rep.t, hex(rep.state_hash()), flush=True)
"""


def test_sigkilled_replica_restarts_and_catches_up(tmp_path):
    """The real thing: a durable replica subprocess follows a TCP primary,
    is SIGKILLed mid-stream, the primary keeps ingesting, and the
    restarted process converges to the primary's exact hash."""
    host, writer = _primary(tmp_path / "primary", batches=4, seed=77)
    server = ShardServer(host).start()
    script = tmp_path / "replica_follow.py"
    script.write_text(_REPLICA_FOLLOW.format(cap=CAP, dim=D))
    env = dict(os.environ, PYTHONPATH=str(SRC))
    argv = [sys.executable, str(script), str(server.port),
            str(tmp_path / "replica")]
    try:
        proc = subprocess.Popen(argv + ["2"], stdout=subprocess.PIPE,
                                text=True, env=env)
        try:
            acked = [proc.stdout.readline().split() for _ in range(2)]
        finally:
            proc.kill()  # SIGKILL — no atexit, no flush, no close
            proc.wait(timeout=30)
        assert [w[0] for w in acked] == ["ACKED", "ACKED"]
        t_acked = int(acked[-1][1])
        assert 0 < t_acked < host.store.t
        assert host.replica_cursors[7] == t_acked

        # the primary moves on while the replica is dead
        writer.append(_random_log(99, 5, ID_SPACE))

        done = subprocess.run(argv + ["0"], stdout=subprocess.PIPE,
                              text=True, env=env, timeout=300, check=True)
        word, t_s, h_s = done.stdout.strip().splitlines()[-1].split()
        assert word == "DONE"
        assert int(t_s) == host.store.t
        assert int(h_s, 16) == host.state_hash()
        assert host.replica_cursors[7] == host.store.t
    finally:
        server.close()


# --------------------------------------------------------------------------- #
# coordinator recovery over the wire (the transport-agnostic regression)
# --------------------------------------------------------------------------- #


def test_recover_rolls_back_ahead_shards_when_remote_reports_stale_cursor(
        tmp_path):
    """A remote shard that lost its recent commits (stale durable cursor)
    must make ``recover()`` roll the *ahead* shards back to the global
    minimum — the same min-cursor reconciliation as local shards, driven
    entirely through the wire client. Regression for the recovery path
    crashing on remote error types instead of reconciling."""
    n = 2
    genesis = distributed.init_sharded_host(n, CAP, D)
    hosts = [ShardHost(tmp_path / f"host_{s}",
                       distributed.shard_slice(genesis, s, n))
             for s in range(n)]
    clients = [RemoteShardClient(LocalTransport(h)) for h in hosts]
    remote = shard_wal.ShardedDurableStore(tmp_path / "coord",
                                           backends=clients)
    local = shard_wal.ShardedDurableStore(tmp_path / "local", genesis,
                                          n_shards=n)

    batches = [_random_log(50 + i, 6, ID_SPACE) for i in range(3)]
    advances = [remote.planned_advance(b) for b in batches]
    for b in batches:
        assert remote.append(b) == local.append(b)
    t_full = remote.t
    assert remote.restore_at(t_full)[1] == local.restore_at(t_full)[1]

    # shard 1 loses its last group (crash before that flush landed)
    t_stale = t_full - advances[-1]
    hosts[1].handle(p.Rollback(t=t_stale))
    clients[1].refresh_t()
    assert remote.shard_ts() == [t_full, t_stale]
    with pytest.raises(RuntimeError, match="diverged"):
        remote.append(batches[0])  # unreconciled stores refuse new appends

    state, h, t = remote.recover()
    assert t == t_stale
    assert remote.shard_ts() == [t_stale, t_stale]
    assert h == local.restore_at(t_stale)[1], \
        "wire reconciliation diverged from the local twin"
    # and the reconciled store ingests again, staying in lockstep
    assert remote.append(batches[0]) == t_stale + advances[0]


def test_remote_refusals_arrive_as_local_exception_families(tmp_path):
    """RemoteError subclasses ValueError and TransportError subclasses
    OSError — so coordinator code written for local shards (restore
    fallbacks, rollback refusals) needs no wire-specific handling."""
    host = ShardHost(tmp_path / "s", _genesis())
    client = RemoteShardClient(LocalTransport(host))
    client.append(_random_log(1, 4, ID_SPACE))
    with pytest.raises(ValueError):
        client.rollback_to(client.t + 10)  # refused server-side
    with pytest.raises(ValueError):
        client.restore_at(10 ** 6)
    dead = RemoteShardClient.__new__(RemoteShardClient)
    dead.transport = SocketTransport("127.0.0.1", 1)  # nothing listens here
    dead._rid = 0
    with pytest.raises(OSError):
        dead._request(p.Cursor(), p.CursorAck)


# --------------------------------------------------------------------------- #
# failover: SIGKILL the primary, promote a verified replica (DESIGN.md §9)
# --------------------------------------------------------------------------- #


def _spawn_primary(directory):
    """A real shard-server subprocess — the thing we can honestly SIGKILL.
    Returns (proc, writer_factory) once it prints its LISTENING line."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.net.server", "--dir", str(directory),
         "--capacity", str(CAP), "--dim", str(D), "--port", "0"],
        stdout=subprocess.PIPE, text=True,
        env=dict(os.environ, PYTHONPATH=str(SRC)))
    line = proc.stdout.readline().strip()
    assert line.startswith("LISTENING "), f"server failed to start: {line!r}"
    port = int(line.split()[1])
    return proc, lambda: RemoteShardClient(SocketTransport("127.0.0.1", port))


def _apply_prefix(batches, t_max):
    """Reference truth: the in-memory apply of the first ``t_max`` durable
    commands (replica cursors land on batch boundaries here)."""
    state, applied = _genesis(), 0
    for log in batches:
        if applied + len(log) > t_max:
            break
        state = machine.bulk_apply(state, log, ef_construction=32)
        applied += len(log)
    assert applied == t_max, "t_max is not a batch boundary"
    return state


@settings(max_examples=3)
@given(st.integers(0, 10 ** 6))
def test_sigkilled_primary_failover_promotes_max_proven_prefix(seed):
    """Property (the failover contract, DESIGN.md §9): SIGKILL the primary
    mid-grouped-ingest with two replicas at staggered cursors; promotion
    picks the max proven durable cursor, the promoted host's state and
    retrieval hashes equal an independent in-memory apply of exactly that
    prefix — every acked cursor survives, nothing past the max proven
    cursor is resurrected."""
    with tempfile.TemporaryDirectory() as td:
        _sigkill_failover_case(pathlib.Path(td), seed)


def _sigkill_failover_case(root, seed):
    proc, mk_writer = _spawn_primary(root / "primary")
    try:
        writer = mk_writer()
        batches = [_random_log(seed * 1000 + i, 4, ID_SPACE)
                   for i in range(4)]
        reps = [ReplicaStore(mk_writer(), _genesis(),
                             directory=root / f"replica_{i}", replica_id=i)
                for i in range(2)]

        writer.append_many(batches[:2])   # grouped ingest, part 1
        assert reps[0].catch_up() == 0    # replica 0 stops following here
        t_lag = reps[0].t
        writer.append(batches[2])
        assert reps[1].catch_up() == 0    # replica 1 proves one batch more
        t_max = reps[1].t
        assert 0 < t_lag < t_max == writer.t
        acked = {r.replica_id: r.t for r in reps}

        writer.append(batches[3])         # the unshipped suffix...
        t_dead = writer.t
        proc.kill()                       # ...dies with the primary
        proc.wait(timeout=30)

        host, winner_idx, t = promote_on_primary_loss(reps)
        assert winner_idx == 1 and t == t_max
        assert t == max(proven_cursor(r) for r in reps)
        assert host.store.t == t_max < t_dead, \
            "the dead primary's unshipped suffix was resurrected"
        assert all(host.store.t >= c for c in acked.values()), \
            "an acked cursor was lost in failover"

        ref = _apply_prefix(batches, t_max)
        assert host.state_hash() == hashing.hash_pytree(ref)
        q = _queries(seed)
        plan = query_lib.plan_query(shard_wal.live_count(ref), K, 64)
        ids, scores = query_lib.execute_plan(ref, q, K, plan)
        got = query_lib.execute_plan(host.state, q, K, plan)
        assert query_lib.retrieval_hash(*got) == query_lib.retrieval_hash(
            ids, scores)

        # the promoted host is a full primary: it ingests and serves tails
        new_writer = RemoteShardClient(LocalTransport(host))
        new_writer.append(_random_log(seed + 7, 3, ID_SPACE))
        straggler = reps[0]
        straggler.primary = new_writer
        assert straggler.catch_up() == 0
        assert straggler.t == host.store.t
        assert straggler.state_hash() == host.state_hash()
        host.close()
    finally:
        proc.kill()
        proc.wait(timeout=30)


def test_tampered_replica_wal_refuses_promotion(tmp_path):
    """Replace a replica's WAL with a valid-but-different log (same length,
    different commands): the promotion cross-check — winner's durable
    prefix hashed at each survivor's proven cursor — catches it and the
    promotion is refused with ReplicaDivergence."""
    host, _ = _primary(tmp_path / "primary", batches=3, seed=5)
    good = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                        directory=tmp_path / "replica_good", replica_id=0)
    good.catch_up()

    # forge a straggler whose WAL is valid (every record self-checks, the
    # state replays cleanly) but is NOT a prefix of the primary's log
    forged_primary, _ = _primary(tmp_path / "forged", batches=2, seed=6)
    forged = ReplicaStore(RemoteShardClient(LocalTransport(forged_primary)),
                          _genesis(), directory=tmp_path / "replica_forged",
                          replica_id=1)
    forged.catch_up()
    assert 0 < forged.t < good.t  # good wins on cursor, forged is checked

    with pytest.raises(ReplicaDivergence, match="promotion refused"):
        promote_on_primary_loss([good, forged])

    # and an in-memory follower can never be the proven winner at all
    mem = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       replica_id=2)
    mem.catch_up()
    with pytest.raises(ValueError, match="no proven durable prefix"):
        promote_on_primary_loss([mem])


def test_promote_after_crash_window_recovers_from_wal(tmp_path):
    """A replica SIGKILLed between its WAL append and its state commit
    reopens one verified slice ahead in the WAL; promotion reconciles
    through recover() and lands on the durable cursor."""
    host, _ = _primary(tmp_path / "primary", batches=2, seed=9)
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       directory=tmp_path / "replica", replica_id=0)
    rep.catch_up()
    # simulate the crash window: durable cursor ahead of committed state
    rep.state, rep._hash, rep.t = rep.store.restore_at(0)[0], \
        hashing.hash_pytree(_genesis()), 0
    assert rep.store.t > rep.t
    promoted = rep.promote()
    assert promoted.store.t == host.store.t
    assert promoted.state_hash() == host.state_hash()
    promoted.close()


def test_promote_sharded_reconciles_staggered_winners(tmp_path):
    """Sharded failover: per-shard winners at staggered cursors are rolled
    back to one global cursor through ShardedDurableStore.recover() — the
    promoted fleet lands on exactly the prefix every shard can prove, and
    it hash-matches the local twin at that cursor."""
    n = 2
    genesis = distributed.init_sharded_host(n, CAP, D)
    hosts = [ShardHost(tmp_path / f"host_{s}",
                       distributed.shard_slice(genesis, s, n))
             for s in range(n)]
    clients = [RemoteShardClient(LocalTransport(h)) for h in hosts]
    store = shard_wal.ShardedDurableStore(tmp_path / "coord",
                                          backends=clients)
    local = shard_wal.ShardedDurableStore(tmp_path / "local", genesis,
                                          n_shards=n)
    batches = [_random_log(30 + i, 5, ID_SPACE) for i in range(3)]
    for b in batches:
        assert store.append(b) == local.append(b)

    reps = [ReplicaStore(RemoteShardClient(LocalTransport(hosts[s])),
                         distributed.shard_slice(genesis, s, n),
                         directory=tmp_path / f"replica_{s}", replica_id=s)
            for s in range(n)]
    reps[0].catch_up()                    # shard 0's replica proves it all
    t_stale = store.t - store.planned_advance(batches[-1])
    # shard 1's replica lags one group (its primary dies before it tails)
    while reps[1].t < t_stale:
        reps[1].sync(max_commands=1)
    assert reps[1].t == t_stale

    new_store, state, h, t, promoted = promote_sharded(
        tmp_path / "coord2", [[reps[0]], [reps[1]]])
    assert t == t_stale, "the fleet reconciled past a shard's proven prefix"
    assert [ph.store.t for ph in promoted] == [t_stale, t_stale], \
        "recover() did not roll the ahead winner back"
    assert h == local.restore_at(t_stale)[1], \
        "promoted fleet diverged from the local twin at the global cursor"
    # the reconciled fleet is a serving store again: it ingests in lockstep
    local2 = shard_wal.ShardedDurableStore(tmp_path / "local2", genesis,
                                           n_shards=n)
    for b in batches[:2]:
        local2.append(b)
    assert new_store.append(batches[2]) == local2.append(batches[2])
    for ph in promoted:
        ph.close()


# --------------------------------------------------------------------------- #
# side-table shipping: the promoted replica serves prefixes without refilling
# --------------------------------------------------------------------------- #


def test_side_table_ships_verified_and_survives_promotion(tmp_path):
    host, writer = _primary(tmp_path / "primary", batches=2, seed=3)
    host.side_table.put(1, b"alpha tokens")
    host.side_table.put(2, b"beta tokens")
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       directory=tmp_path / "replica", replica_id=0)
    rep.catch_up()
    assert rep.side_table.record_count == host.side_table.record_count
    assert rep.side_table.entries == host.side_table.entries
    assert rep.side_table.digest_at(2) == host.side_table.digest_at(2)

    # incremental: later puts (including an overwrite) ship on the next sync
    host.side_table.put(1, b"alpha v2")
    writer.append(_random_log(8, 3, ID_SPACE))
    rep.catch_up()
    assert rep.side_table.record_count == 3
    assert rep.side_table.entries[1] == b"alpha v2"

    promoted = rep.promote()
    assert promoted.side_table.entries == host.side_table.entries
    assert promoted.side_table.digest_at(3) == host.side_table.digest_at(3)
    # the promoted host serves SIDE_TAIL itself: a next-generation replica
    # mirrors from it without the old primary
    recs, count, digest = RemoteShardClient(
        LocalTransport(promoted)).side_tail(0)
    assert count == 3 and digest == host.side_table.digest_at(3)
    promoted.close()


def test_tampered_side_table_shipment_commits_nothing(tmp_path):
    """A man-in-the-middle rewriting a shipped side-table record (and
    re-signing the per-record digest) is caught by the chained prefix
    digest, and the mirror commits nothing."""
    import struct as _struct

    host, _ = _primary(tmp_path / "primary", batches=1, seed=4)
    host.side_table.put(7, b"payload")

    def rewrite(m):
        body = _struct.pack("<QI", 7, 4) + b"evil"
        raw = body + _struct.pack(
            "<Q", hashing.digest_bytes(body))  # self-consistent record
        return dataclasses.replace(m, records=(raw,))

    class TamperSide:
        def __init__(self, inner):
            self.inner = inner

        def request(self, data):
            resp = self.inner.request(data)
            msg, rid, _ = p.decode_frame(resp)
            if isinstance(msg, p.SideTailAck) and msg.records:
                return p.encode_frame(rewrite(msg), rid)
            return resp

        def close(self):
            self.inner.close()

    client = RemoteShardClient(LocalTransport(host))
    client.transport = TamperSide(LocalTransport(host))
    rep = ReplicaStore(client, _genesis(),
                       directory=tmp_path / "replica", replica_id=0)
    with pytest.raises(ReplicaDivergence, match="side-table prefix digest"):
        rep.catch_up()
    assert rep.side_table.record_count == 0, "a tampered record committed"


# --------------------------------------------------------------------------- #
# pipelined catch-up + teardown
# --------------------------------------------------------------------------- #


def test_pipelined_catch_up_is_bit_identical_to_serial(tmp_path):
    host, _ = _primary(tmp_path / "primary", batches=4, seed=11)
    serial = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                          _genesis(), replica_id=0)
    serial.catch_up(max_commands=3)
    piped = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                         _genesis(), replica_id=1,
                         prefetch=RemoteShardClient(LocalTransport(host)))
    assert piped.catch_up(max_commands=3, pipeline=True) == 0
    assert piped.t == serial.t == host.store.t
    assert piped.state_hash() == serial.state_hash() == host.state_hash()
    q = _queries(11)
    assert piped.retrieval_hash(q, K) == serial.retrieval_hash(q, K)


def test_pipelined_catch_up_rides_prefetch_faults(tmp_path):
    """A lossy prefetch connection only costs the fallback round trip —
    verification and convergence are unchanged."""
    host, _ = _primary(tmp_path / "primary", batches=4, seed=13)
    flaky = RemoteShardClient(LocalTransport(host))
    flaky.transport = FaultyTransport(LocalTransport(host), 13,
                                      drop_resp=0.5)
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       replica_id=2, prefetch=flaky)
    assert rep.catch_up(max_commands=2, pipeline=True,
                        max_rounds=200) == 0
    assert rep.t == host.store.t
    assert rep.state_hash() == host.state_hash()


def test_pipeline_without_prefetch_client_is_refused(tmp_path):
    host, _ = _primary(tmp_path / "primary", batches=1, seed=1)
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis())
    with pytest.raises(ValueError, match="prefetch"):
        rep.catch_up(pipeline=True)


def test_replica_double_close_is_a_noop(tmp_path):
    host, _ = _primary(tmp_path / "primary", batches=1, seed=2)
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       directory=tmp_path / "replica", replica_id=0,
                       prefetch=RemoteShardClient(LocalTransport(host)))
    rep.catch_up(pipeline=True)
    rep.close()
    rep.close()  # regression: the second close must be a no-op
    host.close()
    host.close()


# --------------------------------------------------------------------------- #
# residual lag: "caught up" vs "gave up" (DESIGN.md §12)
# --------------------------------------------------------------------------- #


def test_catch_up_reports_residual_lag_when_outrun(tmp_path):
    """A hot primary that writes between the replica's tails outruns a
    bounded catch-up: the call must report the residual lag, not return
    silently looking identical to convergence. Regression for catch_up's
    give-up path being indistinguishable from the caught-up path."""
    host, writer = _primary(tmp_path / "primary", batches=1, seed=21)

    class HotPrimary:
        """Every TAIL the replica sends lands AFTER a fresh ingest burst —
        the primary's cursor always moves first."""

        def __init__(self, inner):
            self.inner = inner
            self.hot = True
            self.rounds = 0

        def request(self, data):
            msg, _, _ = p.decode_frame(data)
            if isinstance(msg, p.Tail) and self.hot:
                self.rounds += 1
                writer.append(_random_log(200 + self.rounds, 3, ID_SPACE))
            return self.inner.request(data)

        def close(self):
            self.inner.close()

    hot = {}

    def factory(inner):
        hot["t"] = HotPrimary(inner)
        return hot["t"]

    rep = _replica_over(host, factory, replica_id=0)
    lag = rep.catch_up(max_commands=2, max_rounds=3)
    assert lag > 0, "an outrun catch-up must report residual lag, not 0"
    assert rep.t < host.store.t
    # the reported lag is the primary's probed cursor distance exactly
    assert rep.t + lag == host.store.t
    # the writer quiesces: the next catch-up proves convergence (lag 0)
    hot["t"].hot = False
    assert rep.catch_up() == 0
    assert rep.t == host.store.t
    assert rep.state_hash() == host.state_hash()


# --------------------------------------------------------------------------- #
# live followers: the background tailer (DESIGN.md §12)
# --------------------------------------------------------------------------- #


def _await(cond, *, timeout=60.0, tick=0.002):
    deadline = time.time() + timeout
    while not cond():
        assert time.time() < deadline, "condition never held"
        time.sleep(tick)


def test_follower_thread_converges_without_explicit_sync(tmp_path):
    """The tentpole property: under a FollowerPolicy the replica tails the
    primary on its own thread — repeated ingest bursts converge with NO
    caller-side sync, every converged cursor is hash-proven, and the
    follower stops/restarts cleanly."""
    host, writer = _primary(tmp_path / "primary", batches=1, seed=31)
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       replica_id=0)
    rep.start_following(FollowerPolicy(max_lag_commands=0, max_delay_s=0.01))
    assert rep.following
    rep.start_following()  # idempotent while running
    try:
        for i in range(3):
            writer.append(_random_log(40 + i, 4, ID_SPACE))
            rep.notify_writes()
            _await(lambda: rep.t >= host.store.t)
            state, h, t = rep.snapshot()
            assert t == host.store.t and h == host.state_hash()
        assert rep.follow_error is None
        assert host.replica_cursors[0] == host.store.t
    finally:
        rep.stop_following()
    assert not rep.following
    # the stopped follower is still a valid replica, and restartable
    writer.append(_random_log(99, 3, ID_SPACE))
    assert rep.catch_up() == 0
    rep.start_following(FollowerPolicy(max_delay_s=0.01))
    assert rep.following
    rep.close()  # close() stops the thread too
    assert not rep.following


def test_follower_rides_transport_faults(tmp_path):
    """A lossy wire only delays the follower — the thread retries
    idempotently and still converges to the proven cursor."""
    host, writer = _primary(tmp_path / "primary", batches=2, seed=37)
    rep = _replica_over(
        host,
        lambda inner: FaultyTransport(inner, 37, drop_req=0.3,
                                      drop_resp=0.3, duplicate=0.2),
        replica_id=4)
    rep.start_following(FollowerPolicy(max_delay_s=0.005))
    try:
        writer.append(_random_log(55, 4, ID_SPACE))
        _await(lambda: rep.t >= host.store.t)
        assert rep.state_hash() == host.state_hash()
        assert rep.follow_error is None and rep.following
    finally:
        rep.stop_following()


def test_follower_halts_on_divergence_and_records_why(tmp_path):
    """Divergence is terminal for a follower: the thread must STOP (not
    spin retrying a proven mismatch), record the exception on
    ``follow_error``, and commit nothing."""
    host, _ = _primary(tmp_path / "primary", batches=2, seed=33)
    rep = _replica_over(
        host,
        lambda inner: _TamperTransport(
            inner,
            lambda m: dataclasses.replace(m, state_hash=m.state_hash ^ 1)),
        replica_id=1)
    rep.start_following(FollowerPolicy(max_delay_s=0.005))
    _await(lambda: not rep.following)
    assert isinstance(rep.follow_error, ReplicaDivergence)
    assert rep.t == 0, "a diverged follower committed a cursor"
    assert host.replica_cursors == {}, "a diverged follower acked"


def test_wedged_host_times_out_as_transport_error():
    """A host that accepts but never answers must surface as a bounded
    ``TransportError`` — the hang the failure detector cannot see.
    Regression for the socket deadline not covering request I/O."""
    wedge = socket.socket()
    try:
        wedge.bind(("127.0.0.1", 0))
        wedge.listen(1)  # connections complete in the backlog; no reads
        port = wedge.getsockname()[1]
        tr = SocketTransport("127.0.0.1", port, timeout=0.2)
        t0 = time.time()
        with pytest.raises(p.TransportError):
            tr.request(p.encode_frame(p.Cursor(), 1))
        assert time.time() - t0 < 5.0, "the deadline did not bound the hang"
        tr.close()
    finally:
        wedge.close()


# --------------------------------------------------------------------------- #
# lease-based failure detection → automatic verified promotion (§12)
# --------------------------------------------------------------------------- #


def test_detector_auto_promotes_sigkilled_primary_to_max_proven_prefix(
        tmp_path):
    """The full loop, against a real SIGKILLed subprocess primary: healthy
    beats hold the lease; the kill expires it after ``lease_misses``
    bounded probes; the detector auto-promotes WITHOUT any caller action;
    and the promoted host's state equals an independent in-memory apply of
    exactly the max proven WAL prefix — the unshipped suffix dies with
    the primary, every acked cursor survives."""
    proc, mk_writer = _spawn_primary(tmp_path / "primary")
    try:
        writer = mk_writer()
        batches = [_random_log(9000 + i, 4, ID_SPACE) for i in range(4)]
        reps = [ReplicaStore(mk_writer(), _genesis(),
                             directory=tmp_path / f"replica_{i}",
                             replica_id=i)
                for i in range(2)]
        writer.append_many(batches[:2])
        assert reps[0].catch_up() == 0    # the straggler stops here
        writer.append(batches[2])
        assert reps[1].catch_up() == 0    # the winner proves one batch more
        t_max = reps[1].t
        assert 0 < reps[0].t < t_max == writer.t
        writer.append(batches[3])         # unshipped: dies with the primary

        det = FailureDetector(
            [mk_writer()], [reps],
            lease=LeaseConfig(interval_s=0.01, lease_misses=2), epoch=1)
        assert det.poll() == {}           # healthy: the lease holds
        assert det.events[-1]["event"] == "beat"
        assert det.misses == [0]

        proc.kill()
        proc.wait(timeout=30)
        det.start()                       # automatic from here on
        _await(lambda: 0 in det.promoted)
        det.stop()
        host = det.promoted[0]
        assert host.store.t == t_max, \
            "promotion missed the max proven prefix (or resurrected " \
            "the dead primary's suffix)"
        ref = _apply_prefix(batches, t_max)
        assert host.state_hash() == hashing.hash_pytree(ref), \
            "promoted state != independent apply of the proven prefix"
        assert det.epoch == 2, "failover did not bump the fleet epoch"
        assert host.epoch == 2, "the promoted host was not stamped"
        assert load_epoch(host.store.dir) == 2, "the stamp is not durable"
        kinds = [e["event"] for e in det.events]
        assert kinds.count("miss") >= 2 and "lease_expired" in kinds \
            and "promoted" in kinds
        host.close()
    finally:
        proc.kill()
        proc.wait(timeout=30)


def test_stale_epoch_append_is_fenced_after_failover(tmp_path):
    """The fencing invariant: after a promotion bumps the fleet epoch, a
    revived old primary is stamped by the first beat that reaches it and
    its pre-failover writers' APPENDs are refused with StaleEpochError —
    durably, across a host restart."""
    host = ShardHost(tmp_path / "old", _genesis())
    old_writer = RemoteShardClient(LocalTransport(host))
    old_writer.append(_random_log(1, 4, ID_SPACE))
    rep = ReplicaStore(RemoteShardClient(LocalTransport(host)), _genesis(),
                       directory=tmp_path / "replica", replica_id=0)
    assert rep.catch_up() == 0

    # failover (the detector's move): epoch 1 -> 2, verified promotion
    new_host, _, t = promote_on_primary_loss([rep], epoch=2)
    assert new_host.epoch == 2 and load_epoch(new_host.store.dir) == 2

    # the "dead" primary comes back; the detector's beat stamps it
    probe = RemoteShardClient(LocalTransport(host))
    assert probe.epoch == 0               # handshake predates the stamp
    probe.bump_epoch(2)                   # the detector's fleet epoch
    _, host_epoch, _ = probe.heartbeat()
    assert host_epoch == 2
    assert host.epoch == 2 and load_epoch(host.store.dir) == 2

    # the old regime's writer can never commit again
    t_before = host.store.t
    with pytest.raises(p.RemoteError) as ei:
        old_writer.append(_random_log(2, 4, ID_SPACE))
    assert ei.value.kind == "StaleEpochError"
    assert host.store.t == t_before, "a fenced append advanced the cursor"

    # the fence survives a restart of the old host
    host.close()
    revived = ShardHost(tmp_path / "old")
    assert revived.epoch == 2
    err = revived.handle(p.Append(
        base_t=revived.store.t, epoch=0,
        logs=(log_to_bytes(_random_log(3, 4, ID_SPACE)),)))
    assert isinstance(err, p.ErrorMsg) and err.kind == "StaleEpochError"

    # a fresh client learns the current epoch at handshake and may write
    fresh = RemoteShardClient(LocalTransport(revived))
    assert fresh.epoch == 2
    fresh.append(_random_log(4, 4, ID_SPACE))

    # and the NEW primary serves the new regime's writes
    nw = RemoteShardClient(LocalTransport(new_host))
    assert nw.epoch == 2
    nw.append(_random_log(5, 4, ID_SPACE))
    new_host.close()
    revived.close()


def test_detector_adopts_a_greater_epoch_from_beats(tmp_path):
    """Two detectors, one fleet: a beat against a host stamped by a newer
    regime out-epochs this detector — it adopts (fleet epoch is a max),
    so a later promotion by EITHER detector still fences the older one."""
    host = ShardHost(tmp_path / "s", _genesis())
    stamped = RemoteShardClient(LocalTransport(host))
    stamped.bump_epoch(7)
    stamped.heartbeat()                   # host durably at epoch 7
    det = FailureDetector([RemoteShardClient(LocalTransport(host))], [[]],
                          epoch=1)
    det.poll()
    assert det.epoch == 7, "the detector did not adopt the newer epoch"
    host.close()
