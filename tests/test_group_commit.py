"""Group commit + scheduled compaction (DESIGN.md §6).

The acceptance contract: a group-committed WAL replays bit-identically to
a fsync-per-command WAL of the same commands; killing the process mid-
group (random byte truncation inside the group's write) recovers to the
last whole record, and ``recover()`` hash-matches ``replay(genesis,
log[:t])`` at that prefix; the dead-ratio compaction policy rewrites the
log only when due and never changes the replayed state.
"""
import time

import jax
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import commands, durability, hashing, machine, wal
from repro.core.state import init_state
from test_bulk_apply import _random_log
from test_durability import _hash_trace, _record_boundaries

D = 8


# --------------------------------------------------------------------------- #
# append_many: one fsync, same bits
# --------------------------------------------------------------------------- #


def test_append_many_is_bit_identical_to_sequential_appends(tmp_path):
    log = _random_log(3, 48, id_space=12)
    a = wal.WriteAheadLog(tmp_path / "a", D, segment_records=16)
    for i in range(48):
        a.append(log.slice(i, i + 1))
    b = wal.WriteAheadLog(tmp_path / "b", D, segment_records=16)
    b.append_many([log.slice(i, i + 12) for i in range(0, 48, 12)])
    assert a.t == b.t == 48
    genesis = init_state(32, D)
    ha = hashing.hash_pytree(machine.replay(genesis, a.read_range(0, 48)))
    hb = hashing.hash_pytree(machine.replay(genesis, b.read_range(0, 48)))
    assert ha == hb == hashing.hash_pytree(machine.replay(genesis, log))
    # and the on-disk segments are byte-identical: grouping is invisible
    for pa, pb in zip(sorted((tmp_path / "a").glob("seg_*.wal")),
                      sorted((tmp_path / "b").glob("seg_*.wal"))):
        assert pa.read_bytes() == pb.read_bytes()


def test_append_many_does_not_merge_nop_runs_across_logs(tmp_path):
    """Byte-invisibility of grouping, worst case: logs that end/start with
    zero-NOP runs. Merging runs across the boundary would change record
    framing and the FNV chain — two replicas grouping differently would
    stop comparing bit-identically for audit."""
    nop2 = machine._pad_log(commands.empty_log(D), 2)
    nop3 = machine._pad_log(commands.empty_log(D), 3)
    a = wal.WriteAheadLog(tmp_path / "a", D, segment_records=1024)
    a.append(nop2)
    a.append(nop3)
    b = wal.WriteAheadLog(tmp_path / "b", D, segment_records=1024)
    b.append_many([nop2, nop3])
    assert a.t == b.t == 5
    sa = next((tmp_path / "a").glob("seg_*.wal")).read_bytes()
    sb = next((tmp_path / "b").glob("seg_*.wal")).read_bytes()
    assert sa == sb, "NOP runs must not merge across log boundaries"


def test_writer_keeps_pending_group_on_sink_failure(tmp_path):
    """A sink exception must not discard the pending (never-acked) group:
    it stays buffered and a retry flush lands every command."""
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    log = _random_log(20, 12, id_space=6)
    gw.submit(log)

    real = w.append_many
    w.append_many = lambda logs: (_ for _ in ()).throw(OSError("disk full"))
    with pytest.raises(OSError):
        gw.flush()
    assert gw.pending == 12, "failed flush must keep the group retryable"
    w.append_many = real
    assert gw.flush() == 12 and gw.pending == 0
    genesis = init_state(16, D)
    assert (hashing.hash_pytree(machine.replay(genesis, w.read_range(0, 12)))
            == hashing.hash_pytree(machine.replay(genesis, log)))


def test_writer_retry_after_partial_flush_never_duplicates(tmp_path):
    """A flush that fails midway through a multi-segment group leaves its
    durable prefix on disk (per-segment fsync); the retry must append only
    the rest — duplicating the prefix would silently corrupt replay."""
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    log = _random_log(22, 20, id_space=8)
    gw.submit(log)

    orig = w._open_segment
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 2:  # the roll into the second segment fails
            raise OSError("disk full")
        orig()

    w._open_segment = flaky
    with pytest.raises(OSError):
        gw.flush()
    assert w.t == 8, "first segment's records are durable"
    assert gw.pending == 12, "only the un-durable suffix stays pending"
    w._open_segment = orig
    assert gw.flush() == 20
    genesis = init_state(32, D)
    assert (hashing.hash_pytree(machine.replay(genesis, w.read_range(0, 20)))
            == hashing.hash_pytree(machine.replay(genesis, log)))


def test_compaction_failure_propagates_not_swallowed(tmp_path):
    """A failure inside scheduled compaction itself (corrupt mid-history
    segment) must surface on append, not vanish — the CheckpointManager
    no-silent-loss discipline applies to compaction too."""
    genesis = init_state(6, D)
    policy = wal.CompactionPolicy(dead_ratio=0.01, min_commands=8,
                                  check_every=8)
    store = durability.DurableStore(tmp_path, genesis, segment_records=4,
                                    compaction=policy)
    log = _churny_log(13, 12)
    store.append(log.slice(0, 6))  # below check_every: no check yet
    seg0 = sorted((tmp_path / "wal").glob("seg_*.wal"))[0]
    raw = bytearray(seg0.read_bytes())
    raw[-4] ^= 0xFF  # corrupt an interior segment's chain mid-history
    seg0.write_bytes(bytes(raw))
    with pytest.raises(ValueError):
        store.append(log.slice(6, 10))  # check due → read hits corruption


def test_compaction_skips_when_genesis_snapshot_unavailable(tmp_path):
    """Deleting the t=0 snapshot (so genesis cannot be restored) must skip
    scheduled compaction silently — that one case is legitimate."""
    genesis = init_state(6, D)
    policy = wal.CompactionPolicy(dead_ratio=0.01, min_commands=8,
                                  check_every=8)
    store = durability.DurableStore(tmp_path, genesis, segment_records=64,
                                    compaction=policy)
    for p in (tmp_path / "snapshots").glob("t_*.vsn2"):
        p.unlink()
    log = _churny_log(14, 24)
    store.append(log)  # check due, genesis unavailable: no raise, no compact
    assert store.t == 24
    h = hashing.hash_pytree(
        machine.bulk_apply(genesis, store.wal.read_range(0, 24)))
    assert h == hashing.hash_pytree(machine.replay(genesis, log))


def test_append_many_skips_empty_logs(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=16)
    assert w.append_many([]) == 0
    assert w.append_many([commands.empty_log(D)]) == 0
    log = _random_log(0, 4, id_space=4)
    assert w.append_many([commands.empty_log(D), log]) == 4


# --------------------------------------------------------------------------- #
# GroupCommitWriter: batching semantics
# --------------------------------------------------------------------------- #


def test_writer_flushes_at_max_batch(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=16, max_delay_s=3600))
    log = _random_log(1, 40, id_space=10)
    for i in range(40):
        gw.submit(log.slice(i, i + 1))
    assert gw.groups == 2 and w.t == 32  # two full groups committed
    assert gw.pending == 8 and gw.target_t == 40
    assert gw.flush() == 40 and gw.pending == 0
    assert gw.groups == 3


def test_writer_flushes_on_deadline(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=0.01))
    log = _random_log(2, 4, id_space=4)
    gw.submit(log.slice(0, 2))
    assert w.t == 0  # buffered: deadline not reached
    time.sleep(0.02)
    gw.submit(log.slice(2, 4))  # deadline observed at the next submit
    assert w.t == 4 and gw.pending == 0


def test_writer_commands_not_durable_until_flush(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=64, max_delay_s=3600))
    log = _random_log(4, 10, id_space=6)
    gw.submit(log)
    assert w.t == 0 and gw.pending == 10  # buffered only — never acked
    # the crash model: the writer dies; a reopened WAL has nothing
    reopened = wal.WriteAheadLog(tmp_path, D)
    assert reopened.t == 0


# --------------------------------------------------------------------------- #
# crash inside a group commit: random truncation, longest-whole-record rule
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(10))
def test_kill_mid_group_recovers_last_whole_record(tmp_path, seed):
    """Kill the process mid-group-write (random byte cut inside the group's
    extent): recovery keeps the longest whole-record prefix — possibly a
    partial group, never a partial record — and recover() hash-matches
    replay(genesis, log[:t])."""
    rng = np.random.default_rng(seed)
    log = _random_log(seed, 30, id_space=8)
    genesis = init_state(32, D)
    ref = _hash_trace(genesis, log)

    wdir = tmp_path / "wal"
    w = wal.WriteAheadLog(wdir, D, segment_records=1024)
    w.append(log.slice(0, 6))  # acked pre-group history
    seg = next(wdir.glob("seg_*.wal"))
    group_start = seg.stat().st_size
    w.append_many([log.slice(i, i + 8) for i in range(6, 30, 8)])

    header, bounds = _record_boundaries(seg)
    cut = int(rng.integers(group_start, seg.stat().st_size))
    with open(seg, "r+b") as f:
        f.truncate(cut)

    expect_t = max([c for o, c in bounds if o <= cut], default=0)
    assert expect_t >= 6, "acked pre-group records must survive"

    recovered = wal.WriteAheadLog(wdir)
    assert recovered.t == expect_t
    state = machine.replay(genesis, recovered.read_range(0, expect_t))
    assert hashing.hash_pytree(state) == ref[expect_t]
    # the group is re-submittable: extend to the full log and verify
    recovered.append(log.slice(expect_t, 30))
    state2 = machine.replay(genesis, recovered.read_range(0, 30))
    assert hashing.hash_pytree(state2) == ref[30]


def test_store_recover_after_torn_group(tmp_path):
    """DurableStore + writer: flushed groups are durable, a torn in-flight
    suffix is truncated, recover() lands exactly on the flushed prefix."""
    log = _random_log(21, 24, id_space=8)
    genesis = init_state(32, D)
    ref = _hash_trace(genesis, log)
    store = durability.DurableStore(tmp_path / "s", genesis,
                                    segment_records=1024)
    gw = wal.GroupCommitWriter(
        store, wal.GroupCommitPolicy(max_batch=8, max_delay_s=3600))
    for i in range(24):
        gw.submit(log.slice(i, i + 1))
    assert store.t == 24  # three full groups
    seg = sorted((tmp_path / "s" / "wal").glob("seg_*.wal"))[-1]
    with open(seg, "ab") as f:
        f.write(b"\x99torn in-flight group bytes\x99")

    reopened = durability.DurableStore(tmp_path / "s")
    state, h, t = reopened.recover()
    assert t == 24 and h == ref[24]


# --------------------------------------------------------------------------- #
# truncate_to: the group-rollback primitive
# --------------------------------------------------------------------------- #


def test_truncate_to_record_boundary_and_reappend(tmp_path):
    log = _random_log(5, 30, id_space=10)
    genesis = init_state(32, D)
    ref = _hash_trace(genesis, log)
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)  # multi-segment
    w.append(log)
    w.truncate_to(13)
    assert w.t == 13
    assert hashing.hash_pytree(
        machine.replay(genesis, w.read_range(0, 13))) == ref[13]
    w.append(log.slice(13, 30))  # the chain extends cleanly after rollback
    assert hashing.hash_pytree(
        machine.replay(genesis, w.read_range(0, 30))) == ref[30]


def test_truncate_to_splits_a_nop_run(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    log = _random_log(6, 5, id_space=4)
    w.append(log)
    w.append(machine._pad_log(commands.empty_log(D), 12))  # 12-NOP run
    w.truncate_to(9)  # lands inside the run
    assert w.t == 9
    back = w.read_range(0, 9)
    assert (np.asarray(back.opcode)[5:] == commands.NOP).all()
    genesis = init_state(16, D)
    expect = log.concat(machine._pad_log(commands.empty_log(D), 4))
    assert (hashing.hash_pytree(machine.replay(genesis, back))
            == hashing.hash_pytree(machine.replay(genesis, expect)))


def test_truncate_to_refuses_gaps_and_stays_intact(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    w.append(_random_log(7, 6, id_space=4))
    w.reset_to(20)  # lost region [6, 20)
    w.append(_random_log(8, 4, id_space=4))
    with pytest.raises(ValueError, match="gap|retained"):
        w.truncate_to(10)  # inside the hole
    assert w.t == 24, "a refused truncate must not damage the WAL"
    w.truncate_to(20)  # the hole's end is a valid rollback point
    assert w.t == 20


def test_rollback_to_drops_snapshots_above(tmp_path):
    log = _random_log(9, 20, id_space=8)
    genesis = init_state(32, D)
    store = durability.DurableStore(tmp_path, genesis, segment_records=1024)
    store.append(log)
    s = machine.bulk_apply(genesis, log.slice(0, 10))
    store.checkpoint(jax.tree.map(np.asarray, s))
    s2 = machine.bulk_apply(s, log.slice(10, 20))
    store.checkpoint(jax.tree.map(np.asarray, s2))
    assert store.snapshots() == [0, 10, 20]
    store.rollback_to(15)
    assert store.snapshots() == [0, 10] and store.t == 15
    _, h = store.restore_at(15)
    assert h == _hash_trace(genesis, log)[15]


# --------------------------------------------------------------------------- #
# scheduled compaction: dead-ratio driven, replay-invariant
# --------------------------------------------------------------------------- #


def _churny_log(seed, n):
    # small id space + deletes/meta churn: plenty of provably-dead commands
    return _random_log(seed, n, id_space=5, opcode_weights=(1, 4, 2, 1, 1, 4))


def test_compaction_policy_triggers_on_dead_ratio(tmp_path):
    genesis = init_state(6, D)
    policy = wal.CompactionPolicy(dead_ratio=0.05, min_commands=20,
                                  check_every=20)
    store = durability.DurableStore(tmp_path, genesis, segment_records=8,
                                    compaction=policy)
    log = _churny_log(10, 60)
    ref = hashing.hash_pytree(machine.replay(genesis, log))
    for i in range(0, 60, 10):
        store.append(log.slice(i, i + 10))
    after = sum(p.stat().st_size
                for p in (tmp_path / "wal").glob("seg_*.wal"))
    # the policy fired at least once: NOP-run RLE + dropped INSERT payloads
    # must have shrunk the on-disk log relative to the raw append total
    raw = durability.DurableStore(tmp_path.parent / "raw", genesis,
                                  segment_records=8)
    raw.append(log)
    raw_bytes = sum(p.stat().st_size
                    for p in (tmp_path.parent / "raw" / "wal").glob("*.wal"))
    assert after < raw_bytes, "scheduled compaction never fired"
    _, h = store.restore_at(60)
    assert h == ref, "compaction changed the replayed state"


def test_compaction_policy_respects_min_commands(tmp_path):
    genesis = init_state(6, D)
    policy = wal.CompactionPolicy(dead_ratio=0.01, min_commands=10_000,
                                  check_every=10)
    store = durability.DurableStore(tmp_path, genesis, segment_records=8,
                                    compaction=policy)
    log = _churny_log(11, 40)
    store.append(log)
    raw = durability.DurableStore(tmp_path.parent / "raw2", genesis,
                                  segment_records=8)
    raw.append(log)
    a = sorted(p.read_bytes()
               for p in (tmp_path / "wal").glob("seg_*.wal"))
    b = sorted(p.read_bytes()
               for p in (tmp_path.parent / "raw2" / "wal").glob("seg_*.wal"))
    assert a == b, "compaction must not run below min_commands"


def test_compact_min_dead_ratio_skips_without_rewrite(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)
    log = _churny_log(12, 50)
    w.append(log)
    genesis = init_state(6, D)
    stats = w.compact(genesis, min_dead_ratio=0.999)
    assert stats["skipped"] == 1
    assert stats["bytes_after"] == stats["bytes_before"]
    assert 0.0 < stats["dead_ratio"] < 0.999
    stats2 = w.compact(genesis)  # no gate: the rewrite happens
    assert stats2["skipped"] == 0
    assert stats2["bytes_after"] < stats["bytes_before"]
    h = hashing.hash_pytree(machine.bulk_apply(genesis, w.read_range(0, 50)))
    assert h == hashing.hash_pytree(machine.replay(genesis, log))


# --------------------------------------------------------------------------- #
# timer-thread flush: max_delay_s as a wall-clock bound (DESIGN.md §7)
# --------------------------------------------------------------------------- #


def test_timer_flush_holds_deadline_without_reads(tmp_path):
    """With timer_flush, max_delay_s must hold with NO read barrier and NO
    further submits: the deadline thread makes the pending group durable."""
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(w, wal.GroupCommitPolicy(
        max_batch=1 << 20, max_delay_s=0.02, timer_flush=True))
    log = _random_log(30, 6, id_space=4)
    gw.submit(log)
    deadline = time.monotonic() + 5.0
    while gw.pending and time.monotonic() < deadline:
        time.sleep(0.005)
    assert gw.pending == 0 and w.t == 6, \
        "the timer thread must flush without any read or submit"
    assert gw.timer_flushes >= 1
    genesis = init_state(16, D)
    assert (hashing.hash_pytree(machine.replay(genesis, w.read_range(0, 6)))
            == hashing.hash_pytree(machine.replay(genesis, log)))
    gw.close()


def test_timer_flush_preserves_submit_order(tmp_path):
    """Deadline-ordering regression: timer flushes racing foreground
    submits must never reorder, duplicate or drop commands — the WAL holds
    exactly the submit-order log."""
    w = wal.WriteAheadLog(tmp_path, D, segment_records=16)
    gw = wal.GroupCommitWriter(w, wal.GroupCommitPolicy(
        max_batch=1 << 20, max_delay_s=0.002, timer_flush=True))
    log = _random_log(31, 40, id_space=8)
    for i in range(40):
        gw.submit(log.slice(i, i + 1))
        if i % 7 == 0:
            time.sleep(0.004)  # let deadline flushes land mid-stream
    gw.close()  # stops the timer and flushes the tail
    assert w.t == 40 and gw.pending == 0
    genesis = init_state(32, D)
    assert (hashing.hash_pytree(machine.replay(genesis, w.read_range(0, 40)))
            == hashing.hash_pytree(machine.replay(genesis, log))), \
        "timer flushes reordered or lost commands"


def test_timer_flush_close_is_idempotent_and_final(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(w, wal.GroupCommitPolicy(
        max_batch=1 << 20, max_delay_s=3600, timer_flush=True))
    log = _random_log(32, 4, id_space=4)
    gw.submit(log)
    gw.close()   # flushes the pending group even though the deadline is far
    assert w.t == 4 and gw.pending == 0
    gw.close()   # idempotent
    assert w.t == 4


def test_failed_flush_that_landed_everything_clears_the_deadline(tmp_path):
    """A sink failure AFTER the whole group landed (e.g. a post-append
    compaction error) empties the buffer via _drop_landed; the deadline
    must clear with it, or a timer_flush thread would see an expired
    deadline with nothing to flush and busy-spin forever."""
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    gw = wal.GroupCommitWriter(
        w, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    log = _random_log(33, 8, id_space=4)
    gw.submit(log)

    real = w.append_many

    def land_then_raise(logs):
        real(logs)
        raise OSError("post-append failure (compaction)")

    w.append_many = land_then_raise
    with pytest.raises(OSError):
        gw.flush()
    assert gw.pending == 0 and w.t == 8, "the group itself landed"
    assert gw._oldest is None, "an emptied buffer must clear its deadline"


def test_sharded_partial_flush_drops_whole_landed_batches(tmp_path):
    """A sharded sink advances in padded-batch units, not raw commands: a
    flush that lands batch 1 on every shard then fails must pop exactly
    batch 1 from the buffer — slicing raw commands off the front (the
    single-host rule) would re-append a durable prefix and corrupt replay."""
    import jax.numpy as jnp
    from repro.core import boundary, commands, distributed, shard_wal
    rng = np.random.default_rng(40)
    n, ns = 16, 3
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    b1, b2 = log.slice(0, 8), log.slice(8, 16)
    genesis = distributed.init_sharded_host(ns, 16, D)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=ns)
    gw = wal.GroupCommitWriter(
        store, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    gw.submit(b1)
    gw.submit(b2)

    real = store.append_many_routed

    def first_batch_only(routed_logs):
        real(routed_logs[:1])  # batch 1 lands on every shard, then "disk full"
        raise OSError("disk full")

    store.append_many_routed = first_batch_only
    with pytest.raises(OSError):
        gw.flush()
    assert store.t > 0, "batch 1 landed"
    assert gw.pending == 8, "only batch 2 may stay queued for retry"
    store.append_many_routed = real
    gw.flush()

    ref = shard_wal.bulk_apply_sharded(
        shard_wal.bulk_apply_sharded(genesis, b1, ns), b2, ns)
    _, h = store.restore_at(store.t)
    assert h == hashing.hash_pytree(ref), \
        "retry after a partial sharded flush duplicated durable commands"
