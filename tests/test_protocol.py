"""Wire-protocol conformance: byte-frozen goldens + torn-frame properties.

Every message type in repro.net.protocol is pinned three ways:

  1. round trip — encode_frame(decode_frame(x)) is the identity on messages;
  2. golden fixtures — the exact frame bytes are frozen in
     tests/fixtures/golden_wire/ (regenerate deliberately with
     ``PYTHONPATH=src python scripts/gen_golden_wire.py`` when WIRE_FORMAT
     is bumped), so encoding can never drift silently;
  3. corruption properties — every truncation point and every bit flip of
     a valid frame decodes to ProtocolError, never to a different message.

The exemplar list (``_golden_messages``) is imported by the generator
script, mirroring how scripts/gen_golden_snapshots.py imports
``_golden_state`` from test_durability — one source of truth for what the
goldens contain.
"""
import json
import pathlib

import pytest

from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import commands
from repro.net import protocol as p

FIXTURES = pathlib.Path(__file__).resolve().parent / "fixtures" / "golden_wire"


def _golden_log_bytes() -> bytes:
    """A tiny deterministic command log blob (integer-only commands — no
    float boundary — so the bytes are platform-invariant)."""
    log = commands.link_cmd(3, 7, dim=4)
    log = log.concat(commands.unlink_cmd(3, 7, dim=4))
    log = log.concat(commands.set_meta_cmd(3, 1, 42, dim=4))
    log = log.concat(commands.delete_cmd(7, dim=4))
    return commands.log_to_bytes(log)


def _golden_messages():
    """One deterministic exemplar per wire message type: (name, msg, rid).

    Field values are chosen to exercise non-default content (so a field
    accidentally dropped from FIELDS changes the bytes) while staying
    platform-invariant. The generator script freezes these frames into
    tests/fixtures/golden_wire/.
    """
    blob = _golden_log_bytes()
    ids = (0).to_bytes(8, "little") + (5).to_bytes(8, "little")
    scores = (123).to_bytes(8, "little") + (-4 % (1 << 64)).to_bytes(8, "little")
    return [
        ("hello", p.Hello(epoch=3), 1),
        ("hello_ack",
         p.HelloAck(dim=4, itemsize=4, contract="Q16.16", t=9,
                    state_hash=0x1122334455667788, epoch=3), 1),
        ("cursor", p.Cursor(), 2),
        ("cursor_ack", p.CursorAck(t=13), 2),
        ("append", p.Append(base_t=13, epoch=3, logs=(blob, blob)), 3),
        ("append_ack", p.AppendAck(t=21), 3),
        ("query",
         p.Query(k=5, ef=64, route="exact", use_kernel=False, nq=2, dim=4,
                 itemsize=4, data=bytes(range(32))), 4),
        ("query_ack", p.QueryAck(nq=1, k=2, ids=ids, scores=scores), 4),
        ("checkpoint", p.Checkpoint(t=21, expect_hash=0xDEADBEEFCAFEF00D), 5),
        ("checkpoint_ack", p.CheckpointAck(t=21, bytes_written=4096), 5),
        ("restore_at", p.RestoreAt(t=8), 6),
        ("state_ack",
         p.StateAck(t=8, state_hash=0x0123456789ABCDEF,
                    blob=b"\x00v1-snapshot-stand-in\xff"), 6),
        ("recover", p.Recover(), 7),
        ("rollback", p.Rollback(t=5), 8),
        ("rollback_ack", p.RollbackAck(t=5), 8),
        ("tail", p.Tail(from_t=5, max_commands=128), 9),
        ("tail_ack",
         p.TailAck(from_t=5, t_end=9, state_hash=0xFEEDFACE01020304,
                   log=blob), 9),
        ("replica_ack",
         p.ReplicaCursorAck(replica_id=7, t=9,
                            state_hash=0xFEEDFACE01020304), 10),
        ("replica_ack_ack", p.ReplicaCursorAckAck(t=9), 10),
        ("state_hash", p.StateHashReq(), 11),
        ("state_hash_ack",
         p.StateHashAck(t=9, state_hash=0xFEEDFACE01020304), 11),
        ("read_range", p.ReadRange(t0=2, t1=9), 12),
        ("log_ack", p.LogAck(log=blob), 12),
        ("retain", p.Retain(keep=2), 13),
        ("retain_ack",
         p.RetainAck(snapshots_dropped=3, wal_segments_dropped=2,
                     chunks_dropped=11, oldest_snapshot=16), 13),
        ("side_tail", p.SideTail(from_index=2), 15),
        ("side_tail_ack",
         p.SideTailAck(from_index=2, count=4,
                       table_digest=0xFEEDFACE01020304,
                       records=(b"\x01side-record-a\xfe",
                                b"\x02side-record-bb\xfd")), 15),
        ("heartbeat", p.Heartbeat(node_id=2, epoch=3), 16),
        ("heartbeat_ack",
         p.HeartbeatAck(t=9, epoch=3,
                        state_hash=0xFEEDFACE01020304), 16),
        ("error",
         p.ErrorMsg(kind="ValueError", message="cursor 99 ahead of WAL"),
         14),
    ]


# --------------------------------------------------------------------------- #
# round trip + golden coverage
# --------------------------------------------------------------------------- #


def test_round_trip_every_message_type():
    for name, msg, rid in _golden_messages():
        frame = p.encode_frame(msg, rid)
        decoded, rid2, end = p.decode_frame(frame)
        assert decoded == msg, name
        assert rid2 == rid, name
        assert end == len(frame), name
        assert p.frame_length(frame[:p.HEADER_BYTES]) == len(frame), name


def test_goldens_cover_every_message_type():
    covered = {msg.TYPE for _, msg, _ in _golden_messages()}
    assert covered == set(p.MESSAGE_TYPES), (
        "every wire message type must have a golden exemplar")
    names = [name for name, _, _ in _golden_messages()]
    assert len(names) == len(set(names)) == len(p.MESSAGE_TYPES)


def test_golden_fixture_bytes_frozen():
    """The on-disk frames decode AND match today's encoder byte-for-byte.

    A mismatch means the wire format drifted without a WIRE_FORMAT bump —
    regenerate with scripts/gen_golden_wire.py only on a deliberate format
    change.
    """
    index = json.loads((FIXTURES / "golden_wire.json").read_text())
    assert index["wire_format"] == p.WIRE_FORMAT
    exemplars = {name: (msg, rid) for name, msg, rid in _golden_messages()}
    assert set(index["frames"]) == set(exemplars)
    for name, meta in index["frames"].items():
        frozen = (FIXTURES / f"{name}.bin").read_bytes()
        msg, rid = exemplars[name]
        assert p.encode_frame(msg, rid) == frozen, name
        assert len(frozen) == meta["bytes"], name
        decoded, rid2, _ = p.decode_frame(frozen)
        assert decoded == msg and rid2 == rid, name
        assert meta["msg_type"] == msg.TYPE, name


def test_concatenated_frames_decode_in_sequence():
    msgs = _golden_messages()
    stream = b"".join(p.encode_frame(m, rid) for _, m, rid in msgs)
    off = 0
    for name, msg, rid in msgs:
        decoded, rid2, off = p.decode_frame(stream, off)
        assert decoded == msg and rid2 == rid, name
    assert off == len(stream)


# --------------------------------------------------------------------------- #
# corruption: torn, truncated, bit-flipped — always ProtocolError
# --------------------------------------------------------------------------- #


def test_every_truncation_point_is_rejected():
    """decode_frame(frame[:cut]) raises for EVERY proper prefix — a torn
    frame can never decode as a shorter valid message."""
    for name, msg, rid in _golden_messages():
        frame = p.encode_frame(msg, rid)
        for cut in range(len(frame)):
            with pytest.raises(p.ProtocolError):
                p.decode_frame(frame[:cut])


@settings(max_examples=60)
@given(st.integers(0, len(p.MESSAGE_TYPES) - 1), st.integers(0, 10 ** 9))
def test_single_bit_flip_is_rejected(which, pos_seed):
    _, msg, rid = _golden_messages()[which]
    frame = bytearray(p.encode_frame(msg, rid))
    bit = pos_seed % (len(frame) * 8)
    frame[bit // 8] ^= 1 << (bit % 8)
    with pytest.raises(p.ProtocolError):
        p.decode_frame(bytes(frame))


@settings(max_examples=40)
@given(st.integers(0, len(p.MESSAGE_TYPES) - 1), st.integers(1, 64))
def test_appended_garbage_does_not_confuse_offsets(which, extra):
    """Trailing bytes after a frame are simply not consumed: next_offset
    points exactly past the frame, and garbage alone fails to decode."""
    _, msg, rid = _golden_messages()[which]
    frame = p.encode_frame(msg, rid)
    data = frame + bytes((extra * 37 + i) % 251 for i in range(extra))
    decoded, rid2, end = p.decode_frame(data)
    assert decoded == msg and rid2 == rid and end == len(frame)
    with pytest.raises(p.ProtocolError):
        p.decode_frame(data, end)


def test_trailing_garbage_inside_payload_rejected():
    """A payload longer than its message's canonical encoding is garbage,
    even when the frame digest is recomputed to match."""
    payload = p.CursorAck(t=5).encode_payload() + b"\x00"
    import struct

    from repro.core import hashing
    head = (p.MAGIC + struct.pack("<II", p.WIRE_FORMAT, p.CURSOR_ACK)
            + struct.pack("<QI", 1, len(payload)))
    body = head + payload
    frame = body + struct.pack("<Q", hashing.digest_bytes(body))
    with pytest.raises(p.ProtocolError, match="trailing garbage"):
        p.decode_frame(frame)


def test_unknown_message_type_rejected():
    import struct

    from repro.core import hashing
    head = (p.MAGIC + struct.pack("<II", p.WIRE_FORMAT, 200)
            + struct.pack("<QI", 1, 0))
    frame = head + struct.pack("<Q", hashing.digest_bytes(head))
    with pytest.raises(p.ProtocolError, match="unknown message type"):
        p.decode_frame(frame)


def test_bad_magic_and_format_rejected():
    frame = bytearray(p.encode_frame(p.Cursor(), 1))
    bad_magic = b"XXXX" + bytes(frame[4:])
    with pytest.raises(p.ProtocolError, match="magic"):
        p.frame_length(bad_magic[:p.HEADER_BYTES])
    bad_fmt = bytes(frame[:4]) + (99).to_bytes(4, "little") + bytes(frame[8:])
    with pytest.raises(p.ProtocolError, match="wire format"):
        p.frame_length(bad_fmt[:p.HEADER_BYTES])
    with pytest.raises(p.ProtocolError, match="short frame header"):
        p.frame_length(b"VWIR")


def test_invalid_utf8_string_rejected():
    frame = bytearray(p.encode_frame(p.ErrorMsg(kind="E", message="x"), 1))
    # kind's single utf8 byte sits right after its u32 length prefix
    idx = p.HEADER_BYTES + 4
    assert frame[idx:idx + 1] == b"E"
    frame[idx] = 0xFF
    import struct

    from repro.core import hashing
    body = bytes(frame[:-p.DIGEST_BYTES])
    frame = body + struct.pack("<Q", hashing.digest_bytes(body))
    with pytest.raises(p.ProtocolError, match="utf8"):
        p.decode_frame(frame)


# --------------------------------------------------------------------------- #
# error surfacing
# --------------------------------------------------------------------------- #


def test_expect_turns_error_frame_into_remote_error():
    err = p.ErrorMsg(kind="KeyError", message="no snapshot at 7")
    with pytest.raises(p.RemoteError) as ei:
        p.expect(err, p.CursorAck)
    assert ei.value.kind == "KeyError"
    assert "no snapshot at 7" in str(ei.value)
    assert isinstance(ei.value, ValueError)  # coordinator fallback contract


def test_expect_rejects_wrong_ack_type():
    with pytest.raises(p.ProtocolError, match="expected AppendAck"):
        p.expect(p.CursorAck(t=1), p.AppendAck)


def test_transport_error_is_oserror():
    # the coordinator's _RESTORE_ERRORS envelope catches OSError/ValueError;
    # both wire exceptions must land inside it for transport-agnosticism.
    assert issubclass(p.TransportError, OSError)
    assert issubclass(p.RemoteError, ValueError)
    assert issubclass(p.ProtocolError, ValueError)


def test_stale_epoch_error_crosses_wire_as_remote_kind():
    """A fenced primary sees ``StaleEpochError`` as a RemoteError whose
    ``kind`` names the fencing class — clients distinguish "I was
    deposed" from every other append failure without a new frame type."""
    assert issubclass(p.StaleEpochError, ValueError)
    err = p.ErrorMsg(kind="StaleEpochError",
                     message="append epoch 1 < host epoch 2: fenced")
    with pytest.raises(p.RemoteError) as ei:
        p.raise_if_error(err)
    assert ei.value.kind == "StaleEpochError"
    assert "fenced" in str(ei.value)


def test_error_round_trips_exact_kind():
    frame = p.encode_frame(p.ErrorMsg(kind="RuntimeError", message="m"), 9)
    decoded, _, _ = p.decode_frame(frame)
    with pytest.raises(p.RemoteError) as ei:
        p.raise_if_error(decoded)
    assert ei.value.kind == "RuntimeError"
