"""HLO walker validation: must match XLA cost_analysis on loop-free modules
and correctly multiply loop bodies by trip count."""
import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.roofline.analysis import Roofline
from repro.roofline.hlo_walk import HloModule, walk_hlo


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _cost(compiled):
    from repro.core import compat
    return compat.cost_analysis(compiled)


def test_matches_cost_analysis_single_matmul():
    x = jnp.zeros((256, 512), jnp.float32)
    w = jnp.zeros((512, 128), jnp.float32)
    c = _compiled(lambda a, b: a @ b, x, w)
    t = walk_hlo(c.as_text())
    ca = _cost(c)
    assert t.flops == ca["flops"] == 2 * 256 * 512 * 128
    assert t.bytes == ca["bytes accessed"]


def test_scan_multiplies_trip_count():
    x = jnp.zeros((128, 128), jnp.float32)
    ws = jnp.zeros((7, 128, 128), jnp.float32)

    def scanned(x, ws):
        def step(h, w):
            return h @ w, None
        return jax.lax.scan(step, x, ws)[0]

    c = _compiled(scanned, x, ws)
    t = walk_hlo(c.as_text())
    per_step = 2 * 128 ** 3
    assert abs(t.flops - 7 * per_step) / (7 * per_step) < 0.05


def test_nested_scan_multiplies():
    x = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((3, 4, 64, 64), jnp.float32)

    def nested(x, ws):
        def outer(h, wgroup):
            def inner(h, w):
                return h @ w, None
            h, _ = jax.lax.scan(inner, h, wgroup)
            return h, None
        return jax.lax.scan(outer, x, ws)[0]

    c = _compiled(nested, x, ws)
    t = walk_hlo(c.as_text())
    per_step = 2 * 64 ** 3
    assert abs(t.flops - 12 * per_step) / (12 * per_step) < 0.05


def test_elementwise_flops_counted():
    x = jnp.zeros((1024,), jnp.float32)
    c = _compiled(lambda a: jnp.tanh(a) + a * 2.0, x)
    t = walk_hlo(c.as_text())
    assert 2 * 1024 <= t.flops <= 4 * 1024


def test_dominant_term_logic():
    r = Roofline(flops=1e15, hbm_bytes=1e9, wire_bytes=1e9, chips=256,
                 collectives={})
    assert r.dominant == "compute"
    r = Roofline(flops=1e12, hbm_bytes=1e14, wire_bytes=0, chips=256,
                 collectives={})
    assert r.dominant == "memory"
    r = Roofline(flops=1e12, hbm_bytes=1e9, wire_bytes=1e13, chips=256,
                 collectives={})
    assert r.dominant == "collective"


def test_bytes_min_leq_bytes():
    x = jnp.zeros((256, 256), jnp.float32)

    def f(a):
        h = jnp.tanh(a @ a)
        return jnp.sum(h * 3.0)

    t = walk_hlo(_compiled(f, x).as_text())
    assert 0 < t.bytes_min <= t.bytes
