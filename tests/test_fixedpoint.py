"""Property tests for the fixed-point substrate (paper §5.1 invariants)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import fixedpoint as fp
from repro.core.contracts import CONTRACTS, Q8_8, Q16_16

finite_floats = st.floats(min_value=-100.0, max_value=100.0,
                          allow_nan=False, allow_infinity=False)
unit_floats = st.floats(min_value=-1.0, max_value=1.0,
                        allow_nan=False, allow_infinity=False)


@given(st.lists(finite_floats, min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_encode_decode_roundtrip_error_bounded(xs):
    x = np.asarray(xs, np.float64)
    raw = fp.encode(x, Q16_16)
    back = np.asarray(fp.decode(raw, Q16_16))
    clipped = np.clip(x, Q16_16.min_value, Q16_16.max_value)
    assert np.all(np.abs(back - clipped) <= Q16_16.resolution)


@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=2, max_size=64))
@settings(max_examples=50, deadline=None)
def test_integer_sum_is_order_invariant(raws):
    """The paper's core argument: integer addition is associative, so ANY
    summation order gives the same bits. Floats fail this; ints cannot."""
    a = np.asarray(raws, np.int64)
    rng = np.random.default_rng(0)
    total = None
    for _ in range(5):
        perm = rng.permutation(len(a))
        s = int(jnp.sum(jnp.asarray(a)[perm]))
        if total is None:
            total = s
        assert s == total


@given(st.lists(unit_floats, min_size=4, max_size=64))
@settings(max_examples=30, deadline=None)
def test_float_sum_order_sensitivity_exists_but_fixed_point_immune(xs):
    """Companion to the above: the same permutation game on float32 partial
    sums CAN produce different bits (we don't require it for every draw),
    while the quantized path is always bit-stable."""
    x = np.asarray(xs, np.float32)
    raw = fp.encode(x, Q16_16).astype(np.int64)
    rng = np.random.default_rng(1)
    baseline = int(raw.sum())
    for _ in range(4):
        perm = rng.permutation(len(raw))
        assert int(raw[perm].sum()) == baseline


@given(st.lists(st.floats(min_value=-0.99, max_value=0.99, allow_nan=False),
                min_size=2, max_size=32))
@settings(max_examples=50, deadline=None)
def test_qdot_matches_float_within_quantization(xs):
    x = np.asarray(xs, np.float64)
    raw = fp.encode(x, Q16_16)
    got = float(fp.decode(fp.qdot(raw, raw), Q16_16))
    want = float(np.dot(x, x))
    # quantization error: ~n * resolution * |x| per term + final rounding
    tol = len(x) * Q16_16.resolution * 4 + Q16_16.resolution
    assert abs(got - want) <= tol


@given(st.integers(0, 2**62 - 1))
@settings(max_examples=200, deadline=None)
def test_isqrt_exact(n):
    r = int(fp.isqrt(jnp.asarray([n], jnp.int64))[0])
    assert r == math.isqrt(n)


@given(st.lists(st.floats(min_value=-5, max_value=5, allow_nan=False),
                min_size=2, max_size=48).filter(
                    lambda v: sum(abs(t) for t in v) > 0.1))
@settings(max_examples=50, deadline=None)
def test_qnorm_unit_length(xs):
    x = np.asarray(xs, np.float64)
    n = fp.qnorm(fp.encode(x, Q16_16), contract=Q16_16)
    d = np.asarray(fp.decode(n, Q16_16))
    assert abs(float(d @ d) - 1.0) < 1e-3


@given(st.floats(min_value=-200, max_value=200, allow_nan=False),
       st.floats(min_value=-200, max_value=200, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_saturation_clamps(a, b):
    ra, rb = fp.encode(np.float64(a), Q8_8), fp.encode(np.float64(b), Q8_8)
    s = fp.qadd(ra, rb, Q8_8)
    assert Q8_8.min_raw <= int(s) <= Q8_8.max_raw


def test_q32_generic_path_refuses_but_limb_path_works():
    from repro.core.contracts import Q32_32
    raw = fp.encode(np.float64(0.5), Q32_32)
    with pytest.raises(NotImplementedError):
        fp.qmul(raw, raw, Q32_32)          # generic narrow-contract path
    # add/sub remain exact
    assert int(fp.qadd(raw, raw, Q32_32)) == 2 * int(raw)
    # the limb-based route is exact: 0.5 * 0.5 == 0.25 at Q32.32
    got = int(fp.qmul_q32(jnp.asarray(raw), jnp.asarray(raw)))
    assert got == (1 << 30), got           # 0.25 * 2^32
    v = fp.encode(np.asarray([0.5, -0.25, 0.125]), Q32_32)
    dot = int(fp.qdot_q32(jnp.asarray(v), jnp.asarray(v)))
    want = int(round((0.25 + 0.0625 + 0.015625) * (1 << 32)))
    assert abs(dot - want) <= 1


@pytest.mark.parametrize("name", ["Q8.8", "Q16.16", "Q2.13"])
def test_contract_determinism_is_contract_independent(name):
    """Paper §6: determinism holds for ANY precision contract."""
    c = CONTRACTS[name]
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, 64)
    raw = fp.encode(x, c)
    a = fp.qdot_wide(raw, raw, contract=c)
    b = fp.qdot_wide(raw[::-1].copy(), raw[::-1].copy(), contract=c)
    assert int(a) == int(b)
