"""128-bit limb arithmetic vs Python bigints (the Q32.32 'future' contract)."""
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import limbs

i64 = st.integers(-(2**62), 2**62 - 1)


@given(i64, i64)
@settings(max_examples=200, deadline=None)
def test_mul_i64_exact(a, b):
    w = limbs.mul_i64_i64(jnp.asarray([a], jnp.int64), jnp.asarray([b], jnp.int64))
    got = limbs.to_python_int(tuple(x[0] for x in w))
    assert got == a * b


# contract-realistic Q32.32 raws: |v| ≤ 2.0 → |raw| ≤ 2^33; the 128-bit
# accumulator then has ≥ 2^(127-66) = 2^61 elements of headroom
q32_raw = st.integers(-(2**33), 2**33)


@given(st.lists(q32_raw, min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_qdot_q32_wide_exact(xs):
    a = jnp.asarray(xs, jnp.int64)
    w = limbs.qdot_q32_wide(a, a)
    got = limbs.to_python_int(w)
    want = sum(x * x for x in xs)
    assert got == want


@given(st.lists(i64, min_size=1, max_size=2))
@settings(max_examples=50, deadline=None)
def test_qdot_extreme_magnitudes_small_n(xs):
    """Full int64 range is exact while the true sum fits 128 bits (n ≤ 2)."""
    a = jnp.asarray(xs, jnp.int64)
    got = limbs.to_python_int(limbs.qdot_q32_wide(a, a))
    assert got == sum(x * x for x in xs)


@given(st.lists(q32_raw, min_size=2, max_size=32))
@settings(max_examples=30, deadline=None)
def test_wide_sum_order_invariant(xs):
    """The paper's argument extended to 128 bits: any permutation, same bits."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(xs, jnp.int64)
    b = jnp.asarray(xs[::-1], jnp.int64)
    base = limbs.to_python_int(limbs.qdot_q32_wide(a, a))
    perm = rng.permutation(len(xs))
    ap = jnp.asarray(np.asarray(xs)[perm], jnp.int64)
    assert limbs.to_python_int(limbs.qdot_q32_wide(ap, ap)) == base


def test_q32_dot_renormalize_and_saturate():
    # small values: exact renormalization
    one = 1 << 32  # Q32.32 representation of 1.0
    a = jnp.asarray([one, one // 2], jnp.int64)
    out = int(limbs.q32_dot_to_q32(a, a))
    want = (one * one + (one // 2) ** 2) >> 32
    assert out == want
    # huge values: saturates rather than wrapping
    big = jnp.asarray([2**62 - 1] * 4, jnp.int64)
    assert int(limbs.q32_dot_to_q32(big, big)) == 2**63 - 1
    neg = jnp.asarray([-(2**62)] * 4, jnp.int64)
    assert int(limbs.q32_dot_to_q32(neg, big)) == -(2**63)


def test_wide_add_neg_roundtrip():
    a = limbs.from_int64(jnp.asarray([12345678901234], jnp.int64))
    na = limbs.wide_neg(a)
    z = limbs.wide_add(a, na)
    assert limbs.to_python_int(tuple(x[0] for x in z)) == 0
