"""bulk_apply ≡ replay: the equivalence contract of the vectorized ingest
path (DESIGN.md §3).

``machine.bulk_apply`` may segment, scatter and batch however it likes — but
the final state must be **hash-identical** (``hashing.hash_pytree``) to the
one-command-at-a-time ``machine.replay`` on the same log. These tests prove
that on randomized logs covering all six opcodes plus the known hard cases:
duplicate-id upserts, DELETE→INSERT slot-reuse cycles (stale HNSW edges!),
full-arena rejection, NOP padding, and ``version`` accounting.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import boundary, commands, hashing, machine
from repro.core.commands import (DELETE, INSERT, LINK, NOP, SET_META, UNLINK,
                                 DEFAULT_CONTRACT)
from repro.core.state import init_state, slot_of_id

D = 8


def _vec(rng):
    return boundary.normalize_embedding(
        rng.normal(size=(D,)).astype(np.float32))


def _random_log(seed: int, n: int, id_space: int,
                opcode_weights=(1, 3, 1, 1, 1, 1)) -> commands.CommandLog:
    """A random mixed log: all six opcodes, duplicate ids, invalid targets."""
    rng = np.random.default_rng(seed)
    ops = rng.choice(6, size=n, p=np.asarray(opcode_weights) / sum(opcode_weights))
    recs = []
    for op in ops:
        i = int(rng.integers(0, id_space))
        j = int(rng.integers(0, id_space))
        if op == NOP:
            recs.append(commands._mk(NOP, D, DEFAULT_CONTRACT))
        elif op == INSERT:
            recs.append(commands.insert_cmd(i, np.asarray(_vec(rng))))
        elif op == DELETE:
            recs.append(commands.delete_cmd(i, D))
        elif op == LINK:
            recs.append(commands.link_cmd(i, j, D))
        elif op == UNLINK:
            recs.append(commands.unlink_cmd(i, j, D))
        else:
            recs.append(commands.set_meta_cmd(
                i, int(rng.integers(-1, 4)), int(rng.integers(-50, 50)), D))
    log = recs[0]
    for r in recs[1:]:
        log = log.concat(r)
    return log


def _assert_equivalent(s0, log, chunk=None):
    ref = machine.replay(s0, log)
    blk = machine.bulk_apply(s0, log)
    h_ref, h_blk = hashing.hash_pytree(ref), hashing.hash_pytree(blk)
    assert h_ref == h_blk, f"bulk_apply diverged: {h_ref:#x} != {h_blk:#x}"
    if chunk:
        chk = machine.apply_chunked(s0, log, chunk)
        assert hashing.hash_pytree(chk) == h_ref, "apply_chunked diverged"
    return ref, blk


# --------------------------------------------------------------------------- #
# randomized equivalence: ≥50 logs across all six opcodes
# --------------------------------------------------------------------------- #


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_bulk_apply_hash_identical_on_random_logs(seed):
    """50 randomized mixed logs: hash(bulk) == hash(replay), every time."""
    rng = np.random.default_rng(seed)
    cap = int(rng.choice([4, 8, 16, 32]))
    n = int(rng.integers(1, 36))
    id_space = int(rng.choice([3, 6, 24]))  # small ⇒ upserts + reuse cycles
    levels = int(rng.choice([2, 4]))
    log = _random_log(seed, n, id_space)
    s0 = init_state(cap, D, hnsw_levels=levels)
    _assert_equivalent(s0, log)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_bulk_apply_matches_chunked_replay(seed):
    """bulk == replay == apply_chunked: batch boundaries are invisible."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 28))
    log = _random_log(seed, n, id_space=6)
    s0 = init_state(16, D, hnsw_levels=2)
    _assert_equivalent(s0, log, chunk=int(rng.integers(1, 7)))


# --------------------------------------------------------------------------- #
# targeted hard cases
# --------------------------------------------------------------------------- #


def test_pure_insert_batch_and_version():
    rng = np.random.default_rng(0)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(24, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(24, dtype=jnp.int64), vecs)
    ref, blk = _assert_equivalent(init_state(64, D), log, chunk=5)
    assert int(blk.version) == len(log)
    assert int(blk.count) == 24


def test_duplicate_id_upserts():
    """Same id inserted repeatedly: later inserts overwrite in place."""
    rng = np.random.default_rng(1)
    log = commands.insert_cmd(7, np.asarray(_vec(rng)))
    for _ in range(5):
        log = log.concat(commands.insert_cmd(7, np.asarray(_vec(rng))))
        log = log.concat(commands.insert_cmd(9, np.asarray(_vec(rng))))
    ref, blk = _assert_equivalent(init_state(8, D), log)
    assert int(blk.count) == 2


def test_delete_insert_slot_reuse_cycles():
    """Tombstone reuse: freed slots keep stale HNSW edges — the case where a
    naive pre-scatter diverges from sequential replay."""
    rng = np.random.default_rng(2)
    log = commands.insert_batch(
        jnp.arange(6, dtype=jnp.int64),
        boundary.normalize_embedding(rng.normal(size=(6, D)).astype(np.float32)))
    for cycle in range(4):
        log = log.concat(commands.delete_cmd(cycle % 6, D))
        log = log.concat(commands.insert_cmd(100 + cycle, np.asarray(_vec(rng))))
        log = log.concat(commands.insert_cmd(200 + cycle, np.asarray(_vec(rng))))
        log = log.concat(commands.delete_cmd(200 + cycle, D))
    ref, blk = _assert_equivalent(init_state(8, D), log, chunk=3)
    # the reused slots really were recycled (arena stayed small)
    assert int(blk.count) <= 8


def test_full_arena_rejection():
    """Inserts past capacity are rejected but still advance logical time."""
    rng = np.random.default_rng(3)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(10, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(10, dtype=jnp.int64), vecs)
    ref, blk = _assert_equivalent(init_state(4, D), log)
    assert int(blk.count) == 4
    assert int(blk.version) == 10
    # delete frees a slot; the next fresh insert lands in it
    log2 = commands.delete_cmd(1, D).concat(
        commands.insert_cmd(99, np.asarray(_vec(rng))))
    ref2, blk2 = _assert_equivalent(blk, log2)
    assert int(slot_of_id(blk2, jnp.int64(99))) == int(
        slot_of_id(ref2, jnp.int64(99)))


def test_link_unlink_meta_runs():
    rng = np.random.default_rng(4)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(5, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(5, dtype=jnp.int64), vecs)
    for a in range(5):
        for b in range(5):
            log = log.concat(commands.link_cmd(a, b, D))
    log = log.concat(commands.unlink_cmd(0, 1, D))
    log = log.concat(commands.unlink_cmd(0, 1, D))  # double unlink = no-op
    for s in (-2, 0, 1, 7):  # out-of-range meta slots clip
        log = log.concat(commands.set_meta_cmd(2, s, 1000 + s, D))
        log = log.concat(commands.set_meta_cmd(2, s, 2000 + s, D))  # last wins
    log = log.concat(commands.set_meta_cmd(404, 0, 1, D))  # missing id no-op
    _assert_equivalent(init_state(8, D), log)


def test_nop_runs_only_bump_version():
    log = commands._mk(NOP, D, DEFAULT_CONTRACT)
    for _ in range(7):
        log = log.concat(commands._mk(NOP, D, DEFAULT_CONTRACT))
    ref, blk = _assert_equivalent(init_state(4, D), log)
    assert int(blk.version) == 8
    assert int(blk.count) == 0


def test_empty_log_is_identity():
    s0 = init_state(4, D)
    out = machine.bulk_apply(s0, commands.empty_log(D))
    assert hashing.hash_pytree(out) == hashing.hash_pytree(s0)


def test_small_ef_construction_still_bit_identical():
    """ef_construction < degree//2: the default path clip-repeats forward
    candidates (duplicate row entries), so the fast insert must bail to the
    reference implementation — the hash contract holds regardless."""
    rng = np.random.default_rng(6)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(30, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(30, dtype=jnp.int64), vecs)
    s0 = init_state(64, D, hnsw_levels=3, hnsw_degree=16)
    for ef in (4, 8):
        a = machine.replay(s0, log, ef_construction=ef)
        b = machine.bulk_apply(s0, log, ef_construction=ef)
        assert hashing.hash_pytree(a) == hashing.hash_pytree(b), ef


def test_bulk_apply_composes_across_calls():
    """bulk_apply(bulk_apply(S, L1), L2) == replay(S, L1 ++ L2)."""
    rng = np.random.default_rng(5)
    l1 = _random_log(50, 18, 6)
    l2 = _random_log(51, 18, 6)
    s0 = init_state(16, D, hnsw_levels=2)
    once = machine.bulk_apply(machine.bulk_apply(s0, l1), l2)
    ref = machine.replay(s0, l1.concat(l2))
    assert hashing.hash_pytree(once) == hashing.hash_pytree(ref)
