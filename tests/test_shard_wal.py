"""Per-shard WALs under one global cursor (DESIGN.md §6).

The acceptance contract: a ShardedDurableStore ingest (group-committed,
routed, NOP-padded to lockstep) + kill + ``recover()`` reproduces the
exact merged state hash AND ``retrieval_hash()`` of an uninterrupted
in-memory run; a crash between per-shard flushes reconciles to the last
globally-complete point (shards ahead roll back their never-acked
suffix); the merged-manifest hash is the whole-state hash.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import (boundary, commands, distributed, hashing, machine,
                        query, search, shard_wal, wal)
from repro.core.state import init_state
from test_bulk_apply import _random_log

D = 8
NS = 3
ID_SPACE_SMALL = 12  # < hnsw degree: every live node provably reachable


def _genesis(n_shards=NS, cap=16):
    return distributed.init_sharded_host(n_shards, cap, D)


def _batches(seed, n, step, id_space=20):
    log = _random_log(seed, n, id_space=id_space)
    return [log.slice(i, min(i + step, n)) for i in range(0, n, step)], log


def _apply_all(state, batches, n_shards=NS):
    for b in batches:
        state = shard_wal.bulk_apply_sharded(state, b, n_shards)
    return state


# --------------------------------------------------------------------------- #
# lockstep ingest + restore
# --------------------------------------------------------------------------- #


def test_sharded_ingest_restore_roundtrip(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(0, 48, 12)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=64, chunk_size=256)
    ref = genesis
    for b in batches:
        store.append(b)
        ref = shard_wal.bulk_apply_sharded(ref, b, NS)
    assert len(set(store.shard_ts())) == 1, "shards must stay in lockstep"
    state, h = store.restore_at(store.t)
    assert h == hashing.hash_pytree(ref)
    for la, lb in zip(jax.tree_util.tree_leaves(state),
                      jax.tree_util.tree_leaves(ref)):
        assert (np.asarray(la) == np.asarray(lb)).all()


def test_group_commit_path_is_bit_identical_to_per_batch(tmp_path):
    """Grouping batches must not change routing or padding: the WALs (and
    hence every restore) are bit-identical to the ungrouped path."""
    genesis = _genesis()
    batches, _ = _batches(1, 40, 8)
    a = shard_wal.ShardedDurableStore(tmp_path / "a", genesis, n_shards=NS,
                                      segment_records=256)
    for b in batches:
        a.append(b)
    b_store = shard_wal.ShardedDurableStore(tmp_path / "b", genesis,
                                            n_shards=NS, segment_records=256)
    gw = wal.GroupCommitWriter(
        b_store, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    for b in batches:
        gw.submit(b)
    gw.flush()
    assert a.t == b_store.t
    for s in range(NS):
        segs_a = sorted((tmp_path / "a" / f"shard_{s:04d}" / "wal").glob("*.wal"))
        segs_b = sorted((tmp_path / "b" / f"shard_{s:04d}" / "wal").glob("*.wal"))
        for pa, pb in zip(segs_a, segs_b):
            assert pa.read_bytes() == pb.read_bytes()


def test_restore_at_historic_batch_boundaries(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(2, 36, 9)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=64)
    ref = genesis
    cursors = [0]
    refs = {0: hashing.hash_pytree(genesis)}
    for b in batches:
        t = store.append(b)
        ref = shard_wal.bulk_apply_sharded(ref, b, NS)
        cursors.append(t)
        refs[t] = hashing.hash_pytree(ref)
    for t in cursors:  # every boundary, not just the head
        _, h = store.restore_at(t)
        assert h == refs[t], f"restore_at({t}) diverged"


# --------------------------------------------------------------------------- #
# the acceptance scenario: ingest + kill + recover == uninterrupted run
# --------------------------------------------------------------------------- #


def test_kill_and_recover_matches_uninterrupted_retrieval_hash(tmp_path):
    genesis = _genesis(cap=32)
    batches, _ = _batches(3, 60, 12)
    ref = _apply_all(genesis, batches)

    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    gw = wal.GroupCommitWriter(
        store, wal.GroupCommitPolicy(max_batch=24, max_delay_s=3600))
    for b in batches:
        gw.submit(b)
    gw.flush()
    t_acked = store.t

    # kill: torn, never-acked garbage lands on two shards' WAL tails
    for s in (0, 2):
        seg = sorted((tmp_path / f"shard_{s:04d}" / "wal").glob("*.wal"))[-1]
        with open(seg, "ab") as f:
            f.write(b"\xde\xadtorn group\xbe\xef" * (s + 1))

    reopened = shard_wal.ShardedDurableStore(tmp_path)
    state, h, t = reopened.recover()
    assert t == t_acked
    assert h == hashing.hash_pytree(ref), "recover diverged from the run"

    rng = np.random.default_rng(0)
    q = boundary.admit_query(rng.normal(size=(6, D)).astype(np.float32))
    ids_a, s_a = shard_wal.exact_search_sharded(ref, NS, q, 5)
    ids_b, s_b = shard_wal.exact_search_sharded(state, NS, q, 5)
    assert (query.retrieval_hash(ids_a, s_a)
            == query.retrieval_hash(ids_b, s_b))


def test_crash_between_shard_flushes_reconciles_to_min(tmp_path):
    """A kill between per-shard group flushes leaves a shard-order prefix
    holding the group; recover() must land every shard on the last
    globally-complete cursor and leave the fleet appendable in lockstep."""
    genesis = _genesis()
    batches, _ = _batches(4, 40, 10)
    acked, extra = batches[:3], batches[3]
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    ref = _apply_all(genesis, acked)
    for b in acked:
        store.append(b)
    t_acked = store.t

    # crash mid-append_many: only shard 0 got the next group
    routed = distributed.route_commands(extra, NS)
    store.shards[0].append(jax.tree.map(lambda a: a[0], routed))
    assert store.shards[0].t > t_acked

    reopened = shard_wal.ShardedDurableStore(tmp_path)
    state, h, t = reopened.recover()
    assert t == t_acked
    assert len(set(reopened.shard_ts())) == 1
    assert h == hashing.hash_pytree(ref)

    # the group was never acked upstream: the client re-submits it whole
    t2 = reopened.append(extra)
    ref2 = shard_wal.bulk_apply_sharded(ref, extra, NS)
    _, h2 = reopened.restore_at(t2)
    assert h2 == hashing.hash_pytree(ref2)


def test_writer_target_t_exact_for_sharded_sink(tmp_path):
    """target_t must predict the padded global cursor, not the raw command
    count: a batch advances every shard by its heaviest shard's share."""
    genesis = _genesis()
    batches, _ = _batches(11, 24, 8)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    gw = wal.GroupCommitWriter(
        store, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    predicted = [gw.submit(b) for b in batches]
    assert gw.flush() == predicted[-1], \
        "submit()'s returned cursor must be the one flush() lands on"
    # and intermediate predictions were the true per-batch boundaries
    replayed = shard_wal.ShardedDurableStore(tmp_path / "again", genesis,
                                             n_shards=NS)
    assert predicted == [replayed.append(b) for b in batches]


def test_append_to_diverged_store_refused_before_any_write(tmp_path):
    """Appending to an unreconciled post-crash store must be refused BEFORE
    anything is fsynced — otherwise the same logical offset would durably
    hold different batches on different shards."""
    genesis = _genesis()
    batches, _ = _batches(12, 20, 10)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    store.append(batches[0])
    routed = distributed.route_commands(batches[1], NS)
    store.shards[0].append(jax.tree.map(lambda a: a[0], routed))  # crash-ish
    before = store.shard_ts()
    with pytest.raises(RuntimeError, match="recover"):
        store.append(batches[1])
    assert store.shard_ts() == before, "refusal must not touch any WAL"


def test_checkpointed_recover_uses_snapshots_and_merged_hash(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(5, 30, 10)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256, chunk_size=256)
    ref = genesis
    for b in batches:
        store.append(b)
        ref = shard_wal.bulk_apply_sharded(ref, b, NS)
    store.checkpoint(ref)
    assert store.merged_records() == [int(np.asarray(ref.version)[0])]

    reopened = shard_wal.ShardedDurableStore(tmp_path)
    state, h, t = reopened.recover()
    assert t == store.t and h == hashing.hash_pytree(ref)


def test_merged_hash_tamper_detected(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(6, 20, 10)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    ref = _apply_all(genesis, batches)
    for b in batches:
        store.append(b)
    store.checkpoint(ref)
    t = store.t
    rec_path = store._merged_path(t)
    rec = json.loads(rec_path.read_text())
    rec["hash"] = f"{int(rec['hash'], 16) ^ 1:#018x}"
    rec_path.write_text(json.dumps(rec))
    with pytest.raises(ValueError, match="hash mismatch"):
        store.restore_at(t)


def test_checkpoint_refuses_diverged_cursors(tmp_path):
    genesis = _genesis()
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS)
    bad = genesis
    import dataclasses
    bad = dataclasses.replace(
        bad, version=jnp.asarray([0, 1, 0], bad.version.dtype))
    with pytest.raises(ValueError, match="disagree"):
        store.checkpoint(bad)


# --------------------------------------------------------------------------- #
# shared chunk store: cross-shard dedup + owner-side sweep
# --------------------------------------------------------------------------- #


def test_shared_chunkstore_dedups_and_retain_keeps_live_chunks(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(7, 40, 10)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=8, chunk_size=256)
    ref = genesis
    for b in batches:
        store.append(b)
        ref = shard_wal.bulk_apply_sharded(ref, b, NS)
        store.checkpoint(ref)
    # all shards share one physical chunk dir
    assert (tmp_path / "chunks").is_dir()
    assert not (tmp_path / "shard_0000" / "chunks").exists()

    stats = store.retain(1)
    assert stats["snapshots_dropped"] > 0
    # post-sweep, every shard still restores and the merge still verifies
    state, h = store.restore_at(store.t)
    assert h == hashing.hash_pytree(ref)
    # merged records outside the window were pruned with the snapshots
    oldest = min(s.snapshots()[0] for s in store.shards)
    assert all(t >= oldest for t in store.merged_records())


def test_reopen_validates_shard_count(tmp_path):
    genesis = _genesis()
    shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS)
    with pytest.raises(ValueError, match="shards"):
        shard_wal.ShardedDurableStore(tmp_path, n_shards=NS + 1)


# --------------------------------------------------------------------------- #
# sharded exact search: layout-invariant retrieval
# --------------------------------------------------------------------------- #


def test_exact_search_sharded_matches_single_kernel():
    """The merged sharded state and a single kernel holding the same live
    (id → vector) content return bit-identical retrieval sets: scores and
    (score, id) tie-breaks are slot-layout-invariant."""
    rng = np.random.default_rng(1)
    n = 24
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int64)
    log = commands.insert_batch(ids, vecs)

    sharded = shard_wal.bulk_apply_sharded(_genesis(), log, NS)
    flat = machine.bulk_apply(init_state(64, D), log)

    q = boundary.admit_query(rng.normal(size=(5, D)).astype(np.float32))
    ids_s, s_s = shard_wal.exact_search_sharded(sharded, NS, q, 6)
    ids_f, s_f = search.exact_search(flat, q, 6)
    assert (np.asarray(ids_s) == np.asarray(ids_f)).all()
    assert (np.asarray(s_s) == np.asarray(s_f)).all()


# --------------------------------------------------------------------------- #
# mesh-free sharded HNSW + engine-facing facts + sharded rollback
# --------------------------------------------------------------------------- #


def test_hnsw_search_sharded_exhaustive_beams_match_exact():
    """In the beam-exhaustive regime (ef >= per-shard live count) the
    per-shard HNSW fan-out must reproduce the exact sharded answer — and
    hence the flat single-kernel answer — bit for bit."""
    rng = np.random.default_rng(3)
    n = 30
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    sharded = shard_wal.bulk_apply_sharded(_genesis(), log, NS)
    flat = machine.bulk_apply(init_state(64, D), log)

    q = boundary.admit_query(rng.normal(size=(5, D)).astype(np.float32))
    ids_h, d_h = shard_wal.hnsw_search_sharded(sharded, NS, q, 6, ef=64)
    ids_e, s_e = shard_wal.exact_search_sharded(sharded, NS, q, 6)
    ids_f, s_f = search.exact_search(flat, q, 6)
    assert (np.asarray(ids_h) == np.asarray(ids_e)).all()
    assert (np.asarray(d_h) == np.asarray(s_e)).all()
    assert (np.asarray(ids_h) == np.asarray(ids_f)).all()
    assert (np.asarray(d_h) == np.asarray(s_f)).all()
    # the planner-facing fan-out wrapper takes the same route
    plan = query.plan_query(int(np.asarray(sharded.count).sum()), 6, 64,
                            route="hnsw")
    ids_p, d_p = query.sharded_host_query(sharded, NS, q, 6, plan)
    assert (np.asarray(ids_p) == np.asarray(ids_h)).all()
    assert (np.asarray(d_p) == np.asarray(d_h)).all()


def test_live_count_and_shard_live_counts_facts():
    rng = np.random.default_rng(4)
    n = 20
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    for i in (0, 5):
        log = log.concat(commands.delete_cmd(i, D))
    sharded = shard_wal.bulk_apply_sharded(_genesis(), log, NS)
    flat = machine.bulk_apply(init_state(64, D), log)
    assert shard_wal.live_count(flat) == shard_wal.live_count(sharded) == 18
    per = distributed.shard_live_counts(sharded, NS)
    assert per.sum() == 18
    assert (per == np.asarray(sharded.count)).all()


def test_sharded_rollback_to_drops_history_and_merged_records(tmp_path):
    genesis = _genesis()
    batches, _ = _batches(15, 30, 10)
    store = shard_wal.ShardedDurableStore(tmp_path, genesis, n_shards=NS,
                                          segment_records=256)
    ref = genesis
    cursors, refs = [], {}
    for b in batches:
        t = store.append(b)
        ref = shard_wal.bulk_apply_sharded(ref, b, NS)
        store.checkpoint(ref)
        cursors.append(t)
        refs[t] = ref
    t_mid = cursors[0]
    store.rollback_to(t_mid)
    assert store.t == t_mid
    assert len(set(store.shard_ts())) == 1, "rollback must keep lockstep"
    assert all(t <= t_mid for t in store.merged_records())
    _, h = store.restore_at(t_mid)
    assert h == hashing.hash_pytree(refs[t_mid])
    # the store keeps accepting appends at the rolled-back cursor
    t2 = store.append(batches[1])
    ref2 = shard_wal.bulk_apply_sharded(refs[t_mid], batches[1], NS)
    _, h2 = store.restore_at(t2)
    assert h2 == hashing.hash_pytree(ref2)
    with pytest.raises(ValueError, match="ahead"):
        store.rollback_to(store.t + 100)


def test_pre_routed_submit_path_is_bit_identical(tmp_path):
    """The serve engine's route-once path (submit(routed=) →
    append_many_routed) must write byte-identical per-shard WALs to the
    route-inside-the-store path — routing exactly once is an optimization,
    never a semantic."""
    genesis = _genesis()
    batches, _ = _batches(16, 40, 8)
    a = shard_wal.ShardedDurableStore(tmp_path / "a", genesis, n_shards=NS,
                                      segment_records=256)
    for b in batches:
        a.append(b)
    b_store = shard_wal.ShardedDurableStore(tmp_path / "b", genesis,
                                            n_shards=NS, segment_records=256)
    gw = wal.GroupCommitWriter(
        b_store, wal.GroupCommitPolicy(max_batch=1 << 20, max_delay_s=3600))
    predicted = [gw.submit(b, routed=distributed.route_commands(b, NS))
                 for b in batches]
    assert gw.flush() == a.t == predicted[-1]
    for s in range(NS):
        segs_a = sorted((tmp_path / "a" / f"shard_{s:04d}" / "wal").glob("*.wal"))
        segs_b = sorted((tmp_path / "b" / f"shard_{s:04d}" / "wal").glob("*.wal"))
        assert len(segs_a) == len(segs_b)
        for pa, pb in zip(segs_a, segs_b):
            assert pa.read_bytes() == pb.read_bytes()
    with pytest.raises(ValueError, match="shares"):
        b_store.append_many_routed(
            [distributed.route_commands(batches[0], NS + 1)])


# --------------------------------------------------------------------------- #
# device-side routed apply + sharded re-link (DESIGN.md §11)
# --------------------------------------------------------------------------- #


def test_device_apply_matches_host_apply_bit_for_bit():
    """``apply_routed_device`` (one vmapped device scan, no per-shard host
    loop) must land on exactly the state the host ``bulk_apply`` driver
    lands on, on randomized six-opcode logs at every shard count — the
    knob is a driver choice, never a semantic one."""
    for seed in range(3):
        log = _random_log(seed + 77, 40, id_space=ID_SPACE_SMALL)
        for ns in (1, 2, 4):
            genesis = distributed.init_sharded_host(ns, 16, D)
            routed = distributed.route_commands(log, ns)
            host = shard_wal.bulk_apply_sharded(genesis, log, ns,
                                                routed=routed, device=False)
            dev = shard_wal.apply_routed_device(genesis, routed, ns)
            assert hashing.hash_pytree(host) == hashing.hash_pytree(dev), \
                (seed, ns)


def test_device_apply_auto_threshold():
    """``device=None`` auto-routes by share length: at or under
    ``_DEVICE_APPLY_MAX`` both drivers are interchangeable (and must be
    bit-identical); either way the result matches the explicit drivers."""
    log = _random_log(5, 24, id_space=ID_SPACE_SMALL)
    genesis = _genesis()
    routed = distributed.route_commands(log, NS)
    assert int(routed.opcode.shape[1]) <= shard_wal._DEVICE_APPLY_MAX
    auto = shard_wal.bulk_apply_sharded(genesis, log, NS, routed=routed)
    dev = shard_wal.bulk_apply_sharded(genesis, log, NS, routed=routed,
                                       device=True)
    host = shard_wal.bulk_apply_sharded(genesis, log, NS, routed=routed,
                                        device=False)
    assert (hashing.hash_pytree(auto) == hashing.hash_pytree(dev)
            == hashing.hash_pytree(host))


def test_shard_stack_unstack_roundtrip():
    log = _random_log(9, 30, id_space=ID_SPACE_SMALL)
    state = shard_wal.bulk_apply_sharded(_genesis(), log, NS)
    back = shard_wal.shard_unstack(shard_wal.shard_stack(state, NS), NS)
    assert hashing.hash_pytree(back) == hashing.hash_pytree(state)
    # each stacked lane IS the shard slice
    stacked = shard_wal.shard_stack(state, NS)
    for s in range(NS):
        lane = jax.tree.map(lambda a, s=s: a[s], stacked)
        sl = distributed.shard_slice(state, s, NS)
        for la, lb in zip(jax.tree_util.tree_leaves(lane),
                          jax.tree_util.tree_leaves(sl)):
            assert (np.asarray(la) == np.asarray(lb)).all()


def test_relink_sharded_matches_per_slice_contract():
    """``relink_sharded`` == slice-by-slice ``hnsw.relink`` == slice-by-
    slice ``hnsw.fresh_build`` (the bit-exact re-link contract applied per
    shard), with the merged arena untouched."""
    from repro.core import hnsw
    log = _random_log(21, 48, id_space=ID_SPACE_SMALL)
    state = shard_wal.bulk_apply_sharded(_genesis(), log, NS)
    relinked = shard_wal.relink_sharded(state, NS)
    assert hashing.content_hash(relinked) == hashing.content_hash(state)
    for s in range(NS):
        sl = distributed.shard_slice(state, s, NS)
        got = distributed.shard_slice(relinked, s, NS)
        assert (hashing.hash_pytree(got)
                == hashing.hash_pytree(hnsw.relink(sl))
                == hashing.hash_pytree(hnsw.fresh_build(sl))), s
