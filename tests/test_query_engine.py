"""Batched deterministic query engine (DESIGN.md §4).

The read-path equivalence contract: every batched / planned / sharded search
is bit-identical — ids, wide scores, tie order — to the per-query reference
loop over ``hnsw.hnsw_search`` / ``search.exact_search``. Randomized logs
(inserts, deletes, duplicate vectors, non-contiguous ids) drive the checks;
``merge_topk``'s algebraic properties get a property test via ``_pbt``.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.core import boundary, commands, hnsw, machine, query, search
from repro.core.state import init_state

D = 20
INF = int(search.INF)


def _random_state(seed: int, n: int = 120, capacity: int = 192,
                  n_delete: int = 10, n_dup: int = 0):
    """Replay a randomized log: shuffled non-contiguous ids, optional runs of
    duplicate vectors, a sprinkle of deletes."""
    rng = np.random.default_rng(seed)
    raw = rng.normal(size=(n, D)).astype(np.float32)
    if n_dup:
        raw[n // 3:n // 3 + n_dup] = raw[n // 3]
    vecs = boundary.normalize_embedding(raw)
    ids = rng.permutation(n).astype(np.int64) * 7 + 3
    log = commands.insert_batch(jnp.asarray(ids), vecs)
    for i in rng.choice(n, size=n_delete, replace=False):
        log = log.concat(commands.delete_cmd(int(ids[i]), D))
    return machine.replay(init_state(capacity, D), log), vecs


def _queries(seed: int, b: int = 8):
    rng = np.random.default_rng(seed)
    return boundary.admit_query(rng.normal(size=(b, D)).astype(np.float32))


# --------------------------------------------------------------------------- #
# tentpole: batched == per-query reference loop
# --------------------------------------------------------------------------- #


def test_batched_hnsw_equals_per_query_loop():
    for seed, k, ef in ((0, 5, 32), (1, 10, 64), (2, 3, 16)):
        state, _ = _random_state(seed, n_dup=4 if seed == 1 else 0)
        q = _queries(100 + seed)
        bi, bd, bs = query.batched_hnsw_search(state, q, k, ef=ef)
        for b in range(q.shape[0]):
            ri, rd, rs = hnsw.hnsw_search(state, q[b], k, ef=ef)
            assert (np.asarray(bi[b]) == np.asarray(ri)).all(), (seed, b)
            assert (np.asarray(bd[b]) == np.asarray(rd)).all(), (seed, b)
            assert (np.asarray(bs[b]) == np.asarray(rs)).all(), (seed, b)


def test_executed_plan_equals_reference_loop():
    """Whatever route the planner picks, the batched answer equals running
    that route's single-query reference one row at a time."""
    state, _ = _random_state(3)
    q = _queries(103)
    live = int(state.count)
    for plan in (
        query.plan_query(live, 5, 32),                     # → exact (small)
        query.plan_query(live, 5, 32, route="hnsw"),       # forced hnsw
        query.plan_query(live, 5, 32, use_kernel=True),    # exact via Pallas
    ):
        ids, scores = query.execute_plan(state, q, 5, plan)
        for b in range(q.shape[0]):
            if plan.route == query.ROUTE_EXACT:
                ri, rs = search.exact_search(state, q[b][None], 5)
                ri, rs = ri[0], rs[0]
            else:
                ri, rs, _ = hnsw.hnsw_search(state, q[b], 5, ef=plan.ef)
            assert (np.asarray(ids[b]) == np.asarray(ri)).all(), plan
            assert (np.asarray(scores[b]) == np.asarray(rs)).all(), plan


def test_planner_rules_are_static_and_deterministic():
    p = query.plan_query(100, 5, 32)
    assert p.route == query.ROUTE_EXACT and "live" in p.reason
    assert query.plan_query(100, 5, 32) == p  # pure data, replayable
    # k > ef can never come out of an ef-beam
    assert query.plan_query(50_000, 128, 64).route == query.ROUTE_EXACT
    # beam covers the whole corpus → scan
    assert query.plan_query(2_000, 5, 4_096).route == query.ROUTE_EXACT
    # big corpus, sane beam → graph
    assert query.plan_query(50_000, 10, 64).route == query.ROUTE_HNSW
    # operator override wins over every rule
    assert query.plan_query(10, 5, 32, route="hnsw").route == query.ROUTE_HNSW
    try:
        query.plan_query(10, 5, 32, route="scan")
        assert False, "unknown route must raise"
    except ValueError:
        pass
    # forcing hnsw with k > ef must raise, not hand back [B, ef] arrays
    try:
        query.plan_query(10, 48, 32, route="hnsw")
        assert False, "forced hnsw with k > ef must raise"
    except ValueError:
        pass


# --------------------------------------------------------------------------- #
# satellite: merge_topk algebra (property test via _pbt)
# --------------------------------------------------------------------------- #


def _random_topk_list(rng, m: int, k: int):
    """A sorted top-k-style list [k]: real (score, id) pairs up-front, then
    (INF, -1) padding; occasional tombstone score collisions."""
    n_real = int(rng.integers(0, k + 1))
    scores = np.sort(rng.integers(0, 2**40, size=n_real)).astype(np.int64)
    ids = rng.choice(m, size=n_real, replace=False).astype(np.int64)
    # sort the block the way a real top-k emits it: (score, id)
    order = np.lexsort((ids, scores))
    s = np.full(k, INF, np.int64)
    i = np.full(k, -1, np.int64)
    s[:n_real], i[:n_real] = scores[order], ids[order]
    return jnp.asarray(s), jnp.asarray(i)


def _eq(a, b):
    return all((np.asarray(x) == np.asarray(y)).all() for x, y in zip(a, b))


@given(st.integers(0, 2**31 - 1), st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_merge_topk_is_associative_commutative_perm_invariant(seed, k):
    rng = np.random.default_rng(seed)
    a_s, a_i = _random_topk_list(rng, 10_000, k)
    b_s, b_i = _random_topk_list(rng, 10_000, k)
    c_s, c_i = _random_topk_list(rng, 10_000, k)

    ab = search.merge_topk(a_s, a_i, b_s, b_i, k)
    ba = search.merge_topk(b_s, b_i, a_s, a_i, k)
    assert _eq(ab, ba), "commutativity"

    ab_c = search.merge_topk(*ab, c_s, c_i, k)
    bc = search.merge_topk(b_s, b_i, c_s, c_i, k)
    a_bc = search.merge_topk(a_s, a_i, *bc, k)
    assert _eq(ab_c, a_bc), "associativity"

    # permutation invariance: shuffle the pooled candidates, merge again
    pool_s = jnp.concatenate([a_s, b_s])
    pool_i = jnp.concatenate([a_i, b_i])
    perm = rng.permutation(2 * k)
    pm = search.merge_candidates(pool_s[perm], pool_i[perm], k)
    assert _eq((pm[0], pm[1]), ab), "permutation invariance"


def test_merge_topk_tombstones_never_beat_real_results():
    k = 4
    real_s = jnp.asarray([7, 9, INF, INF], jnp.int64)
    real_i = jnp.asarray([42, 3, -1, -1], jnp.int64)
    pad_s = jnp.full((k,), INF, jnp.int64)
    pad_i = jnp.full((k,), -1, jnp.int64)
    s, i = search.merge_topk(pad_s, pad_i, real_s, real_i, k)
    assert np.asarray(i).tolist() == [42, 3, -1, -1]
    assert np.asarray(s).tolist() == [7, 9, INF, INF]


# --------------------------------------------------------------------------- #
# satellite: duplicate vectors tie-break identically on every path
# --------------------------------------------------------------------------- #


def test_duplicate_vectors_tie_break_by_id_on_all_paths():
    n, n_dup, k = 48, 6, 8
    rng = np.random.default_rng(11)
    raw = rng.normal(size=(n, D)).astype(np.float32)
    raw[16:16 + n_dup] = raw[16]            # duplicates under ids 16..24
    vecs = boundary.normalize_embedding(raw)
    ids = jnp.arange(n, dtype=jnp.int64)    # insert order == id order
    state = machine.replay(init_state(96, D), commands.insert_batch(ids, vecs))

    q = vecs[16][None]                      # the duplicated vector itself
    e_ids, e_s = search.exact_search(state, q, k)
    # the k nearest are the duplicates at distance 0, in ascending id order
    assert np.asarray(e_ids)[0, :n_dup].tolist() == list(range(16, 16 + n_dup))
    assert (np.asarray(e_s)[0, :n_dup] == 0).all()

    ke_ids, ke_s = search.exact_search(state, q, k, use_kernel=True)
    assert (np.asarray(ke_ids) == np.asarray(e_ids)).all()
    assert (np.asarray(ke_s) == np.asarray(e_s)).all()

    h_ids, h_d, _ = hnsw.hnsw_search(state, q[0], k, ef=64)
    assert (np.asarray(h_ids) == np.asarray(e_ids)[0]).all()
    assert (np.asarray(h_d) == np.asarray(e_s)[0]).all()

    b_ids, b_d, _ = query.batched_hnsw_search(state, q, k, ef=64)
    assert (np.asarray(b_ids) == np.asarray(e_ids)).all()
    assert (np.asarray(b_d) == np.asarray(e_s)).all()


# --------------------------------------------------------------------------- #
# satellite: exact_search kernel parity (Pallas interpret mode on CPU)
# --------------------------------------------------------------------------- #


def test_kernel_parity_l2_and_dot_odd_shapes():
    for seed, nq, n, dim, k, n_del in (
        (0, 1, 7, 5, 3, 0), (1, 3, 37, 19, 7, 5),
        (2, 5, 130, 33, 11, 17), (3, 2, 200, 24, 200, 40),
    ):
        rng = np.random.default_rng(seed)
        vecs = boundary.normalize_embedding(
            rng.normal(size=(n, dim)).astype(np.float32))
        ids = rng.permutation(n).astype(np.int64) * 11 + 2  # rank ≠ slot order
        log = commands.insert_batch(jnp.asarray(ids), vecs)
        for i in rng.choice(n, size=n_del, replace=False):
            log = log.concat(commands.delete_cmd(int(ids[i]), dim))
        state = machine.replay(init_state(n, dim), log)
        q = boundary.admit_query(rng.normal(size=(nq, dim)).astype(np.float32))
        for metric in (search.METRIC_L2, search.METRIC_DOT):
            ref = search.exact_search(state, q, k, metric=metric)
            got = search.exact_search(state, q, k, metric=metric,
                                      use_kernel=True)
            assert (np.asarray(got[0]) == np.asarray(ref[0])).all(), \
                (seed, metric)
            assert (np.asarray(got[1]) == np.asarray(ref[1])).all(), \
                (seed, metric)


# --------------------------------------------------------------------------- #
# shard fan-out: planner-driven sharded query == single kernel, bitwise
# (multi-device → subprocess, per the dry-run isolation rule)
# --------------------------------------------------------------------------- #

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    import repro
    from repro.core import (boundary, commands, compat, distributed, hnsw,
                            machine, query, search)
    from repro.core.state import init_state

    mesh = compat.make_mesh((4, 2), ("model", "data"))
    D, N, K = 16, 56, 8
    rng = np.random.default_rng(0)
    raw = rng.normal(size=(N, D)).astype(np.float32)
    raw[20:26] = raw[20]                       # duplicates under distinct ids
    vecs = boundary.normalize_embedding(raw)
    ids = jnp.arange(N, dtype=jnp.int64)
    log = commands.insert_batch(ids, vecs)

    ref = machine.replay(init_state(128, D), log)
    q = jnp.concatenate([vecs[20][None],       # ties: id-ordered duplicates
        boundary.admit_query(rng.normal(size=(7, D)).astype(np.float32))])
    ref_ids, ref_scores = search.exact_search(ref, q, K)
    assert np.asarray(ref_ids)[0, :6].tolist() == list(range(20, 26))

    routed = distributed.route_commands(log, 4)
    st = distributed.init_sharded_state(mesh, "model", 32, D)
    st = distributed.distributed_replay(mesh, "model", st, routed)

    # exact route: bit-identical to the single kernel, duplicates included
    plan = query.plan_query(int(np.asarray(st.count).sum()), K, 64)
    assert plan.route == query.ROUTE_EXACT
    d_ids, d_scores = query.sharded_query(mesh, "model", st, q, K, plan,
                                          query_axis="data")
    assert (np.asarray(d_ids) == np.asarray(ref_ids)).all(), "ids diverged"
    assert (np.asarray(d_scores) == np.asarray(ref_scores)).all()

    # hnsw route: per-shard beams cover each tiny shard fully (ef >= n_local),
    # so the merge_topk fan-in must reproduce the exact answer — duplicates
    # tie-break by id across shard boundaries
    hplan = query.plan_query(N, K, 64, route="hnsw")
    h_ids, h_scores = query.sharded_query(mesh, "model", st, q, K, hplan,
                                          query_axis="data")
    assert (np.asarray(h_ids) == np.asarray(ref_ids)).all(), "hnsw ids"
    assert (np.asarray(h_scores) == np.asarray(ref_scores)).all()
    print("SHARDED_QUERY_OK")
""")


def test_sharded_query_equals_single_kernel():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_QUERY_OK" in proc.stdout
