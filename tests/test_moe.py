"""MoE layer: determinism, capacity semantics, shard_map == dense equality.

Repro note (jax 0.8.2 / XLA CPU): grad(scan(shard_map)) with fully-manual dp
specs needs explicit jit out_shardings (KeyError in parse_flatten_op_sharding
otherwise), and bf16 psum inside partial-manual shard_map aborts in XLA's
AllReducePromotion. Both worked around in moe.py / train/step.py; the
subprocess test below covers the working configuration end to end.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.models.layers import moe as moe_lib


def _cfg():
    return get_reduced_config("phi3_5_moe_42b_a6_6b")


def test_dense_moe_deterministic():
    cfg = _cfg()
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y1, a1 = moe_lib._moe_dense(params, x, cfg)
    y2, a2 = moe_lib._moe_dense(params, x, cfg)
    assert (np.asarray(y1) == np.asarray(y2)).all()
    assert float(a1) == float(a2)


def test_expert_padding_never_routed():
    import dataclasses
    cfg = dataclasses.replace(_cfg(), num_experts=40, expert_d_ff=16,
                              num_experts_per_tok=4)
    assert cfg.padded_experts == 48
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    assert params["w_gate"].shape[0] == 48
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    xt = x.reshape(-1, cfg.d_model)
    probs, top_p, top_e = moe_lib._route(params, xt, cfg)
    assert int(jnp.max(top_e)) < 40  # padded experts unreachable


def test_capacity_drops_overflow_deterministically():
    import dataclasses
    cfg = dataclasses.replace(_cfg(), moe_capacity_factor=0.25)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y1, _ = moe_lib._moe_dense(params, x, cfg)
    y2, _ = moe_lib._moe_dense(params, x, cfg)
    assert (np.asarray(y1) == np.asarray(y2)).all()
    # some tokens dropped → some rows equal zero contribution is fine; just
    # require finiteness and shape
    assert np.isfinite(np.asarray(y1, np.float32)).all()


_SMAP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro
    from repro.configs import get_reduced_config
    from repro.models import sharding as shd, transformer as tf
    from repro.models.layers import moe as moe_lib
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import make_train_step

    from repro.core import compat
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    cfg = get_reduced_config('phi3_5_moe_42b_a6_6b')
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    y_dense, _ = moe_lib._moe_dense(params, x, cfg)
    with compat.use_mesh(mesh):
        y_smap, _ = jax.jit(lambda p, x: moe_lib.moe_ffn(p, x, cfg))(params, x)
    err = float(jnp.max(jnp.abs(y_dense - y_smap)))
    assert err == 0.0, f"shard_map EP diverged from dense: {err}"

    # full train step with explicit out_shardings
    full = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(full)
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab_size),
             'labels': jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, cfg.vocab_size)}
    step = make_train_step(cfg, AdamWConfig())
    with compat.use_mesh(mesh):
        p_sh = shd.param_shardings(jax.eval_shape(lambda: full), cfg, mesh)
        rep = NamedSharding(mesh, P())
        o_sh = {"m": p_sh, "v": p_sh, "step": rep}
        m_sh = {k: rep for k in ("loss", "ce", "aux", "grad_norm", "lr")}
        jitted = jax.jit(step, out_shardings=(p_sh, o_sh, m_sh))
        p2, o2, m = jitted(full, opt, batch)
        assert np.isfinite(float(m["loss"]))
    print("MOE_SMAP_OK")
""")


def test_shardmap_moe_equals_dense_and_trains():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _SMAP], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_SMAP_OK" in proc.stdout
