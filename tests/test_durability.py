"""Durability layer: segmented WAL, chunked v2 snapshots, time travel
(DESIGN.md §5).

The acceptance contract: ``restore_at(store, t)`` is hash-identical to
``replay(genesis, log[:t])`` for randomized logs over all six opcodes at
every snapshot boundary AND at every offset between them; incremental-chain
restores are bit-identical to full restores; compacted-log replay equals
raw-log replay; a torn WAL tail recovers to the longest valid record
prefix.
"""
import dataclasses
import json
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.checkpoint.manager import (CheckpointManager,
                                      DurableCheckpointManager)
from repro.core import (boundary, commands, distributed, durability, hashing,
                        machine, snapshot, wal)
from repro.core.state import init_state
from test_bulk_apply import _random_log

D = 8
FIXTURES = pathlib.Path(__file__).parent / "fixtures"


def _hash_trace(genesis, log):
    """hashes[t] == hash of replay(genesis, log[:t]) — the sequential
    reference the whole durability layer must agree with."""
    step = jax.jit(machine.apply_command)
    hashes = [hashing.hash_pytree(genesis)]
    s = genesis
    for i in range(len(log)):
        s = step(s, log.record(i))
        hashes.append(hashing.hash_pytree(s))
    return hashes


def _store_with_history(tmp_path, log, *, capacity=32, every=9,
                        segment_records=5):
    genesis = init_state(capacity, D)
    store = durability.DurableStore(tmp_path / "store", genesis,
                                    segment_records=segment_records,
                                    chunk_size=256)
    store.append(log)
    step = jax.jit(machine.apply_command)
    s = genesis
    for t in range(1, len(log) + 1):
        s = step(s, log.record(t - 1))
        if t % every == 0:
            store.checkpoint(s)
    return store, genesis


# --------------------------------------------------------------------------- #
# WAL: round trip, segmentation, reopen
# --------------------------------------------------------------------------- #


def test_wal_roundtrip_replay_identical(tmp_path):
    log = _random_log(7, 40, id_space=12)
    w = wal.WriteAheadLog(tmp_path, D, segment_records=6)
    w.append(log.slice(0, 13))
    w.append(log.slice(13, 40))
    assert w.t == 40
    back = w.read_range(0, 40)
    genesis = init_state(32, D)
    assert (hashing.hash_pytree(machine.replay(genesis, back))
            == hashing.hash_pytree(machine.replay(genesis, log)))


def test_wal_reopen_continues_chain(tmp_path):
    log = _random_log(3, 30, id_space=10)
    w = wal.WriteAheadLog(tmp_path, D, segment_records=4)
    w.append(log.slice(0, 11))
    w2 = wal.WriteAheadLog(tmp_path, segment_records=4)  # dim from header
    assert w2.t == 11 and w2.dim == D
    w2.append(log.slice(11, 30))
    w3 = wal.WriteAheadLog(tmp_path)
    assert w3.t == 30
    genesis = init_state(32, D)
    assert (hashing.hash_pytree(machine.replay(genesis, w3.read_range(0, 30)))
            == hashing.hash_pytree(machine.replay(genesis, log)))


def test_wal_nop_runs_are_rle(tmp_path):
    pad = commands.empty_log(D)
    nops = machine._pad_log(pad, 64)  # 64 zero-arg NOPs
    w = wal.WriteAheadLog(tmp_path, D, segment_records=1024)
    w.append(nops)
    assert w.t == 64
    seg = next(tmp_path.glob("seg_*.wal"))
    # one run record, not 64: header + single 36-byte record
    assert seg.stat().st_size < 200
    back = w.read_range(0, 64)
    assert (np.asarray(back.opcode) == commands.NOP).all() and len(back) == 64


# --------------------------------------------------------------------------- #
# time travel: restore_at ≡ replay prefix at EVERY offset
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", [0, 1])
def test_restore_at_every_offset(tmp_path, seed):
    n = 36
    log = _random_log(seed, n, id_space=10)
    store, genesis = _store_with_history(tmp_path, log, every=9)
    ref = _hash_trace(genesis, log)
    assert store.snapshots() == [0, 9, 18, 27, 36]
    for t in range(n + 1):  # every boundary and every offset between
        state, h = durability.restore_at(store, t)
        assert h == ref[t], f"restore_at({t}) diverged from replay prefix"
        assert int(state.version) == t


def test_restore_at_respects_all_opcodes(tmp_path):
    # deliberate hard cases: upsert, delete+reinsert slot reuse, full arena
    log = _random_log(11, 48, id_space=5)  # heavy id collisions
    store, genesis = _store_with_history(tmp_path, log, capacity=6, every=7)
    ref = _hash_trace(genesis, log)
    for t in list(range(0, 49, 5)) + [7, 14, 48]:
        _, h = store.restore_at(t)
        assert h == ref[t]


def test_recover_after_clean_shutdown(tmp_path):
    log = _random_log(2, 25, id_space=8)
    store, genesis = _store_with_history(tmp_path, log, every=10)
    reopened = durability.DurableStore(tmp_path / "store")
    state, h, t = reopened.recover()
    assert t == 25
    assert h == _hash_trace(genesis, log)[25]


# --------------------------------------------------------------------------- #
# incremental chunked snapshots
# --------------------------------------------------------------------------- #


def test_incremental_snapshot_writes_only_dirty_chunks(tmp_path):
    genesis = init_state(256, D)
    vecs = boundary.normalize_embedding(
        np.random.default_rng(0).normal(size=(64, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(64, dtype=jnp.int64), vecs)
    s1 = machine.bulk_apply(genesis, log.slice(0, 60))
    s2 = machine.bulk_apply(s1, log.slice(60, 64))

    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    _, full1 = snapshot.snapshot_v2(s1, chunks, chunk_size=256)
    m2, inc = snapshot.snapshot_v2(s2, chunks, chunk_size=256)
    assert full1["bytes_written"] > 0
    # 4 inserts dirty their arena rows plus scattered HNSW back-edge chunks —
    # still far below rewriting the full serialization (what v1 would cost)
    assert 0 < inc["bytes_written"] < inc["bytes_total"] / 4
    assert inc["bytes_written"] < full1["bytes_written"]

    # incremental-chain restore is bit-identical to a fresh full snapshot
    fresh = snapshot.ChunkStore(tmp_path / "fresh")
    m_full, _ = snapshot.snapshot_v2(s2, fresh, chunk_size=256)
    a, ha = snapshot.restore_v2(m2, chunks)
    b, hb = snapshot.restore_v2(m_full, fresh)
    assert ha == hb == hashing.hash_pytree(s2)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert (np.asarray(la) == np.asarray(lb)).all()


def test_v2_detects_chunk_corruption(tmp_path):
    genesis = init_state(16, D)
    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    manifest, _ = snapshot.snapshot_v2(genesis, chunks, chunk_size=64)
    victim = sorted((tmp_path / "chunks").glob("*.chk"))[0]
    raw = bytearray(victim.read_bytes())
    raw[0] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="corrupt"):
        snapshot.restore_v2(manifest, chunks)


def test_restore_any_dispatches_both_formats(tmp_path):
    state = machine.replay(init_state(16, D), _random_log(5, 10, id_space=4))
    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    v1 = snapshot.snapshot_bytes(state)
    v2, _ = snapshot.snapshot_v2(state, chunks)
    (_, h1), (_, h2) = snapshot.restore_any(v1), snapshot.restore_any(v2, chunks)
    assert h1 == h2 == hashing.hash_pytree(state)


# --------------------------------------------------------------------------- #
# compaction: bit-exact replay equivalence
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("seed", range(6))
def test_compaction_replay_equivalent(seed):
    # small id space + small arena: upserts, dead deletes, rejections galore
    log = _random_log(seed, 60, id_space=6,
                      opcode_weights=(1, 4, 2, 2, 2, 3))
    genesis = init_state(5, D)
    compacted, stats = wal.compact_log(genesis, log)
    assert len(compacted) == len(log)  # logical time is preserved
    h_raw = hashing.hash_pytree(machine.replay(genesis, log))
    h_cmp = hashing.hash_pytree(machine.bulk_apply(genesis, compacted))
    assert h_cmp == h_raw, f"compaction diverged (folded={stats['folded']})"


def test_compaction_folds_known_dead_commands():
    vecs = boundary.normalize_embedding(
        np.random.default_rng(0).normal(size=(4, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(2, dtype=jnp.int64), vecs[:2])
    log = log.concat(commands.set_meta_cmd(0, 0, 1, D))   # superseded ↓
    log = log.concat(commands.set_meta_cmd(0, 0, 2, D))
    log = log.concat(commands.delete_cmd(99, D))          # absent id
    log = log.concat(commands.link_cmd(0, 1, D))          # cancelled pair ↓
    log = log.concat(commands.unlink_cmd(0, 1, D))
    log = log.concat(commands.insert_cmd(0, np.asarray(vecs[2])))  # upsert ↓
    log = log.concat(commands.insert_cmd(0, np.asarray(vecs[3])))  # wins
    genesis = init_state(8, D)
    compacted, stats = wal.compact_log(genesis, log)
    assert stats["folded"] >= 5
    assert (hashing.hash_pytree(machine.bulk_apply(genesis, compacted))
            == hashing.hash_pytree(machine.replay(genesis, log)))


def test_wal_compact_on_disk(tmp_path):
    log = _random_log(9, 50, id_space=5, opcode_weights=(1, 4, 2, 1, 1, 4))
    genesis = init_state(6, D)
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)
    w.append(log)
    h_raw = hashing.hash_pytree(machine.replay(genesis, log))
    stats = w.compact(genesis)
    assert w.t == 50
    assert stats["bytes_after"] <= stats["bytes_before"]
    h_cmp = hashing.hash_pytree(
        machine.bulk_apply(genesis, w.read_range(0, 50)))
    assert h_cmp == h_raw


# --------------------------------------------------------------------------- #
# crash recovery: torn WAL tail → longest valid record prefix
# --------------------------------------------------------------------------- #


def _record_boundaries(seg_path):
    """(header_size, [(byte offset after record, cumulative commands)]) of a
    clean segment — independent re-derivation of the framing, so the test
    does not trust the implementation's own offsets."""
    data = seg_path.read_bytes()
    (n,) = struct.unpack_from("<I", data, 24)
    header = 24 + 4 + n + 8  # fixed header + contract str + header chain
    _, dim, itemsize = struct.unpack_from("<III", data, 4)
    off = header
    out = []
    total = 0
    while off < len(data):
        op, a0 = struct.unpack_from("<Iq", data, off)
        off += 28 + (dim * itemsize if op == commands.INSERT else 0) + 8
        total += a0 if op == wal.NOP_RUN else 1
        out.append((off, total))
    return header, out


@pytest.mark.parametrize("seed", range(8))
def test_torn_tail_recovers_longest_valid_prefix(tmp_path, seed):
    """Truncate the segment at a random byte; recovery must yield exactly
    the longest valid record prefix, and the recovered state must equal
    replay of that prefix (the hash chain detects the torn tail)."""
    rng = np.random.default_rng(seed)
    log = _random_log(seed, 24, id_space=8)
    genesis = init_state(32, D)
    ref = _hash_trace(genesis, log)

    w = wal.WriteAheadLog(tmp_path / "wal", D, segment_records=1024)
    w.append(log)
    seg = next((tmp_path / "wal").glob("seg_*.wal"))
    header, bounds = _record_boundaries(seg)
    cut = int(rng.integers(header, seg.stat().st_size))
    with open(seg, "r+b") as f:
        f.truncate(cut)

    expect_t = max([c for o, c in bounds if o <= cut], default=0)
    valid_end = max([o for o, c in bounds if o <= cut], default=header)
    recovered = wal.WriteAheadLog(tmp_path / "wal")
    assert recovered.t == expect_t, "must recover the LONGEST valid prefix"
    assert seg.stat().st_size == valid_end, "torn bytes must be truncated"
    state = machine.replay(genesis, recovered.read_range(0, expect_t))
    assert hashing.hash_pytree(state) == ref[expect_t]
    # the truncated WAL is append-able again: extend and verify
    recovered.append(log.slice(expect_t, 24))
    state2 = machine.replay(genesis, recovered.read_range(0, 24))
    assert hashing.hash_pytree(state2) == ref[24]


def test_store_recovers_across_torn_tail_and_snapshot(tmp_path):
    """Snapshot newer than the durable WAL prefix (torn tail below it):
    recover() must come back at the snapshot, not the shorter prefix."""
    log = _random_log(4, 20, id_space=8)
    store, genesis = _store_with_history(tmp_path, log, every=10,
                                         segment_records=1024)
    ref = _hash_trace(genesis, log)
    seg = sorted((tmp_path / "store" / "wal").glob("seg_*.wal"))[-1]
    _, bounds = _record_boundaries(seg)
    cut = bounds[len(bounds) // 2][0] + 3  # torn mid-record
    with open(seg, "r+b") as f:
        f.truncate(cut)
    reopened = durability.DurableStore(tmp_path / "store")
    state, h, t = reopened.recover()
    assert t == 20  # snapshot at t=20 outlives the torn log
    assert h == ref[20]


# --------------------------------------------------------------------------- #
# retention over (snapshot, WAL-segment) pairs
# --------------------------------------------------------------------------- #


def test_retention_drops_pairs_and_sweeps_chunks(tmp_path):
    log = _random_log(6, 36, id_space=10)
    store, genesis = _store_with_history(tmp_path, log, every=9,
                                         segment_records=3)
    ref = _hash_trace(genesis, log)
    n_chunks_before = len(store.chunks.keys())
    stats = store.retain(2)
    assert store.snapshots() == [27, 36]
    assert stats["snapshots_dropped"] == 3
    assert stats["wal_segments_dropped"] > 0
    assert len(store.chunks.keys()) < n_chunks_before
    # inside the window: still bit-identical
    for t in (27, 30, 36):
        _, h = store.restore_at(t)
        assert h == ref[t]
    # outside the window: refused, not wrong
    with pytest.raises(ValueError):
        store.restore_at(9)


def test_retention_of_tail_segment_keeps_wal_appendable(tmp_path):
    """Retention that drops the active tail segment must reset the writer:
    the next append opens a fresh segment instead of crashing or writing
    into the unlinked file."""
    genesis = init_state(32, D)
    store = durability.DurableStore(tmp_path / "s", genesis,
                                    segment_records=1024)
    log = _random_log(12, 30, id_space=9)
    store.append(log.slice(0, 20))
    s = machine.bulk_apply(genesis, log.slice(0, 20))
    store.checkpoint(s)
    store.retain(1)  # drops genesis snapshot AND the whole [0, 20) segment
    assert store.snapshots() == [20]
    t = store.append(log.slice(20, 30))
    assert t == 30
    s2 = machine.bulk_apply(s, log.slice(20, 30))
    _, h = store.restore_at(30)
    assert h == hashing.hash_pytree(s2)


def test_recover_reconciles_wal_cursor_past_lost_region(tmp_path):
    """Snapshot ahead of a torn WAL: after recover(), new appends must land
    at offsets past the snapshot cursor (never colliding with the lost
    region), the gap must be refused, and checkpoints must work again."""
    log = _random_log(14, 20, id_space=8)
    store, genesis = _store_with_history(tmp_path, log, every=10,
                                         segment_records=1024)
    seg = sorted((tmp_path / "store" / "wal").glob("seg_*.wal"))[-1]
    _, bounds = _record_boundaries(seg)
    with open(seg, "r+b") as f:
        f.truncate(bounds[len(bounds) // 2][0] + 3)  # torn below t=20

    reopened = durability.DurableStore(tmp_path / "store")
    state, h, t = reopened.recover()
    assert t == 20
    extra = _random_log(15, 8, id_space=8)
    assert reopened.append(extra) == 28  # past the snapshot, no collision
    state2 = machine.bulk_apply(state, extra)
    reopened.checkpoint(state2)  # cursor consistency restored
    _, h2 = reopened.restore_at(28)
    assert h2 == hashing.hash_pytree(state2)
    with pytest.raises(ValueError, match="gap"):  # lost history is refused
        reopened.restore_at(15)


def test_restore_at_falls_back_over_broken_snapshot(tmp_path):
    """A torn newest snapshot must not lose history the WAL still covers:
    restore_at falls back to an older snapshot plus a longer tail."""
    log = _random_log(16, 20, id_space=8)
    store, genesis = _store_with_history(tmp_path, log, every=10)
    ref = _hash_trace(genesis, log)
    newest = sorted((tmp_path / "store" / "snapshots").glob("t_*.vsn2"))[-1]
    raw = bytearray(newest.read_bytes())
    raw[-1] ^= 0xFF  # break the manifest's tree-hash trailer
    newest.write_bytes(bytes(raw))
    _, h = store.restore_at(20)   # snapshot t=20 is broken → t=10 + tail
    assert h == ref[20]
    state, h2, t = store.recover()
    assert t == 20 and h2 == ref[20]


def test_restore_at_falls_back_over_truncated_manifest(tmp_path):
    """A manifest torn mid-structure fails in the struct layer, not just
    the hash check — the fallback must catch that too."""
    log = _random_log(17, 20, id_space=8)
    store, genesis = _store_with_history(tmp_path, log, every=10)
    ref = _hash_trace(genesis, log)
    newest = sorted((tmp_path / "store" / "snapshots").glob("t_*.vsn2"))[-1]
    newest.write_bytes(newest.read_bytes()[:37])  # torn mid-header
    _, h = store.restore_at(20)
    assert h == ref[20]
    _, h2, t = store.recover()
    assert t == 20 and h2 == ref[20]


def test_stillborn_tail_segment_dropped_on_open(tmp_path):
    """A segment whose header was torn by a crash holds zero durable
    records (headers are fsynced before any append); opening must drop it
    and keep the verified history, not fail."""
    log = _random_log(18, 12, id_space=6)
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)
    w.append(log)
    (tmp_path / f"seg_{w.t:020d}.wal").write_bytes(b"VWSG\x01\x00")  # torn
    reopened = wal.WriteAheadLog(tmp_path)
    assert reopened.t == 12 and reopened.torn_tail_dropped == 6
    genesis = init_state(16, D)
    assert (hashing.hash_pytree(machine.replay(genesis,
                                               reopened.read_range(0, 12)))
            == hashing.hash_pytree(machine.replay(genesis, log)))
    reopened.append(log.slice(0, 4))  # and the WAL is still appendable
    assert reopened.t == 16


def test_interrupted_compaction_swap_rolls_forward(tmp_path):
    """Crash mid-swap (commit marker written, some old segments already
    unlinked): reopening must finish the swap from the committed set, so
    the full history stays readable."""
    import shutil
    log = _random_log(19, 30, id_space=5, opcode_weights=(1, 4, 2, 1, 1, 4))
    genesis = init_state(6, D)
    h_raw = hashing.hash_pytree(machine.replay(genesis, log))
    w = wal.WriteAheadLog(tmp_path, D, segment_records=8)
    w.append(log)

    # simulate the state compact() reaches right after its commit point,
    # with the old-segment unlink pass half done
    compacted, _ = wal.compact_log(genesis, log)
    tmp = tmp_path / "compact.tmp"
    tmp.mkdir()
    new = wal.WriteAheadLog(tmp, D, segment_records=8)
    new.append(compacted)
    names = sorted(p.name for p in tmp.glob("seg_*.wal"))
    (tmp_path / "compact.commit").write_text("\n".join(names))
    old_segs = sorted(tmp_path.glob("seg_*.wal"))
    old_segs[0].unlink()
    shutil.copy(tmp / names[-1], tmp_path / names[-1])  # one move done too

    recovered = wal.WriteAheadLog(tmp_path)
    assert recovered.t == 30
    assert not (tmp_path / "compact.commit").exists()
    assert not tmp.exists()
    h_rec = hashing.hash_pytree(
        machine.bulk_apply(genesis, recovered.read_range(0, 30)))
    assert h_rec == h_raw


def test_wal_reopen_adopts_header_contract(tmp_path):
    """Reopening without naming the contract must adopt it from the segment
    header — defaulting would silently wrap-cast the vector payloads."""
    from repro.core.contracts import Q16_16, Q32_32
    vec = np.arange(D, dtype=np.int64) * (1 << 33)  # needs 64-bit storage
    log = commands.insert_cmd(7, vec, Q32_32)
    w = wal.WriteAheadLog(tmp_path, D, Q32_32, segment_records=16)
    w.append(log)
    r = wal.WriteAheadLog(tmp_path)
    assert r.contract.name == Q32_32.name
    back = r.read_range(0, 1)
    assert (np.asarray(back.vec[0]) == vec).all()
    with pytest.raises(ValueError, match="contract"):
        wal.WriteAheadLog(tmp_path, contract=Q16_16)


def test_wal_rejects_mismatched_vec_dtype(tmp_path):
    w = wal.WriteAheadLog(tmp_path, D, segment_records=16)
    log = _random_log(0, 4, id_space=4)
    bad = dataclasses.replace(log, vec=log.vec.astype(jnp.int8))
    with pytest.raises(ValueError, match="dtype"):
        w.append(bad)
    w.append(log)  # the good log still appends on a clean chain
    assert w.t == 4


def test_durable_checkpoint_manager_retention(tmp_path):
    genesis = init_state(32, D)
    mgr = DurableCheckpointManager(str(tmp_path / "d"), genesis, keep=2,
                                   segment_records=4)
    log = _random_log(8, 30, id_space=9)
    s = genesis
    for start in (0, 10, 20):
        piece = log.slice(start, start + 10)
        s = machine.bulk_apply(s, piece)
        mgr.save(s, piece)
    assert len(mgr.store.snapshots()) == 2
    state, h, t = mgr.recover()
    assert t == 30 and h == hashing.hash_pytree(s)


# --------------------------------------------------------------------------- #
# async checkpoint errors must not vanish (regression)
# --------------------------------------------------------------------------- #


def test_async_save_error_reraised(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path / "c"), async_save=True)
    tree = {"w": jnp.arange(8, dtype=jnp.int32)}
    import repro.checkpoint.manager as manager_mod

    def boom(*a, **k):
        raise OSError("disk on fire")

    monkeypatch.setattr(manager_mod, "save_checkpoint", boom)
    mgr.save(tree, step=1)  # schedules the failing background write
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    monkeypatch.undo()
    mgr.save(tree, step=2)  # error was cleared; next save works
    mgr.wait()
    assert mgr.steps() == [2]


def test_async_save_error_reraised_on_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path / "c"), async_save=True)
    tree = {"w": jnp.arange(8, dtype=jnp.int32)}
    import repro.checkpoint.manager as manager_mod
    monkeypatch.setattr(manager_mod, "save_checkpoint",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("x")))
    mgr.save(tree, step=1)
    with pytest.raises(RuntimeError):
        mgr.save(tree, step=2)  # surfaced on the NEXT save, not swallowed


def test_sync_save_error_raises(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path / "c"), async_save=False)
    import repro.checkpoint.manager as manager_mod
    monkeypatch.setattr(manager_mod, "save_checkpoint",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("x")))
    with pytest.raises(RuntimeError):
        mgr.save({"w": jnp.zeros(4)}, step=1)


# --------------------------------------------------------------------------- #
# checkpoint dedup against the chunk store
# --------------------------------------------------------------------------- #


def test_checkpoint_dedup_shares_chunks(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2, async_save=False,
                            dedup=True)
    big = jnp.arange(4096, dtype=jnp.int64)
    small = jnp.arange(8, dtype=jnp.int32)
    mgr.save({"big": big, "small": small}, step=1)
    written_after_1 = mgr._chunks.bytes_written
    mgr.save({"big": big, "small": small + 1}, step=2)  # big leaf unchanged
    delta = mgr._chunks.bytes_written - written_after_1
    assert delta < written_after_1 / 4, \
        "unchanged leaf must be deduplicated, not rewritten"
    tree, step, h = mgr.restore_latest({"big": big, "small": small})
    assert step == 2 and (np.asarray(tree["small"]) == np.asarray(small) + 1).all()

    mgr.save({"big": big * 2, "small": small}, step=3)  # rotates step 1 out
    referenced = set()
    for s in mgr.steps():
        man = json.loads(
            (mgr._ckpt_path(s) / "manifest.json").read_text())
        referenced.update(int(m["chunk"], 16) for m in man["leaves"])
    assert set(mgr._chunks.keys()) == referenced, \
        "gc must sweep chunks no surviving manifest references"


# --------------------------------------------------------------------------- #
# sharded snapshots under one merged manifest
# --------------------------------------------------------------------------- #


def test_sharded_snapshot_combined_hash(tmp_path):
    shards = [
        machine.replay(init_state(16, D), _random_log(s, 20, id_space=6))
        for s in range(2)
    ]
    full = distributed.merge_shards(shards)
    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    manifest = distributed.snapshot_sharded(full, 2, chunks, chunk_size=256)
    restored, h = distributed.restore_sharded(manifest, chunks)
    assert h == hashing.hash_pytree(full)
    for la, lb in zip(jax.tree_util.tree_leaves(restored),
                      jax.tree_util.tree_leaves(full)):
        assert (np.asarray(la) == np.asarray(lb)).all()
    # shard_slice is the exact inverse of merge_shards
    again = distributed.merge_shards(
        [distributed.shard_slice(full, s, 2) for s in range(2)])
    assert hashing.hash_pytree(again) == h


def test_sharded_snapshot_tamper_detected(tmp_path):
    shards = [machine.replay(init_state(16, D), _random_log(3, 10, id_space=4))
              for _ in range(2)]
    full = distributed.merge_shards(shards)
    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    manifest = bytearray(distributed.snapshot_sharded(full, 2, chunks))
    manifest[12] ^= 0x01  # flip a combined-hash bit
    with pytest.raises(ValueError, match="hash mismatch"):
        distributed.restore_sharded(bytes(manifest), chunks)


# --------------------------------------------------------------------------- #
# golden bytes: format drift is a reviewable event
# --------------------------------------------------------------------------- #


def _golden_state():
    """Tiny deterministic state built from integer-only commands (no float
    boundary, so the bytes are platform-invariant by construction)."""
    genesis = init_state(8, 4, max_links=2, meta_slots=2,
                         hnsw_levels=2, hnsw_degree=4)
    vecs = (np.arange(24, dtype=np.int64).reshape(6, 4) * 257 - 1500)
    log = commands.insert_batch(jnp.arange(6, dtype=jnp.int64),
                                jnp.asarray(vecs))
    log = log.concat(commands.delete_cmd(2, 4))
    log = log.concat(commands.link_cmd(0, 3, 4))
    log = log.concat(commands.set_meta_cmd(1, 1, 424242, 4))
    return machine.replay(genesis, log)


def test_golden_snapshot_bytes_stable(tmp_path):
    expect = json.loads((FIXTURES / "golden.json").read_text())
    state = _golden_state()
    assert hashing.hash_pytree(state) == int(expect["state_hash"], 16)

    # v1: serializer is byte-for-byte stable
    v1 = snapshot.snapshot_bytes(state)
    assert v1 == (FIXTURES / "golden_v1.bin").read_bytes(), \
        "v1 snapshot bytes drifted — bump FORMAT_VERSION, don't mutate v1"

    # v2: manifest bytes and chunk keys are stable
    chunks = snapshot.ChunkStore(tmp_path / "chunks")
    v2, _ = snapshot.snapshot_v2(state, chunks,
                                 chunk_size=expect["chunk_size"])
    assert v2 == (FIXTURES / "golden_v2_manifest.bin").read_bytes(), \
        "v2 manifest bytes drifted — bump FORMAT_VERSION_V2, don't mutate v2"


def test_golden_cross_version_restore():
    expect = json.loads((FIXTURES / "golden.json").read_text())
    s1, h1 = snapshot.restore_bytes((FIXTURES / "golden_v1.bin").read_bytes())
    s2, h2 = snapshot.restore_v2(
        (FIXTURES / "golden_v2_manifest.bin").read_bytes(),
        snapshot.ChunkStore(FIXTURES / "golden_v2_chunks"))
    assert h1 == h2 == int(expect["state_hash"], 16)
    for la, lb in zip(jax.tree_util.tree_leaves(s1),
                      jax.tree_util.tree_leaves(s2)):
        assert (np.asarray(la) == np.asarray(lb)).all()


# --------------------------------------------------------------------------- #
# SideTable: the durable serving-cache primitive (DESIGN.md §7)
# --------------------------------------------------------------------------- #


def test_side_table_roundtrip_later_wins_and_torn_tail(tmp_path):
    from repro.core.durability import SideTable
    path = tmp_path / "t.sdt"
    t = SideTable(path)
    t.put(1, b"one")
    t.put(2, b"two")
    t.put(1, b"uno")      # later record for a key wins
    t.sync()
    t.close()
    back = SideTable(path)
    assert back.entries == {1: b"uno", 2: b"two"}
    back.close()
    with open(path, "ab") as f:
        f.write(b"\xde\xadtorn record prefix")   # crash mid-append
    torn = SideTable(path)                        # truncates the torn tail
    assert torn.entries == {1: b"uno", 2: b"two"}
    torn.put(3, b"three")                         # and appends cleanly after
    torn.close()
    again = SideTable(path)
    assert again.entries == {1: b"uno", 2: b"two", 3: b"three"}
    again.close()


def test_side_table_put_sync_race_with_background_syncer(tmp_path):
    """put/sync serialize on the table lock: a background syncer (the
    group-commit timer's pre_flush) racing foreground puts must never mark
    an unfsynced record clean — after the final sync, every put is on disk."""
    import threading as _threading
    from repro.core.durability import SideTable
    path = tmp_path / "r.sdt"
    t = SideTable(path)
    stop = _threading.Event()

    def syncer():
        while not stop.is_set():
            t.sync()

    th = _threading.Thread(target=syncer)
    th.start()
    try:
        for i in range(500):
            t.put(i, f"payload-{i}".encode())
    finally:
        stop.set()
        th.join()
    t.sync()
    back = SideTable(path)  # reads exactly what is durable on disk
    assert len(back.entries) == 500, \
        "a put raced the syncer and was marked clean before reaching disk"
    assert back.entries[499] == b"payload-499"
    back.close()
    t.close()
