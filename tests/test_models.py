"""Model-stack correctness beyond smoke: cache-decode consistency vs
teacher-forced forward, SSD chunked == recurrent, rope/mrope equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.models import transformer as tf
from repro.models.layers import rope as rope_lib
from repro.models.layers import ssm as ssm_lib


@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "gemma2_2b",
                                  "mamba2_130m", "zamba2_2_7b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy multi-step decode through the cache must equal slicing the
    teacher-forced full forward at each position."""
    cfg = get_reduced_config(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, L, extra = 2, 16, 4
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (B, L + extra)).astype(np.int32)

    # teacher-forced logits over the whole sequence
    full_logits, _ = tf.apply(params, {"tokens": jnp.asarray(tokens)}, cfg)

    # prefill on the first L, then decode the next `extra` with real tokens
    last, caches = tf.prefill(params, {"tokens": jnp.asarray(tokens[:, :L])},
                              cfg, s_cache=L + extra + 4)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, L - 1]),
                               rtol=3e-2, atol=3e-2)
    for t in range(extra - 1):
        pos = jnp.full((B, 1), L + t, jnp.int32)
        step_logits, caches = tf.decode_step(
            params, caches, jnp.asarray(tokens[:, L + t:L + t + 1]), pos, cfg)
        # atol 1e-1: the single-token decode path and the fused full-seq
        # forward associate their float32 reductions differently; on the
        # widest arch a few low-magnitude logits (|x| ~ 1 in a ±10 range)
        # accumulate up to ~8e-2 absolute drift, which rtol can't absorb
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full_logits[:, L + t]),
            rtol=3e-2, atol=1e-1,
            err_msg=f"{arch}: decode diverged at step {t}")


def test_ssd_chunked_equals_recurrence():
    """The chunked (matmul-form) SSD must equal the token-by-token recurrence."""
    b, l, h, p, g, n = 2, 32, 4, 8, 1, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.normal(size=(b, l, h)), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.normal(size=(h,)), jnp.float32) * 0.3)
    B = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)

    y_chunk, final_chunk = ssm_lib.ssd(x, dt, A, B, C, chunk=8)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        y_t, state = ssm_lib.ssd_decode_step(
            state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y_t)
    y_rec = jnp.stack(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_chunk), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_flash_equals_naive_attention():
    """f32 so the only difference is the algorithm (bf16 end-to-end adds
    reduction-order noise ~0.3 in logits across 4 layers — not a bug, but it
    would mask one). The layer-level agreement here is ~1e-7."""
    from repro.models.layers import attention as att
    cfg = dataclasses.replace(get_reduced_config("h2o_danube_1_8b"),
                              dtype="float32")
    params = att.init_attention(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32)[None], (2, 32))
    for local in (False, True):
        o_naive, _ = att.attention(
            params, x, pos, dataclasses.replace(cfg, attn_impl="naive"),
            local=local, mode="train")
        o_flash, _ = att.attention(
            params, x, pos,
            dataclasses.replace(cfg, attn_impl="flash", flash_q_chunk=8,
                                flash_kv_chunk=8),
            local=local, mode="train")
        np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_flash),
                                   rtol=1e-5, atol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    """For equal (t,h,w) position streams, M-RoPE == standard RoPE exactly."""
    positions = jnp.arange(16, dtype=jnp.int32)[None]
    std = rope_lib.rope_angles(positions, 32, 10_000.0)
    m = rope_lib.mrope_angles(rope_lib.text_positions_3d(positions), 32,
                              10_000.0, (8, 4, 4))
    # mrope permutes frequency slots across sections; applying both to a
    # vector must give the same attention scores — check via inner products
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 2, 32))
    a = rope_lib.apply_rope(x, std)
    b = rope_lib.apply_rope(x, m)
    # scores between positions i,j depend only on angle differences, which
    # match per-frequency; for identical streams the angle TABLES themselves
    # must be a permutation-free match
    np.testing.assert_allclose(np.sort(np.asarray(std), axis=-1),
                               np.sort(np.asarray(m), axis=-1), rtol=1e-6)


def test_sliding_window_masks_long_range():
    """With window w, logits at position p must not depend on tokens < p-w."""
    cfg = dataclasses.replace(get_reduced_config("h2o_danube_1_8b"),
                              sliding_window=8, attn_impl="naive")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tok = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    tok2 = tok.copy()
    tok2[0, :4] = (tok2[0, :4] + 7) % cfg.vocab_size  # perturb far past
    la, _ = tf.apply(params, {"tokens": jnp.asarray(tok)}, cfg)
    lb, _ = tf.apply(params, {"tokens": jnp.asarray(tok2)}, cfg)
    # the last position (23) sees only positions ≥ 16 through EVERY layer
    # after ≥1 window hops information from <4 could creep in layer by layer;
    # with 4 layers × window 8, receptive field ≈ 32 > 24, so instead check
    # position 11 in a 1-layer variant
    cfg1 = dataclasses.replace(cfg, num_layers=1)
    p1 = tf.init_params(cfg1, jax.random.PRNGKey(0))
    la, _ = tf.apply(p1, {"tokens": jnp.asarray(tok)}, cfg1)
    lb, _ = tf.apply(p1, {"tokens": jnp.asarray(tok2)}, cfg1)
    np.testing.assert_allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_zigzag_equals_naive_attention():
    """The work-balanced causal path must be exact (f32, layer level)."""
    from repro.models.layers import attention as att
    cfg = dataclasses.replace(get_reduced_config("codeqwen1_5_7b"),
                              dtype="float32")
    params = att.init_attention(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32)[None], (2, 64))
    o_naive, _ = att.attention(
        params, x, pos, dataclasses.replace(cfg, attn_impl="naive"),
        local=False, mode="train")
    o_zig, _ = att.attention(
        params, x, pos,
        dataclasses.replace(cfg, attn_impl="latency", flash_q_chunk=8,
                            flash_kv_chunk=8),
        local=False, mode="train")
    np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_zig),
                               rtol=1e-5, atol=1e-5)
