"""Data pipeline, checkpointing, optimizer, gradient compression, elastic."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.checkpoint.manager import (CheckpointManager, load_checkpoint,
                                      save_checkpoint)
from repro.core import hashing
from repro.data.pipeline import DataConfig, DeterministicPipeline, feistel_permute
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime.elastic import plan_remesh


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #


def test_feistel_is_a_permutation():
    for n in (10, 100, 1000, 4096, 10_001):
        idx = np.arange(n)
        out = feistel_permute(idx, n, seed=3)
        assert sorted(out.tolist()) == list(range(n)), n
        assert not (out == idx).all()  # actually shuffles


def test_pipeline_deterministic_and_rank_consistent():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=101, seed=5)
    p = DeterministicPipeline(cfg)
    a = p.batch(3)
    b = p.batch(3)
    assert (a["tokens"] == b["tokens"]).all()
    # dp_size invariance: concatenating rank shards == the dp=1 batch
    parts = [p.batch(3, dp_rank=r, dp_size=4)["tokens"] for r in range(4)]
    assert (np.concatenate(parts) == a["tokens"]).all()


def test_pipeline_resume_mid_epoch():
    cfg = DataConfig(seq_len=8, global_batch=4, vocab_size=33, seed=1,
                     num_documents=64)
    p = DeterministicPipeline(cfg)
    trace_a = [p.batch(s)["tokens"] for s in range(40)]   # crosses epochs
    p2 = DeterministicPipeline(cfg)                        # "restarted" host
    trace_b = [p2.batch(s)["tokens"] for s in range(40)]
    for a, b in zip(trace_a, trace_b):
        assert (a == b).all()


def test_labels_are_shifted_tokens():
    p = DeterministicPipeline(DataConfig(seq_len=12, global_batch=2,
                                         vocab_size=50, seed=0))
    b = p.batch(0)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "b": jnp.arange(5, dtype=jnp.int32),
            "nested": {"s": jnp.float32(3.5)}}


def test_checkpoint_roundtrip_hash_verified(tmp_path):
    t = _tree()
    h = save_checkpoint(tmp_path / "c1", t, step=7)
    t2, step, h2 = load_checkpoint(tmp_path / "c1", jax.eval_shape(lambda: t))
    assert step == 7 and h == h2 == hashing.hash_pytree(t2)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_detects_tamper(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path / "c1", t, step=1)
    # corrupt one leaf file
    target = tmp_path / "c1" / "0.npy"
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="hash mismatch"):
        load_checkpoint(tmp_path / "c1", jax.eval_shape(lambda: t))


def test_manager_rotation_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(_tree(s), s)
    assert mgr.steps() == [20, 30]  # rotated
    restored = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert restored is not None and restored[1] == 30


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #


def test_adamw_reduces_quadratic_loss():
    optc = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                       weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(optc, params, g, state)
    assert float(loss(params)) < 0.5


def test_adamw_deterministic():
    optc = AdamWConfig()
    params = {"x": jnp.ones((4, 4))}

    def run():
        p, s = params, adamw_init(params)
        for i in range(5):
            g = jax.tree.map(lambda a: a * 0.1 * (i + 1), p)
            p, s, _ = adamw_update(optc, p, g, s)
        return hashing.hash_pytree(p)

    assert run() == run()


# --------------------------------------------------------------------------- #
# elastic planning
# --------------------------------------------------------------------------- #


def test_plan_remesh_shrinks_data_axis():
    full = plan_remesh(512, model=16, prefer_pods=2)
    assert full.shape == (2, 16, 16) and full.dropped_chips == 0
    # lose 5 chips from one pod → biggest valid mesh
    degraded = plan_remesh(507, model=16)
    assert degraded.size <= 507
    assert degraded.shape[-1] == 16  # TP width preserved
    assert degraded.size >= 256      # still uses most of the fleet


def test_plan_remesh_keeps_pow2_data():
    p = plan_remesh(300, model=16)
    data = p.shape[-2]
    assert data & (data - 1) == 0  # power of two


# --------------------------------------------------------------------------- #
# gradient compression (needs a 'pod' axis → subprocess with 4 devices)
# --------------------------------------------------------------------------- #

_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.optim import compress

    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 16, 16)).astype(np.float32) * 1e-3)}

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=(P("pod"),),
                       out_specs=P(), check_vma=False)
    def reduce_q(g):
        g = jax.tree.map(lambda a: a[0], g)
        mean, _ = compress.integer_psum_grads(g, "pod", "Q2.13")
        return mean

    got = reduce_q(grads)
    want = jnp.mean(grads["w"], axis=0)
    err = float(jnp.max(jnp.abs(got["w"] - want)))
    scale = float(jnp.max(jnp.abs(grads["w"])))
    # quantization error bounded by contract resolution * scale
    assert err <= scale / (1 << 13) + 1e-9, (err, scale)

    # determinism: run twice, bit-identical
    a = np.asarray(reduce_q(grads)["w"])
    b = np.asarray(reduce_q(grads)["w"])
    assert (a == b).all()
    print("COMPRESS_OK", err)
""")


def test_integer_gradient_allreduce():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _COMPRESS], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPRESS_OK" in proc.stdout
