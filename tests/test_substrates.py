"""Data pipeline, checkpointing, optimizer, gradient compression, elastic."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.checkpoint.manager import (CheckpointManager, load_checkpoint,
                                      save_checkpoint)
from repro.core import hashing
from repro.data.pipeline import DataConfig, DeterministicPipeline, feistel_permute
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime.elastic import plan_remesh


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #


def test_feistel_is_a_permutation():
    for n in (10, 100, 1000, 4096, 10_001):
        idx = np.arange(n)
        out = feistel_permute(idx, n, seed=3)
        assert sorted(out.tolist()) == list(range(n)), n
        assert not (out == idx).all()  # actually shuffles


def test_pipeline_deterministic_and_rank_consistent():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=101, seed=5)
    p = DeterministicPipeline(cfg)
    a = p.batch(3)
    b = p.batch(3)
    assert (a["tokens"] == b["tokens"]).all()
    # dp_size invariance: concatenating rank shards == the dp=1 batch
    parts = [p.batch(3, dp_rank=r, dp_size=4)["tokens"] for r in range(4)]
    assert (np.concatenate(parts) == a["tokens"]).all()


def test_pipeline_resume_mid_epoch():
    cfg = DataConfig(seq_len=8, global_batch=4, vocab_size=33, seed=1,
                     num_documents=64)
    p = DeterministicPipeline(cfg)
    trace_a = [p.batch(s)["tokens"] for s in range(40)]   # crosses epochs
    p2 = DeterministicPipeline(cfg)                        # "restarted" host
    trace_b = [p2.batch(s)["tokens"] for s in range(40)]
    for a, b in zip(trace_a, trace_b):
        assert (a == b).all()


def test_labels_are_shifted_tokens():
    p = DeterministicPipeline(DataConfig(seq_len=12, global_batch=2,
                                         vocab_size=50, seed=0))
    b = p.batch(0)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "b": jnp.arange(5, dtype=jnp.int32),
            "nested": {"s": jnp.float32(3.5)}}


def test_checkpoint_roundtrip_hash_verified(tmp_path):
    t = _tree()
    h = save_checkpoint(tmp_path / "c1", t, step=7)
    t2, step, h2 = load_checkpoint(tmp_path / "c1", jax.eval_shape(lambda: t))
    assert step == 7 and h == h2 == hashing.hash_pytree(t2)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_detects_tamper(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path / "c1", t, step=1)
    # corrupt one leaf file
    target = tmp_path / "c1" / "0.npy"
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="hash mismatch"):
        load_checkpoint(tmp_path / "c1", jax.eval_shape(lambda: t))


def test_manager_rotation_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(_tree(s), s)
    assert mgr.steps() == [20, 30]  # rotated
    restored = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert restored is not None and restored[1] == 30


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #


def test_adamw_reduces_quadratic_loss():
    optc = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                       weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(optc, params, g, state)
    assert float(loss(params)) < 0.5


def test_adamw_deterministic():
    optc = AdamWConfig()
    params = {"x": jnp.ones((4, 4))}

    def run():
        p, s = params, adamw_init(params)
        for i in range(5):
            g = jax.tree.map(lambda a: a * 0.1 * (i + 1), p)
            p, s, _ = adamw_update(optc, p, g, s)
        return hashing.hash_pytree(p)

    assert run() == run()


# --------------------------------------------------------------------------- #
# elastic planning
# --------------------------------------------------------------------------- #


def test_plan_remesh_shrinks_data_axis():
    full = plan_remesh(512, model=16, prefer_pods=2)
    assert full.shape == (2, 16, 16) and full.dropped_chips == 0
    # lose 5 chips from one pod → biggest valid mesh
    degraded = plan_remesh(507, model=16)
    assert degraded.size <= 507
    assert degraded.shape[-1] == 16  # TP width preserved
    assert degraded.size >= 256      # still uses most of the fleet


def test_plan_remesh_keeps_pow2_data():
    p = plan_remesh(300, model=16)
    data = p.shape[-2]
    assert data & (data - 1) == 0  # power of two


# --------------------------------------------------------------------------- #
# gradient compression (needs a 'pod' axis → subprocess with 4 devices)
# --------------------------------------------------------------------------- #

_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.optim import compress

    from repro.core import compat
    mesh = compat.make_mesh((4,), ("pod",))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 16, 16)).astype(np.float32) * 1e-3)}

    @functools.partial(compat.shard_map, mesh=mesh, in_specs=(P("pod"),),
                       out_specs=P(), check_vma=False)
    def reduce_q(g):
        g = jax.tree.map(lambda a: a[0], g)
        mean, _ = compress.integer_psum_grads(g, "pod", "Q2.13")
        return mean

    got = reduce_q(grads)
    want = jnp.mean(grads["w"], axis=0)
    err = float(jnp.max(jnp.abs(got["w"] - want)))
    scale = float(jnp.max(jnp.abs(grads["w"])))
    # quantization error bounded by contract resolution * scale
    assert err <= scale / (1 << 13) + 1e-9, (err, scale)

    # determinism: run twice, bit-identical
    a = np.asarray(reduce_q(grads)["w"])
    b = np.asarray(reduce_q(grads)["w"])
    assert (a == b).all()
    print("COMPRESS_OK", err)
""")


def test_integer_gradient_allreduce():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _COMPRESS], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPRESS_OK" in proc.stdout


# --------------------------------------------------------------------------- #
# cross-substrate agreement on a bulk-applied log (needs >1 device →
# subprocess, per the dry-run isolation rule)
# --------------------------------------------------------------------------- #

_CROSS_SUBSTRATE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    import repro
    from repro.core import (boundary, commands, compat, distributed, hashing,
                            hnsw, machine, search)
    from repro.core.state import init_state

    D, N, K = 16, 48, 5
    rng = np.random.default_rng(0)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(N, D)).astype(np.float32))
    ids = jnp.arange(N, dtype=jnp.int64) * 7 + 3
    log = commands.insert_batch(ids, vecs)
    q = boundary.admit_query(rng.normal(size=(4, D)).astype(np.float32))

    # substrate 1: flat kernel, bulk-applied — exact search
    flat = machine.bulk_apply(init_state(128, D), log)
    e_ids, _ = search.exact_search(flat, q, K)

    # substrate 2: deterministic HNSW on the same bulk-applied state
    # (ef > N ⇒ the beam covers the whole connected graph ⇒ exact answers)
    h_ids = np.stack([
        np.asarray(hnsw.hnsw_search(flat, qq, K, ef=64)[0]) for qq in q])

    # substrate 3: sharded memory, routed log bulk-applied per shard
    def sharded_ids(n_shards, mesh_shape):
        mesh = compat.make_mesh(mesh_shape, ("model", "data"))
        st = distributed.init_sharded_state(mesh, "model", 128 // n_shards, D)
        st = distributed.distributed_bulk_apply(
            mesh, "model", st, distributed.route_commands(log, n_shards))
        d_ids, _ = distributed.distributed_search(
            mesh, "model", st, q, K, query_axis="data")
        return st, np.asarray(d_ids)

    st4, ids4 = sharded_ids(4, (4, 2))
    st2, ids2 = sharded_ids(2, (2, 4))

    for b in range(q.shape[0]):
        exact_set = set(np.asarray(e_ids)[b].tolist())
        assert set(h_ids[b].tolist()) == exact_set, ("hnsw", b)
        assert set(ids4[b].tolist()) == exact_set, ("sharded4", b)
        assert set(ids2[b].tolist()) == exact_set, ("sharded2", b)

    # shard count must not change the memory content union: the sorted live
    # (id, vector, meta) rows hash identically for 1, 2 and 4 shards
    def content_hash(state):
        ids_h = np.asarray(state.ids)
        valid = np.asarray(state.valid)
        order = np.argsort(ids_h[valid])
        return hashing.hash_pytree({
            "ids": jnp.asarray(ids_h[valid][order]),
            "vecs": jnp.asarray(np.asarray(state.vectors)[valid][order]),
            "meta": jnp.asarray(np.asarray(state.meta)[valid][order]),
        })

    h_flat, h2, h4 = content_hash(flat), content_hash(st2), content_hash(st4)
    assert h_flat == h2 == h4, (hex(h_flat), hex(h2), hex(h4))
    print("CROSS_SUBSTRATE_OK", hex(h_flat))
""")


def test_cross_substrate_agreement_on_bulk_applied_log():
    """exact, HNSW and sharded search agree on a bulk-applied log, and the
    memory content union is invariant to shard count."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(repo_src)
    proc = subprocess.run([sys.executable, "-c", _CROSS_SUBSTRATE], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "CROSS_SUBSTRATE_OK" in proc.stdout
