"""Fault tolerance: checkpoint/restart bitwise recovery, straggler policy."""
import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import hashing
from repro.runtime.coordinator import Coordinator, RunConfig, StragglerPolicy


def _toy_setup(tmp_path, failures=(), name="run"):
    """Tiny deterministic 'training': state = {w}; batch from step index."""

    def init_state_fn():
        return {"w": jnp.zeros((4, 4), jnp.float64),
                "step_sum": jnp.zeros((), jnp.int64)}

    def batch_fn(step):
        rng = np.random.default_rng(step)  # pure function of step
        return jnp.asarray(rng.normal(size=(4, 4)))

    def train_step(state, batch):
        w = state["w"] * 0.9 + batch * 0.1
        return ({"w": w, "step_sum": state["step_sum"] + 1},
                {"loss": jnp.sum(w ** 2)})

    fail_iter = iter(failures)
    injected = set(failures)
    fired = set()

    def injector(step):
        if step in injected and step not in fired:
            fired.add(step)
            return f"node lost at {step}"
        return None

    run = RunConfig(total_steps=30, checkpoint_every=5,
                    checkpoint_dir=str(tmp_path / name), max_restarts=5)
    return Coordinator(run, train_step, batch_fn, init_state_fn,
                       failure_injector=injector)


def test_failure_recovery_bitwise_identical(tmp_path):
    clean = _toy_setup(tmp_path, failures=(), name="clean").train()
    faulty_coord = _toy_setup(tmp_path, failures=(7, 18), name="faulty")
    faulty = faulty_coord.train()
    assert hashing.hash_pytree(clean) == hashing.hash_pytree(faulty), (
        "restart broke determinism")
    events = [e["event"] for e in faulty_coord.events]
    assert events.count("failure") == 2
    assert events.count("restart") == 2


def test_resume_from_existing_checkpoints(tmp_path):
    c1 = _toy_setup(tmp_path, name="resume")
    c1.run = RunConfig(total_steps=12, checkpoint_every=5,
                       checkpoint_dir=str(tmp_path / "resume"))
    mid = c1.train()
    # new coordinator continues to 30 from the stored step
    c2 = _toy_setup(tmp_path, name="resume")
    final = c2.train()
    assert any(e["event"] == "resume" for e in c2.events)
    clean = _toy_setup(tmp_path, name="clean2").train()
    assert hashing.hash_pytree(final) == hashing.hash_pytree(clean)


def test_straggler_flag_and_evict():
    pol = StragglerPolicy(deadline_factor=2.0, evict_after=2)
    run = RunConfig(total_steps=1, straggler=pol, checkpoint_dir="/tmp/x")
    coord = Coordinator(run, lambda s, b: (s, {}), lambda s: None, dict)
    times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
    assert coord._check_stragglers(times) == []       # first flag
    assert coord._check_stragglers(times) == [3]      # second → evict
    # healthy rank resets its counter
    coord._check_stragglers({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0})
    assert coord.flag_counts[3] == 0
