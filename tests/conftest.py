"""Suite-wide fixtures.

The full suite compiles thousands of distinct XLA executables in one
process; each holds live memory mappings, and the process crosses the
kernel's ``vm.max_map_count`` (65530 by default) around ~500 tests in —
at which point the next compiler ``mmap`` fails and XLA segfaults.
Dropping the jit caches between test modules releases the mappings
(verified: map count returns to baseline after ``jax.clear_caches()``)
and bounds the suite's footprint at the cost of cross-module cache
reuse, which only ever saved recompiles of the handful of shared entry
points.
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_mappings():
    yield
    jax.clear_caches()
    gc.collect()
