"""Per-architecture smoke tests: REDUCED config, one forward/train step on CPU,
asserting output shapes and finiteness (no NaNs) — per the assignment spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401  (enables x64)
from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import transformer as tf


def _batch(cfg, key, B=2, L=32):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {}
    if cfg.external_embeddings:
        batch["embeds"] = jax.random.normal(k1, (B, L, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(k1, (B, L), 0, cfg.vocab_size,
                                             dtype=jnp.int32)
    batch["labels"] = jax.random.randint(k2, (B, L), 0, cfg.vocab_size,
                                         dtype=jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(tf.loss_fn, has_aux=True)(
        params, batch, cfg
    )
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), (
            f"{arch}: non-finite grads"
        )

    logits, aux = tf.apply(params, batch, cfg)
    B, L = (batch.get("tokens", batch.get("embeds"))).shape[:2]
    # logits carry the TP-padded vocab; padded columns are masked to -1e30
    assert logits.shape == (B, L, cfg.padded_vocab), f"{arch}: {logits.shape}"
    assert int(jnp.max(jnp.argmax(logits, -1))) < cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    B, L = 2, 32
    batch = _batch(cfg, jax.random.PRNGKey(1), B=B, L=L)

    logits_all, _ = tf.apply(params, batch, cfg)
    last, caches = tf.prefill(params, batch, cfg, s_cache=L + 8)
    # prefill must agree with the full forward at the last position
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_all[:, -1]), rtol=2e-2, atol=2e-2
    )

    pos = jnp.full((B, 1), L, jnp.int32)
    if cfg.external_embeddings:
        emb = jax.random.normal(key, (B, 1, cfg.d_model), jnp.float32)
        ld, _ = tf.decode_step(params, caches, None, pos, cfg, embeds=emb)
    else:
        tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        ld, _ = tf.decode_step(params, caches, tok, pos, cfg)
    assert ld.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(ld)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims(arch):
    """The FULL configs are exercised via the dry-run; here we only check the
    published dimensions are wired through (no allocation)."""
    cfg = get_config(arch)
    expected = {
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92416),
        "mamba2_130m": (24, 768, 0, 0, 0, 50280),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "phi3_5_moe_42b_a6_6b": (32, 4096, 32, 8, 6400, 32064),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_param_counts_plausible():
    """Sanity: headline param counts should be within ~25% of the name."""
    approx = {
        "gemma2_2b": 2.6e9,       # 2b + big embedding
        "granite_34b": 34e9,
        "h2o_danube_1_8b": 1.8e9,
        "codeqwen1_5_7b": 7e9,
        "mamba2_130m": 130e6,
        "qwen2_vl_7b": 7e9,       # backbone ~6.5e9 of the 8b total
        "phi3_5_moe_42b_a6_6b": 42e9,
        "musicgen_large": 3.3e9,  # decoder of the 3.3b (no T5/EnCodec)
        "zamba2_2_7b": 2.7e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.6 * want, f"{arch}: {got:.3g} vs {want:.3g}"


def test_granite_moe_active_params():
    cfg = get_config("granite_moe_3b_a800m")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < total
    # ~3b total / ~800m active headline
    assert 1.5e9 < total < 4.5e9, total
    assert 0.3e9 < active < 1.4e9, active
