"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (exact equality —
integer kernels admit no tolerance)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.kernels.qgemm import ops as qgemm_ops
from repro.kernels.qgemm import ref as qgemm_ref
from repro.kernels.qtopk import ops as qtopk_ops
from repro.kernels.qtopk import ref as qtopk_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nq,nn,d", [
    (1, 1, 8), (4, 16, 32), (8, 128, 64), (128, 256, 512),
    (7, 100, 384), (130, 257, 640), (16, 1000, 768), (3, 33, 8192),
])
def test_qgemm_exact_vs_oracle(nq, nn, d):
    q = RNG.integers(-65536, 65537, size=(nq, d)).astype(np.int32)
    db = RNG.integers(-65536, 65537, size=(nn, d)).astype(np.int32)
    got = qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(db))
    want = qgemm_ref.qgemm_ref(jnp.asarray(q), jnp.asarray(db))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qgemm_extreme_values():
    """Boundary raws (±2^16) at max dim: the overflow-freedom proof, tested."""
    d = 8192
    q = np.full((2, d), 65536, np.int32)
    q[1] = -65536
    db = np.concatenate([np.full((1, d), 65536, np.int32),
                         np.full((1, d), -65536, np.int32)])
    got = qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(db))
    want = qgemm_ref.qgemm_ref(jnp.asarray(q), jnp.asarray(db))
    assert (np.asarray(got) == np.asarray(want)).all()
    assert int(got[0, 0]) == d * 65536 * 65536


def test_qgemm_rejects_oversized_dim():
    q = np.zeros((2, 16384), np.int32)
    with pytest.raises(ValueError, match="dim"):
        qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(q))


@given(st.integers(1, 6), st.integers(4, 200), st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_qtopk_property(nq, n, k):
    k = min(k, n)
    s = RNG.integers(-2**45, 2**45, size=(nq, n)).astype(np.int64)
    keys = np.arange(n, dtype=np.int32)
    got_s, got_k = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), k)
    want_s, want_k = qtopk_ref.qtopk_ref(jnp.asarray(s), jnp.asarray(keys), k)
    assert (np.asarray(got_s) == np.asarray(want_s)).all()
    assert (np.asarray(got_k) == np.asarray(want_k)).all()


def test_qtopk_tie_break_by_key():
    s = np.zeros((1, 64), np.int64)  # ALL tied
    keys = np.arange(64, dtype=np.int32)[::-1].copy()  # reversed keys
    got_s, got_k = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), 5)
    assert np.asarray(got_k)[0].tolist() == [0, 1, 2, 3, 4]


def test_qtopk_big_block_sweep():
    for n in (1024, 2048, 4096, 5000):
        s = RNG.integers(-2**40, 2**40, size=(4, n)).astype(np.int64)
        keys = np.arange(n, dtype=np.int32)
        got = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), 16)
        want = qtopk_ref.qtopk_ref(jnp.asarray(s), jnp.asarray(keys), 16)
        assert (np.asarray(got[0]) == np.asarray(want[0])).all()
        assert (np.asarray(got[1]) == np.asarray(want[1])).all()


# --------------------------------------------------------------------------- #
# qboundary: the fused determinism boundary (quantize + integer normalize)
# --------------------------------------------------------------------------- #

from repro.core.contracts import Q8_8, Q16_16  # noqa: E402
from repro.kernels.qboundary import ops as qb_ops  # noqa: E402
from repro.kernels.qboundary import ref as qb_ref  # noqa: E402


@pytest.mark.parametrize("n,d", [(1, 8), (4, 16), (128, 384), (257, 768),
                                 (100, 64)])
def test_qboundary_bitwise_vs_oracle(n, d):
    x = RNG.normal(size=(n, d)).astype(np.float32) * 2
    got = qb_ops.qboundary(jnp.asarray(x), Q16_16)
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q16_16)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qboundary_no_norm_and_saturation():
    x = np.asarray([[0.5, -1.0, 40000.0, -40000.0]], np.float32)
    got = qb_ops.qboundary(jnp.asarray(x), Q16_16, unit_norm=False)
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q16_16, unit_norm=False)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert int(got[0, 2]) == Q16_16.max_raw  # saturating convert


def test_qboundary_narrow_contract_falls_back():
    x = RNG.normal(size=(8, 16)).astype(np.float32)
    got = qb_ops.qboundary(jnp.asarray(x), Q8_8)       # int16 storage → ref path
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q8_8)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qboundary_unit_norm_property():
    x = RNG.normal(size=(32, 128)).astype(np.float32) * 3
    raw = np.asarray(qb_ops.qboundary(jnp.asarray(x), Q16_16))
    norms = (raw.astype(np.float64) / Q16_16.one)
    lens = np.sqrt((norms ** 2).sum(-1))
    assert np.abs(lens - 1.0).max() < 1e-3
